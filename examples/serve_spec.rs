//! Batched serving demo through the router (the vLLM-shaped front-end):
//! bounded-queue admission, continuous batching with mid-flight
//! join/leave (a finished sequence's KV row is reused by the next queued
//! request), a worker thread owning the engine, per-request metrics —
//! including true per-session queue wait, time-to-first-token and
//! latency rather than the old group-total stamp.
//!
//! Requires trained checkpoints (run `make drafts` or the quickstart
//! first). Usage:
//!
//! ```text
//! cargo run --release --example serve_spec -- \
//!     [--draft eagle3@dense-s] [--loss lkl-eta3] [--requests 16] [--runs runs]
//! ```

use std::path::PathBuf;

use anyhow::Context;

use lk_spec::data::corpus::Corpus;
use lk_spec::data::grammar::Domain;
use lk_spec::runtime::Runtime;
use lk_spec::server::{Router, RouterConfig, SpecEngine};
use lk_spec::train::RunDirs;
use lk_spec::util::{Args, Json};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let draft = args.opt_or("draft", "eagle3@dense-s").to_string();
    let loss = args.opt_or("loss", "lkl-eta3").to_string();
    let n_requests = args.opt_usize("requests", 16)?;
    let max_new = args.opt_usize("max-new", 32)?;
    let runs = PathBuf::from(args.opt_or("runs", "runs"));
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let data = PathBuf::from(args.opt_or("data", "data"));
    args.finish()?;

    let corpus = Corpus::open(&data)?;
    let prompts = corpus.load(Domain::Chat, "eval")?.prompts(n_requests, 16);

    let draft2 = draft.clone();
    let router = Router::spawn(RouterConfig::default(), move || {
        let rt = Box::leak(Box::new(Runtime::new(&artifacts)?));
        let dirs = RunDirs::new(&runs);
        let dspec = rt.manifest.draft(&draft2)?.clone();
        let tckpt = lk_spec::tensor::read_checkpoint(&dirs.target_ckpt(&dspec.target))
            .context("target checkpoint (run `make targets` first)")?;
        let stem = format!("{}__{loss}", draft2.replace('@', "_"));
        let dckpt = lk_spec::tensor::read_checkpoint(&dirs.draft_ckpt(&stem))
            .context("draft checkpoint (run `make drafts` first)")?;
        let vocab_map = if dspec.arch == "eagle3" {
            let j = Json::parse_file(&dirs.vocab_map())?;
            Some(
                j.get("map")
                    .as_arr()
                    .context("map")?
                    .iter()
                    .map(|x| x.as_i64().unwrap_or(0) as i32)
                    .collect::<Vec<i32>>(),
            )
        } else {
            None
        };
        // SpecEngine implements SchedulerCore; the router's worker wraps
        // it in a continuous-batching Scheduler, so sequences join and
        // leave the running decode group mid-flight.
        SpecEngine::new(rt, &draft2, &tckpt, &dckpt, vocab_map, Default::default())
    })?;

    println!("submitting {} requests (draft={draft})…", prompts.len());
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = prompts
        .iter()
        .map(|p| router.submit(p.clone(), max_new))
        .collect::<anyhow::Result<_>>()?;
    let mut tokens = 0usize;
    let mut taus = Vec::new();
    for (i, rx) in receivers.into_iter().enumerate() {
        let res = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "  req {i:>2}: {:>3} tokens  tau={:.2}  queue {:>5.0} ms  ttft {:>5.0} ms  total {:>6.0} ms",
            res.tokens.len(),
            res.stats.tau(),
            res.queue_ms,
            res.ttft_ms,
            res.latency_ms
        );
        tokens += res.tokens.len();
        taus.push(res.stats.tau());
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\nthroughput: {:.1} tok/s over {} requests, mean tau {:.2}",
        tokens as f64 / secs,
        prompts.len(),
        taus.iter().sum::<f64>() / taus.len() as f64
    );
    router.shutdown();
    Ok(())
}
