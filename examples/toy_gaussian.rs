//! Figure 2 reproduction: fit a single Gaussian to a bimodal mixture
//! under forward KL, reverse KL and TV; report the density overlap
//! (= continuous acceptance rate, paper Appendix C).
//!
//! ```text
//! cargo run --release --example toy_gaussian
//! ```
//!
//! Expected qualitative pattern (paper Fig. 2): forward KL mass-covers,
//! reverse KL mode-seeks, TV finds the overlap-maximizing compromise and
//! wins by several points of acceptance.

use lk_spec::spec::overlap::{fit, grid, overlap, Mixture, Objective};

fn ascii_plot(target: &Mixture, mu: f64, sigma: f64) -> String {
    // crude terminal density sketch: target '#', fit 'o', both '@'
    let xs = grid(-6.0, 6.0, 61);
    let rows = 8;
    let pmax = xs.iter().map(|&x| target.pdf(x)).fold(0.0, f64::max);
    let mut canvas = vec![vec![' '; xs.len()]; rows];
    for (i, &x) in xs.iter().enumerate() {
        let tp = ((target.pdf(x) / pmax) * (rows as f64 - 1.0)).round() as usize;
        let qp = ((lk_spec::spec::overlap::gauss_pdf(x, mu, sigma) / pmax)
            * (rows as f64 - 1.0))
            .round() as usize;
        let tp = tp.min(rows - 1);
        let qp = qp.min(rows - 1);
        canvas[rows - 1 - tp][i] = '#';
        canvas[rows - 1 - qp][i] = if canvas[rows - 1 - qp][i] == '#' { '@' } else { 'o' };
    }
    canvas
        .into_iter()
        .map(|r| r.into_iter().collect::<String>())
        .collect::<Vec<_>>()
        .join("\n")
}

fn main() {
    let target = Mixture::paper_toy();
    let xs = grid(-12.0, 12.0, 2001);
    println!("fitting one Gaussian to the bimodal target (Figure 2):\n");
    let mut results = Vec::new();
    for obj in [Objective::ForwardKl, Objective::ReverseKl, Objective::Tv] {
        let (mu, sigma, val) = fit(obj, &target, &xs);
        let alpha = overlap(&target, mu, sigma, &xs);
        println!(
            "{:<10}  mu={:+.2}  sigma={:.2}  objective={:.4}  alpha={:.1}%",
            obj.name(),
            mu,
            sigma,
            val,
            alpha * 100.0
        );
        println!("{}\n", ascii_plot(&target, mu, sigma));
        results.push((obj, alpha));
    }
    let a_tv = results[2].1;
    println!(
        "TV wins by {:+.1}pp over forward KL and {:+.1}pp over reverse KL\n\
         (paper: 60.2% vs 50.2% / 50.8% on its mixture — TV maximizes the\n\
         overlap because alpha = 1 - TV exactly).",
        (a_tv - results[0].1) * 100.0,
        (a_tv - results[1].1) * 100.0
    );
}
