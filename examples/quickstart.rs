//! End-to-end quickstart: the full lk-spec pipeline on one small model.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Steps (all on the public API, no Python anywhere):
//!   1. generate the synthetic domain corpora
//!   2. pretrain the `dense-s` target LM            (L3 driving AOT XLA)
//!   3. train an EAGLE-3 speculator twice: KL baseline vs the paper's
//!      hybrid LK^λ (η=3) objective                 (one artifact, two
//!      runtime loss configs — the "drop-in" property)
//!   4. serve batched requests through the speculative-decoding engine
//!      with exact rejection sampling and report τ + speedup for both
//!
//! The full-protocol sweep (`make experiments`) reproduces the paper's
//! LK > KL ordering; this quickstart's single noisy cell demonstrates
//! the PIPELINE (train→serve→measure) in a few minutes of CPU time.

use std::path::Path;

use lk_spec::config::{LossSpec, TrainPreset};
use lk_spec::data::corpus::{Corpus, CorpusSpec};
use lk_spec::data::grammar::Domain;
use lk_spec::eval::{eval_cell, EvalMode, EvalSettings};
use lk_spec::runtime::Runtime;
use lk_spec::train::{DraftTrainer, RunDirs, TargetTrainer};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let work = Path::new("runs/quickstart");
    let data = work.join("data");

    // 1. corpora --------------------------------------------------------
    let corpus = Corpus::generate(
        &data,
        &CorpusSpec {
            train_tokens: 120_000,
            ..Default::default()
        },
    )?;

    // 2. target pretrain --------------------------------------------------
    let rt = Runtime::new(artifacts)?;
    let dirs = RunDirs::new(work);
    let target = "dense-s";
    if !dirs.target_ckpt(target).exists() {
        let preset = TrainPreset {
            steps: 300,
            ..TrainPreset::target(target)
        };
        let final_loss =
            TargetTrainer { rt: &rt, dirs: RunDirs::new(work) }.train(target, &corpus, &preset, 50)?;
        println!("target pretrained, final LM loss {final_loss:.3}");
    }

    // 3. speculators: KL vs LK^λ -----------------------------------------
    let draft = "eagle3@dense-s";
    for loss in [LossSpec::kl(), LossSpec::lk_lambda(3.0)] {
        let stem = format!("{}__{}", draft.replace('@', "_"), loss.tag);
        if dirs.draft_ckpt(&stem).exists() {
            continue;
        }
        let preset = TrainPreset {
            steps: 200,
            ..TrainPreset::draft(target, "eagle3")
        };
        let m = DraftTrainer { rt: &rt, dirs: RunDirs::new(work) }
            .train(draft, &loss, &corpus, &preset, 50)?;
        println!(
            "trained {} with {}: mean acceptance {:.3}",
            draft, loss.label, m.mean_alpha
        );
    }

    // 4. serve + compare ---------------------------------------------------
    println!("\n{:<22} {:>7} {:>9} {:>9}", "objective", "tau", "tok/s", "speedup");
    let settings = EvalSettings {
        n_prompts: 8,
        n_time_prompts: 2,
        ..Default::default()
    };
    let mut taus = Vec::new();
    for loss in [LossSpec::kl(), LossSpec::lk_lambda(3.0)] {
        let cell = eval_cell(
            &rt, &dirs, &corpus, draft, &loss.tag, Domain::Chat, EvalMode::T1,
            7, &settings, false,
        )?;
        println!(
            "{:<22} {:>7.3} {:>9.1} {:>9.2}",
            loss.label, cell.tau, cell.spec_tps, cell.speedup
        );
        taus.push(cell.tau);
    }
    println!(
        "\nLK^λ vs KL on τ: {:+.1}%  (paper: +3.9% at T=1 for this pair; at the\n\
         quickstart's 200-step budget single-cell τ is noisy to ±5% — run\n\
         `make experiments` for the full-protocol comparison, which\n\
         reproduces the LK > KL ordering)",
        (taus[1] / taus[0] - 1.0) * 100.0
    );
    Ok(())
}
