//! Train one speculator with any LK-family objective and watch the
//! per-head acceptance/λ dynamics — the paper's §4.2 curriculum in action
//! (λ starts near 1 = KL-dominated, decays as acceptance rises).
//!
//! ```text
//! cargo run --release --example train_speculator -- \
//!     [--draft eagle3@dense-s] [--loss lkl-eta3] [--steps 150]
//! ```
//!
//! Requires `make data targets` (or the quickstart) to have produced the
//! corpus + target checkpoint.

use std::path::PathBuf;

use lk_spec::config::{LossSpec, TrainPreset};
use lk_spec::data::corpus::Corpus;
use lk_spec::runtime::Runtime;
use lk_spec::train::{DraftTrainer, RunDirs};
use lk_spec::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let draft = args.opt_or("draft", "eagle3@dense-s").to_string();
    let loss = LossSpec::parse(args.opt_or("loss", "lkl-eta3"))?;
    let steps = args.opt_usize("steps", 150)?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let data = PathBuf::from(args.opt_or("data", "data"));
    let runs = PathBuf::from(args.opt_or("runs", "runs"));
    args.finish()?;

    lk_spec::util::log::set_level(3); // show every logged step

    let rt = Runtime::new(&artifacts)?;
    let corpus = Corpus::open(&data)?;
    let dspec = rt.manifest.draft(&draft)?;
    let preset = TrainPreset {
        steps,
        ..TrainPreset::draft(&dspec.target, &dspec.arch)
    };
    let trainer = DraftTrainer {
        rt: &rt,
        dirs: RunDirs::new(&runs),
    };
    let metrics = trainer.train(&draft, &loss, &corpus, &preset, 10)?;
    println!("\nfinal per-head acceptance rates (position 1..K):");
    for (i, (a, l)) in metrics
        .alpha_heads
        .iter()
        .zip(&metrics.lambda_heads)
        .enumerate()
    {
        println!("  head {}: alpha={:.3}  lambda={:.3}", i + 1, a, l);
    }
    println!(
        "\nNote the paper's two signatures: alpha decays with head depth\n\
         (deeper positions are harder) and lambda = exp(-eta*alpha) is\n\
         correspondingly higher for deeper heads — more KL guidance where\n\
         alignment is weak (§4.2, MTP rationale in §5.2)."
    );
    Ok(())
}
