//! Benchmark support (criterion is unavailable offline): a small
//! warmup/iterate/stats harness for micro-benches plus markdown table
//! rendering shared by the per-paper-table bench binaries, which write
//! their regenerated tables to `results/`.

use std::time::Instant;

use crate::util::{Json, Percentiles};

/// Measure a closure: warmup then timed iterations; returns stats in ms.
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut pct = Percentiles::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        pct.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: pct.mean(),
        p50_ms: pct.pct(50.0),
        p95_ms: pct.pct(95.0),
        p99_ms: pct.pct(99.0),
    }
}

/// Markdown table builder.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("## {}\n\n", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out.push('\n');
        out
    }

    /// Print to stdout and append to `results/<file>.md`.
    pub fn emit(&self, file: &str) -> anyhow::Result<()> {
        let text = self.render();
        print!("{text}");
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{file}.md")), &text)?;
        Ok(())
    }
}

pub fn fmt(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Machine-readable bench rows: flat JSON objects accumulated across a
/// bench binary's sections and written as one array (e.g.
/// `results/BENCH_engine.json`) so the perf trajectory accumulates as
/// CI artifacts instead of prose tables.
#[derive(Default)]
pub struct JsonRows {
    rows: Vec<Json>,
}

impl JsonRows {
    pub fn new() -> JsonRows {
        JsonRows::default()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append one row; values are `Json` scalars (`Json::Num`,
    /// `Json::Str`, …).
    pub fn push(&mut self, fields: Vec<(&str, Json)>) {
        self.rows.push(Json::obj(fields));
    }

    /// Write the accumulated rows to `results/<file>`.
    pub fn write(&self, file: &str) -> anyhow::Result<()> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        Json::Arr(self.rows.clone()).write_file(&dir.join(file))
    }
}

/// Graceful skip for benches whose inputs (trained checkpoints / result
/// cells) are not present — keeps `cargo bench` green on a fresh clone.
pub fn skip(msg: &str) {
    println!("SKIP: {msg}");
    println!("      run `make data targets drafts && ./target/release/lk-spec eval-all` first");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("| a  | bb |") || s.contains("| a | bb |"));
        assert!(s.contains("| 1"));
    }

    #[test]
    fn json_rows_roundtrip() {
        let mut rows = JsonRows::new();
        assert!(rows.is_empty());
        rows.push(vec![
            ("bench", Json::Str("x".into())),
            ("tok_s", Json::Num(12.5)),
            ("rounds", Json::Num(3.0)),
        ]);
        assert_eq!(rows.len(), 1);
        let text = Json::Arr(rows.rows.clone()).to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.idx(0).get("bench").as_str(), Some("x"));
        assert_eq!(back.idx(0).get("tok_s").as_f64(), Some(12.5));
    }

    #[test]
    fn bench_measures() {
        let r = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_ms < 10.0);
        assert!(r.p99_ms >= r.p50_ms);
    }
}
