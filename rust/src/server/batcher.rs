//! Request admission & batching policy (pure logic — unit-testable
//! without PJRT).
//!
//! The engine executes lockstep groups at the lowered batch buckets
//! (manifest `serve_batches`, e.g. {1, 4}). The batcher accumulates
//! queued requests and decides when to form a group: as soon as a full
//! bucket is available, or when the oldest request has waited longer
//! than `max_wait`, whichever comes first — the standard
//! latency/throughput trade of continuous batching front-ends.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub buckets: Vec<usize>,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_millis(20),
            queue_cap: 256,
        }
    }
}

pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
    pub rejected: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(!cfg.buckets.is_empty());
        let mut cfg = cfg;
        cfg.buckets.sort_unstable();
        Batcher {
            cfg,
            queue: VecDeque::new(),
            rejected: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit a request; Err(payload) when the queue is full (backpressure).
    pub fn push(&mut self, payload: T) -> Result<(), T> {
        if self.queue.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return Err(payload);
        }
        self.queue.push_back(Pending {
            payload,
            enqueued: Instant::now(),
        });
        Ok(())
    }

    fn max_bucket(&self) -> usize {
        *self.cfg.buckets.last().unwrap()
    }

    /// Bucket that fits `n` requests best (smallest bucket >= n, else max).
    pub fn bucket_for(&self, n: usize) -> usize {
        *self
            .cfg
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.cfg.buckets.last().unwrap())
    }

    /// Pop the next group to run, or None to keep waiting.
    ///
    /// Policy: run when a full max-bucket is queued; otherwise run
    /// whatever is queued once the oldest request exceeded max_wait.
    pub fn next_group(&mut self, now: Instant) -> Option<Vec<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.max_bucket();
        let stale = now.duration_since(self.queue[0].enqueued) >= self.cfg.max_wait;
        if !full && !stale {
            return None;
        }
        let n = self.queue.len().min(self.max_bucket());
        Some(self.queue.drain(..n).map(|p| p.payload).collect())
    }

    /// Pop up to `n` queued requests immediately, bypassing the group
    /// policy — the continuous-batching join path: a free slot in a
    /// running group should never idle while requests wait.
    pub fn take(&mut self, n: usize) -> Vec<T> {
        let n = n.min(self.queue.len());
        self.queue.drain(..n).map(|p| p.payload).collect()
    }

    /// Return a request to the FRONT of the queue (it stays next in
    /// line). Used when a popped group exceeds the engine's bucket
    /// capacity and the tail must wait for the next group.
    pub fn requeue_front(&mut self, payload: T) {
        self.queue.push_front(Pending {
            payload,
            enqueued: Instant::now(),
        });
    }

    /// `requeue_front` preserving the request's ORIGINAL enqueue stamp,
    /// so a request bounced back by the scheduler (bucket-capacity tail,
    /// paged-KV load shed) keeps accruing queue age toward the
    /// `max_wait` staleness flush instead of being reset to fresh.
    pub fn requeue_front_at(&mut self, payload: T, enqueued: Instant) {
        self.queue.push_front(Pending { payload, enqueued });
    }

    /// Remove every queued request matching `pred`, returning the
    /// removed payloads in queue order. Survivors keep their position
    /// AND their original enqueue stamps (their queue age keeps
    /// accruing). This is the deadline/cancel shed path: an expired or
    /// cancelled request leaves the queue before any group formation or
    /// paged-KV reservation is spent on it.
    pub fn drain_where(&mut self, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut removed = Vec::new();
        for p in std::mem::take(&mut self.queue) {
            if pred(&p.payload) {
                removed.push(p.payload);
            } else {
                self.queue.push_back(p);
            }
        }
        removed
    }

    /// Pop up to one max-bucket of queued requests immediately,
    /// ignoring the full/stale policy — the graceful-drain path: a
    /// draining scheduler flushes the work it already accepted instead
    /// of waiting out `max_wait` for stragglers that will never arrive.
    pub fn flush_group(&mut self) -> Option<Vec<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_bucket());
        Some(self.queue.drain(..n).map(|p| p.payload).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: 4,
        }
    }

    #[test]
    fn full_bucket_dispatches_immediately() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..4 {
            b.push(i).unwrap();
        }
        let g = b.next_group(Instant::now()).unwrap();
        assert_eq!(g, vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_waits_then_flushes() {
        let mut b = Batcher::new(cfg(0)); // max_wait = 0 -> immediate
        b.push(7).unwrap();
        let g = b.next_group(Instant::now()).unwrap();
        assert_eq!(g, vec![7]);

        let mut b = Batcher::new(cfg(10_000));
        b.push(7).unwrap();
        assert!(b.next_group(Instant::now()).is_none(), "should wait");
    }

    #[test]
    fn backpressure_rejects_over_cap() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..4 {
            b.push(i).unwrap();
        }
        assert_eq!(b.push(99), Err(99));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn bucket_selection() {
        let b: Batcher<u32> = Batcher::new(cfg(0));
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(2), 4);
        assert_eq!(b.bucket_for(4), 4);
        assert_eq!(b.bucket_for(9), 4);
    }

    #[test]
    fn take_bypasses_wait_policy_and_preserves_order() {
        let mut b = Batcher::new(cfg(10_000)); // long max_wait
        for i in 0..3 {
            b.push(i).unwrap();
        }
        // The group policy would wait (partial bucket, not stale) …
        assert!(b.next_group(Instant::now()).is_none());
        // … but take() hands requests over immediately, FIFO.
        assert_eq!(b.take(2), vec![0, 1]);
        assert_eq!(b.len(), 1);
        // Over-asking is clamped to what is queued.
        assert_eq!(b.take(10), vec![2]);
        assert!(b.take(5).is_empty());
    }

    #[test]
    fn max_wait_flushes_partial_bucket() {
        let mut b = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_millis(1),
            queue_cap: 8,
        });
        let before = Instant::now();
        b.push(42).unwrap();
        // Not stale at the enqueue instant (clamped duration_since = 0) …
        assert!(b.next_group(before).is_none());
        // … but definitely stale past max_wait.
        let later = Instant::now() + Duration::from_millis(5);
        assert_eq!(b.next_group(later), Some(vec![42]));
    }

    #[test]
    fn requeue_front_at_preserves_queue_age() {
        let mut b = Batcher::new(BatcherConfig {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            queue_cap: 8,
        });
        let old = Instant::now() - Duration::from_millis(50);
        // A bounced request with its original (stale) stamp flushes
        // immediately; a plain requeue would have reset its age.
        b.requeue_front_at(7, old);
        assert_eq!(b.next_group(Instant::now()), Some(vec![7]));
        b.requeue_front(8); // fresh stamp -> must wait again
        assert!(b.next_group(Instant::now()).is_none());
    }

    #[test]
    fn drain_where_sheds_matches_and_keeps_survivor_age() {
        let mut b = Batcher::new(BatcherConfig {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            queue_cap: 8,
        });
        let old = Instant::now() - Duration::from_millis(50);
        b.requeue_front_at(3, old); // stale survivor
        b.requeue_front_at(2, old); // stale shed target
        b.requeue_front_at(1, old); // stale survivor
        // Shed the "expired" request only; order of the rest holds.
        assert_eq!(b.drain_where(|&x| x == 2), vec![2]);
        assert_eq!(b.len(), 2);
        // Survivors kept their stale stamps: they flush immediately
        // instead of waiting out max_wait again.
        assert_eq!(b.next_group(Instant::now()), Some(vec![1, 3]));
        // Nothing queued -> nothing shed.
        assert!(b.drain_where(|_| true).is_empty());
    }

    #[test]
    fn flush_group_bypasses_wait_policy() {
        let mut b = Batcher::new(cfg(10_000)); // long max_wait
        for i in 0..5 {
            if i < 4 {
                b.push(i).unwrap();
            } else {
                b.requeue_front_at(i, Instant::now()); // over cap via requeue
            }
        }
        // The group policy would dispatch a full bucket, so drop to a
        // partial queue first.
        assert_eq!(b.flush_group(), Some(vec![4, 0, 1, 2]));
        // Partial + not stale: next_group waits, flush does not.
        assert!(b.next_group(Instant::now()).is_none());
        assert_eq!(b.flush_group(), Some(vec![3]));
        assert_eq!(b.flush_group(), None);
    }

    #[test]
    fn requeue_front_keeps_fifo_position() {
        let mut b = Batcher::new(cfg(0));
        b.push(1).unwrap();
        b.push(2).unwrap();
        let popped = b.take(2);
        assert_eq!(popped, vec![1, 2]);
        // Returning 2 then 1 (reverse pop order) restores 1, 2, ...
        b.requeue_front(2);
        b.requeue_front(1);
        b.push(3).unwrap();
        assert_eq!(b.take(3), vec![1, 2, 3]);
    }
}
