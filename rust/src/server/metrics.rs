//! Engine + scheduler metrics: counters and latency distributions, with
//! a Prometheus-style text exposition for scraping/debugging.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::{OnlineStats, Percentiles};

use super::engine::RequestResult;

/// Per-engine request counters (both the lockstep and scheduled paths
/// feed these through `observe_request`).
#[derive(Default)]
pub struct EngineMetrics {
    pub requests: u64,
    pub tokens_out: u64,
    pub rounds: u64,
    pub drafted: u64,
    pub accepted: u64,
    pub latency_ms: Percentiles,
    pub ttft_ms: Percentiles,
    pub queue_ms: Percentiles,
    pub tau: OnlineStats,
    /// Which verify implementation this engine resolved to
    /// ("device" | "host"; empty before an engine stamps it).
    pub verify_path: &'static str,
    /// Decode rounds executed (once per group round, unlike `rounds`
    /// which sums per-request participation).
    pub decode_rounds: u64,
    /// Bytes materialized host-side via `output_host` during decode
    /// rounds (Runtime::d2h_bytes_total deltas) — the transfer the
    /// device-resident verify eliminates.
    pub bytes_to_host: u64,
    /// Histogram of per-row accepted lengths per round: chain prefix
    /// lengths, or accepted PATH lengths for tree rounds (index =
    /// length; grown on demand).
    pub path_len_hist: Vec<u64>,
    /// Candidate slots drafted across live row-rounds (K per chain
    /// round, N tree nodes per tree round) — with `row_rounds` this is
    /// the nodes-per-round gauge separating tree cost from chain cost.
    pub nodes_drafted: u64,
    /// Live (non-padding) row-rounds observed.
    pub row_rounds: u64,
    /// Speculation-controller telemetry: the budget chosen for the most
    /// recent round (chain k, or tree depth) …
    pub adaptive_k_last: u64,
    /// … its distribution across rounds …
    pub adaptive_k: OnlineStats,
    /// … candidate slots the round spent (== k for chains, tree nodes
    /// for planned topologies) …
    pub adaptive_slots: OnlineStats,
    /// … and the controller's latest per-position alpha_hat estimates.
    pub alpha_hat: Vec<f64>,
    /// Cross-bucket KV migrations executed (downshift + upshift).
    pub migrations: u64,
    /// KV bytes those migrations moved through the host — 0 on the
    /// device gather path; the gauge exists to PROVE it stays 0.
    pub migration_host_kv_bytes: u64,
    /// Failed verify/gather execute attempts (each retried in place
    /// before the round reports a transient fault; DESIGN.md §9).
    pub transient_faults: u64,
    /// Times the engine fell back from the fused device verify path to
    /// host verify after exhausting execute retries.
    pub verify_degrades: u64,
}

impl EngineMetrics {
    pub fn observe_request(&mut self, r: &RequestResult) {
        self.requests += 1;
        self.tokens_out += r.tokens.len() as u64;
        self.rounds += r.rounds;
        self.drafted += r.stats.drafted.iter().sum::<u64>();
        self.accepted += r.stats.accepted.iter().sum::<u64>();
        self.latency_ms.push(r.latency_ms);
        self.ttft_ms.push(r.ttft_ms);
        self.queue_ms.push(r.queue_ms);
        self.tau.push(r.stats.tau());
    }

    pub fn acceptance_ratio(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean device→host bytes per decode round (steady-state transfer).
    pub fn bytes_to_host_per_round(&self) -> f64 {
        if self.decode_rounds == 0 {
            0.0
        } else {
            self.bytes_to_host as f64 / self.decode_rounds as f64
        }
    }

    /// Record one live row's round shape: `n_slots` candidates drafted
    /// (chain K or tree nodes), `accepted` the accepted prefix/path
    /// length.
    pub fn observe_round_row(&mut self, n_slots: usize, accepted: usize) {
        if self.path_len_hist.len() <= accepted {
            self.path_len_hist.resize(accepted + 1, 0);
        }
        self.path_len_hist[accepted] += 1;
        self.nodes_drafted += n_slots as u64;
        self.row_rounds += 1;
    }

    /// Record the speculation controller's choice for one round: the
    /// budget depth (chain k / tree depth), the candidate slots spent,
    /// and a snapshot of the per-position acceptance estimates.
    pub fn observe_controller(&mut self, depth: usize, slots: usize, alpha: &[f64]) {
        self.adaptive_k_last = depth as u64;
        self.adaptive_k.push(depth as f64);
        self.adaptive_slots.push(slots as f64);
        self.alpha_hat.clear();
        self.alpha_hat.extend_from_slice(alpha);
    }

    /// Record one cross-bucket migration's host-side KV traffic (the
    /// device `kv_gather_rows` path reports 0 bytes).
    pub fn observe_migration_host_kv_bytes(&mut self, bytes: u64) {
        self.migrations += 1;
        self.migration_host_kv_bytes += bytes;
    }

    /// Mean host-side KV bytes per cross-bucket migration.
    pub fn host_kv_bytes_per_migration(&self) -> f64 {
        if self.migrations == 0 {
            0.0
        } else {
            self.migration_host_kv_bytes as f64 / self.migrations as f64
        }
    }

    /// Mean candidate slots drafted per live row-round.
    pub fn nodes_per_round(&self) -> f64 {
        if self.row_rounds == 0 {
            0.0
        } else {
            self.nodes_drafted as f64 / self.row_rounds as f64
        }
    }

    /// Mean accepted prefix/path length per live row-round.
    pub fn mean_accepted_len(&self) -> f64 {
        if self.row_rounds == 0 {
            return 0.0;
        }
        let total: u64 = self
            .path_len_hist
            .iter()
            .enumerate()
            .map(|(len, &c)| len as u64 * c)
            .sum();
        total as f64 / self.row_rounds as f64
    }

    /// Prometheus-style text block.
    pub fn render(&mut self, engine: &str) -> String {
        let mut out = String::new();
        let path = if self.verify_path.is_empty() {
            "host"
        } else {
            self.verify_path
        };
        out.push_str(&format!(
            "lkspec_verify_path{{engine=\"{engine}\",path=\"{path}\"}} 1\n"
        ));
        let mut line = |name: &str, v: f64| {
            out.push_str(&format!("lkspec_{name}{{engine=\"{engine}\"}} {v}\n"));
        };
        line("requests_total", self.requests as f64);
        line("tokens_out_total", self.tokens_out as f64);
        line("rounds_total", self.rounds as f64);
        line("decode_rounds_total", self.decode_rounds as f64);
        line("drafted_total", self.drafted as f64);
        line("accepted_total", self.accepted as f64);
        line("acceptance_ratio", self.acceptance_ratio());
        line("tau_mean", self.tau.mean());
        line("bytes_to_host_total", self.bytes_to_host as f64);
        line("bytes_to_host_per_round", self.bytes_to_host_per_round());
        line("transient_faults_total", self.transient_faults as f64);
        line("verify_degrades_total", self.verify_degrades as f64);
        line("nodes_per_round", self.nodes_per_round());
        line("accepted_len_mean", self.mean_accepted_len());
        if self.migrations > 0 {
            line("migrations_total", self.migrations as f64);
            line(
                "kv_host_bytes_per_migration",
                self.host_kv_bytes_per_migration(),
            );
        }
        if self.adaptive_k.n > 0 {
            line("adaptive_k_last", self.adaptive_k_last as f64);
            line("adaptive_k_mean", self.adaptive_k.mean());
            line("adaptive_slots_mean", self.adaptive_slots.mean());
        }
        if !self.latency_ms.is_empty() {
            line("latency_ms_p50", self.latency_ms.pct(50.0));
            line("latency_ms_p95", self.latency_ms.pct(95.0));
            line("latency_ms_p99", self.latency_ms.pct(99.0));
        }
        if !self.ttft_ms.is_empty() {
            line("ttft_ms_p50", self.ttft_ms.pct(50.0));
            line("ttft_ms_p95", self.ttft_ms.pct(95.0));
        }
        if !self.queue_ms.is_empty() {
            line("queue_ms_p50", self.queue_ms.pct(50.0));
            line("queue_ms_p95", self.queue_ms.pct(95.0));
        }
        for (len, &count) in self.path_len_hist.iter().enumerate() {
            out.push_str(&format!(
                "lkspec_accepted_len_rounds{{engine=\"{engine}\",len=\"{len}\"}} {count}\n"
            ));
        }
        for (pos, &a) in self.alpha_hat.iter().enumerate() {
            out.push_str(&format!(
                "lkspec_alpha_hat{{engine=\"{engine}\",pos=\"{pos}\"}} {a}\n"
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// analytic steady-state transfer model (bench + tests)
// ---------------------------------------------------------------------------
//
// Closed forms for the device→host bytes one decode round materializes
// on each verify path; `benches/engine_hotpath.rs` renders these against
// the manifest dims and the live `bytes_to_host_per_round` counter.

/// Host path, target side: the full [B, Vt, V] logits plus [B, Vt, 3d]
/// features pulled for host softmax/acceptance and hidden pickup.
pub fn host_verify_bytes_per_round(b: usize, vt: usize, vocab: usize, feat_dim: usize) -> u64 {
    (b * vt * (vocab + feat_dim) * 4) as u64
}

/// Host path, draft side: what host-side sampling forces down per round
/// (per architecture; `draft_vocab` < `vocab` only for truncated-vocab
/// drafts).
pub fn host_draft_bytes_per_round(
    arch: &str,
    b: usize,
    k: usize,
    vocab: usize,
    draft_vocab: usize,
    d_model: usize,
    vt: usize,
) -> u64 {
    let f = 4usize;
    (match arch {
        // (k-1) chained step pulls + the extend's [B, Vt, Vd] q-logits
        // and [B, Vt, d] hidden planes.
        "eagle3" | "mtp" | "recurrent" => {
            (k.saturating_sub(1)) * b * draft_vocab * f
                + b * vt * draft_vocab * f
                + b * vt * d_model * f
        }
        // one [K, B, V] head-logits pull
        "medusa" => k * b * vocab * f,
        // k chained [B, V] logits pulls
        "mlp" => k * b * vocab * f,
        _ => 0,
    }) as u64
}

/// Device path: n_accepted `[B]` + emitted tokens `[B, Vt]` + the
/// drafted token ids the backends read back (O(B·K) i32 — nothing
/// scales with the vocabulary).
pub fn device_bytes_per_round(b: usize, k: usize, vt: usize) -> u64 {
    ((b + b * vt + b * k) * 4) as u64
}

/// Tree host path: the full [B, Vt, V] logits + [B, Vt, 3d] features
/// pulled for the host rejection walk, plus the parallel-head propose
/// pull ([K, B, V] — one pass feeds every node).
pub fn tree_host_bytes_per_round(
    b: usize,
    vt: usize,
    vocab: usize,
    feat_dim: usize,
    k_heads: usize,
) -> u64 {
    host_verify_bytes_per_round(b, vt, vocab, feat_dim) + (k_heads * b * vocab * 4) as u64
}

/// Tree device path (stateless backends): n_path `[B]` + candidate ids
/// `[B, N]` + emitted tokens `[B, Vt]` — O(B·N) i32 per round; the
/// per-node q tensors, the path splice and the conditioning hidden
/// stay in-graph.
pub fn tree_device_bytes_per_round(b: usize, n_nodes: usize, vt: usize) -> u64 {
    ((b + b * n_nodes + b * vt) * 4) as u64
}

/// Recurrent (EAGLE-3/MTP) tree host path: the target tree pull, one
/// `[B, Vt-1, Vd]` q-logits pull per expansion level past the first
/// (level 0 samples from the extend-produced q1 — no extra transfer),
/// plus the advance's `extend_k` pulls (`[B, Vt, Vd]` q-logits and
/// `[B, Vt, d]` hidden planes — the same pulls the chain path's
/// `host_draft_bytes_per_round` counts).
pub fn recurrent_tree_host_bytes_per_round(
    b: usize,
    vt: usize,
    vocab: usize,
    feat_dim: usize,
    depth: usize,
    draft_vocab: usize,
    d_model: usize,
) -> u64 {
    host_verify_bytes_per_round(b, vt, vocab, feat_dim)
        + (depth.saturating_sub(1) * b * (vt - 1) * draft_vocab * 4) as u64
        + (b * vt * (draft_vocab + d_model) * 4) as u64
}

/// Recurrent tree device path: the stateless tree verdict ints plus the
/// accepted-path node indices `[B, Vt-1]` (the draft-splice map — the
/// engine pulls them only for stateful backends) and the advance's
/// in-graph-sampled first draft (`[B]` ids from `extend_tree_sample`) —
/// still nothing scaling with the vocabulary.
pub fn recurrent_tree_device_bytes_per_round(b: usize, n_nodes: usize, vt: usize) -> u64 {
    tree_device_bytes_per_round(b, n_nodes, vt) + (b * (vt - 1) * 4) as u64 + (b * 4) as u64
}

/// Closed form for what a HOST-repacked cross-bucket migration moves:
/// the full source KV down (`from_literal`) plus the full repacked
/// destination back up (`to_literal`), target cache
/// `[L, 2, B, H, Smax, Dh]` f32. The recurrent draft twin
/// `[2, B, H, Smax, Dh]` adds its own pair when `with_draft`. This is
/// the traffic the `kv_gather_rows_b{Bsrc}x{Bdst}` entries delete —
/// the live counterpart (`EngineMetrics::migration_host_kv_bytes`) must
/// read 0 on the device path.
pub fn migration_host_kv_bytes_host_repack(
    n_layers: usize,
    b_src: usize,
    b_dst: usize,
    heads: usize,
    max_seq: usize,
    head_dim: usize,
    with_draft: bool,
) -> u64 {
    let row = heads * max_seq * head_dim * 4;
    let target = n_layers * 2 * (b_src + b_dst) * row;
    let draft = if with_draft { 2 * (b_src + b_dst) * row } else { 0 };
    (target + draft) as u64
}

/// Device gather path: the only host traffic is the `[B_dst]` i32 row
/// map — zero KV bytes.
pub const fn migration_host_kv_bytes_device() -> u64 {
    0
}

/// Scheduler-level serving metrics: occupancy, queue waits, throughput
/// and the join/leave churn of continuous batching.
#[derive(Default)]
pub struct SchedulerMetrics {
    /// Sessions completed (results handed back).
    pub sessions: u64,
    /// Sessions admitted into groups (bootstrap + joins).
    pub sessions_admitted: u64,
    pub tokens_out: u64,
    /// Decode rounds executed across all groups.
    pub rounds: u64,
    pub groups_formed: u64,
    pub groups_retired: u64,
    /// Mid-flight admissions into a running group.
    pub joins: u64,
    /// Long-tail groups migrated to a smaller bucket.
    pub downshifts: u64,
    /// Shrunk groups re-grown because arrivals queued behind a full
    /// bucket (the downshift's mirror).
    pub upshifts: u64,
    /// Per-SAMPLE occupancy distribution: occupied/capacity once per
    /// round, plus one 0.0 sample per idle tick with requests pending.
    /// Diagnostic only — its mean depends on the driver's tick cadence;
    /// `occupancy_time_mean` is the load gauge.
    pub slot_occupancy: OnlineStats,
    /// Time-weighted occupancy accumulators (poll-frequency-invariant:
    /// each sample is weighted by the wall time since the previous one).
    occ_weighted_secs: f64,
    occ_secs: f64,
    last_occ_at: Option<Instant>,
    /// Ticks with requests queued but no group decoding (the batcher
    /// holding out for a fuller bucket).
    pub idle_ticks: u64,
    /// Row-rounds decoded by live sessions vs burned as padding —
    /// padding is the compute the long-tail downshift reclaims.
    pub live_row_rounds: u64,
    pub padded_row_rounds: u64,
    pub queue_wait_ms: Percentiles,
    pub ttft_ms: Percentiles,
    pub latency_ms: Percentiles,
    started: Option<Instant>,
    /// Paged-KV gauges, refreshed from `kv::PagedKv` every tick (0 when
    /// the scheduler runs without a block pool).
    pub kv_blocks_live: u64,
    pub kv_blocks_free: u64,
    /// Prefix-cache hit rate over admitted prompt tokens.
    pub prefix_hit_rate: f64,
    /// Admissions load-shed because the pool could not reserve the
    /// session's worst-case block footprint.
    pub kv_sheds: u64,
    /// Holder-free prefix blocks reclaimed by LRU eviction.
    pub kv_evictions: u64,
    /// Prompt tokens whose prefill COMPUTE actually ran vs tokens whose
    /// compute was skipped entirely. Under chunked prefill a radix
    /// prefix hit skips the cached chunks' XLA compute (DESIGN.md §11),
    /// so `saved` counts real FLOPs avoided; whole-prompt prefill
    /// computes every position regardless of cache residency, so it
    /// counts the full prompt into `prefill_tokens` and saves nothing
    /// (block sharing still shows in `prefix_hit_rate`).
    pub prefill_tokens: u64,
    pub prefill_tokens_saved: u64,
    /// Prefill chunks executed by the chunked-prefill lane …
    pub prefill_chunks: u64,
    /// … and scheduler ticks in which the lane ran at least one chunk
    /// between decode rounds.
    pub prefill_lane_rounds: u64,
    /// Fault containment (DESIGN.md §9): transient round retries …
    pub transient_retries: u64,
    /// … sessions evicted by session-fatal faults (bootstrap cohorts
    /// and failed joins included) …
    pub session_faults: u64,
    /// … and full engine resets after an engine-fatal fault.
    pub engine_resets: u64,
    /// Requests shed for a missed deadline while still QUEUED (no
    /// prefill or block reservation was spent on them) …
    pub deadline_expired_queued: u64,
    /// … and mid-flight (row evicted, slot + KV blocks released).
    pub deadline_expired_inflight: u64,
    /// Sessions cancelled via the cancel handle (queued or mid-flight).
    pub cancelled: u64,
    /// Graceful-drain state gauge (true while draining).
    pub draining: bool,
}

impl SchedulerMetrics {
    /// Mark serving start (first admission); anchors the tok/s gauge.
    pub fn note_started(&mut self) {
        self.started.get_or_insert_with(Instant::now);
    }

    /// Record one occupancy observation at time `at` (a decode round's
    /// occupied/capacity, or 0.0 for an idle tick with requests
    /// pending). Feeds both the per-sample distribution and the
    /// time-weighted mean — the latter weights each observation by the
    /// wall time since the previous one, so it does not depend on how
    /// often the driver polls `tick()`.
    pub fn observe_occupancy(&mut self, occ: f64, at: Instant) {
        if let Some(prev) = self.last_occ_at.replace(at) {
            let dt = at.saturating_duration_since(prev).as_secs_f64();
            self.occ_weighted_secs += occ * dt;
            self.occ_secs += dt;
        }
        self.slot_occupancy.push(occ);
    }

    /// Time-weighted mean occupancy (poll-frequency-invariant). Falls
    /// back to the per-sample mean before any wall time has elapsed.
    pub fn occupancy_time_mean(&self) -> f64 {
        if self.occ_secs > 0.0 {
            self.occ_weighted_secs / self.occ_secs
        } else {
            self.slot_occupancy.mean()
        }
    }

    pub fn observe_session(&mut self, r: &RequestResult) {
        self.sessions += 1;
        self.tokens_out += r.tokens.len() as u64;
        self.queue_wait_ms.push(r.queue_ms);
        self.ttft_ms.push(r.ttft_ms);
        self.latency_ms.push(r.latency_ms);
    }

    /// Aggregate decode throughput since the first admission.
    pub fn tokens_per_second(&self) -> f64 {
        match self.started {
            None => 0.0,
            Some(t0) => {
                let secs = t0.elapsed().as_secs_f64();
                if secs <= 0.0 {
                    0.0
                } else {
                    self.tokens_out as f64 / secs
                }
            }
        }
    }

    /// Prometheus-style text block (lkspec_sched_* namespace).
    pub fn render(&mut self, engine: &str) -> String {
        let mut out = String::new();
        let tps = self.tokens_per_second();
        let mut line = |name: &str, v: f64| {
            out.push_str(&format!("lkspec_sched_{name}{{engine=\"{engine}\"}} {v}\n"));
        };
        line("sessions_total", self.sessions as f64);
        line("sessions_admitted_total", self.sessions_admitted as f64);
        line("tokens_out_total", self.tokens_out as f64);
        line("rounds_total", self.rounds as f64);
        line("groups_formed_total", self.groups_formed as f64);
        line("groups_retired_total", self.groups_retired as f64);
        line("joins_total", self.joins as f64);
        line("downshifts_total", self.downshifts as f64);
        line("upshifts_total", self.upshifts as f64);
        line("slot_occupancy_mean", self.slot_occupancy.mean());
        line("slot_occupancy_time_mean", self.occupancy_time_mean());
        line("idle_ticks_total", self.idle_ticks as f64);
        line("live_row_rounds_total", self.live_row_rounds as f64);
        line("padded_row_rounds_total", self.padded_row_rounds as f64);
        line("tokens_per_second", tps);
        line("kv_sheds_total", self.kv_sheds as f64);
        line("kv_evictions_total", self.kv_evictions as f64);
        line("transient_retries_total", self.transient_retries as f64);
        line("session_faults_total", self.session_faults as f64);
        line("engine_resets_total", self.engine_resets as f64);
        line(
            "deadline_expired_queued",
            self.deadline_expired_queued as f64,
        );
        line(
            "deadline_expired_inflight",
            self.deadline_expired_inflight as f64,
        );
        line("cancelled_total", self.cancelled as f64);
        line("draining", if self.draining { 1.0 } else { 0.0 });
        line("prefill_tokens_total", self.prefill_tokens as f64);
        line(
            "prefill_tokens_saved_total",
            self.prefill_tokens_saved as f64,
        );
        line("prefill_chunks_total", self.prefill_chunks as f64);
        line("prefill_lane_rounds", self.prefill_lane_rounds as f64);
        if !self.queue_wait_ms.is_empty() {
            line("queue_wait_ms_p50", self.queue_wait_ms.pct(50.0));
            line("queue_wait_ms_p95", self.queue_wait_ms.pct(95.0));
        }
        if !self.ttft_ms.is_empty() {
            line("ttft_ms_p50", self.ttft_ms.pct(50.0));
            line("ttft_ms_p95", self.ttft_ms.pct(95.0));
        }
        if !self.latency_ms.is_empty() {
            line("latency_ms_p50", self.latency_ms.pct(50.0));
            line("latency_ms_p95", self.latency_ms.pct(95.0));
        }
        // Paged-KV capacity gauges live in the plain lkspec_ namespace
        // (they describe the device cache, not the scheduling policy).
        out.push_str(&format!(
            "lkspec_kv_blocks_live{{engine=\"{engine}\"}} {}\n",
            self.kv_blocks_live
        ));
        out.push_str(&format!(
            "lkspec_kv_blocks_free{{engine=\"{engine}\"}} {}\n",
            self.kv_blocks_free
        ));
        out.push_str(&format!(
            "lkspec_prefix_hit_rate{{engine=\"{engine}\"}} {}\n",
            self.prefix_hit_rate
        ));
        out
    }
}

/// HTTP edge metrics (`lkspec_http_*` namespace, documented in
/// docs/METRICS.md). Unlike the scheduler metrics — owned by the single
/// worker thread — these are bumped from per-connection threads, so the
/// hot-path counters are lock-free atomics and only the stream-latency
/// distributions (one observation per SSE token event) sit behind a
/// mutex.
#[derive(Default)]
pub struct HttpMetrics {
    /// Open connections right now (gauge).
    pub conns: AtomicU64,
    pub conns_total: AtomicU64,
    /// Accepted generate requests still awaiting their terminal event
    /// (the edge's view of in-flight + queued work).
    pub queue_depth: AtomicU64,
    /// Requests refused at the edge: max-conns 503s plus every
    /// admission verdict served as a status code (429 queue-full, 413
    /// oversized, 400 invalid, 503 draining).
    pub sheds: AtomicU64,
    /// Client disconnects observed mid-stream; each one cancels its
    /// session through the router so the slot frees.
    pub disconnects: AtomicU64,
    pub requests_total: AtomicU64,
    lat: Mutex<HttpLatency>,
}

#[derive(Default)]
struct HttpLatency {
    ttft_ms: Percentiles,
    inter_token_ms: Percentiles,
}

impl HttpMetrics {
    /// Record one stream's time-to-first-token (request parsed → first
    /// `token` event on the wire).
    pub fn observe_ttft(&self, ms: f64) {
        if let Ok(mut l) = self.lat.lock() {
            l.ttft_ms.push(ms);
        }
    }

    /// Record the gap between consecutive `token` events of one stream.
    pub fn observe_inter_token(&self, ms: f64) {
        if let Ok(mut l) = self.lat.lock() {
            l.inter_token_ms.push(ms);
        }
    }

    /// Prometheus-style text block (lkspec_http_* namespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut line = |name: &str, v: f64| {
            out.push_str(&format!("lkspec_http_{name} {v}\n"));
        };
        line("conns", self.conns.load(Ordering::Relaxed) as f64);
        line("conns_total", self.conns_total.load(Ordering::Relaxed) as f64);
        line("queue_depth", self.queue_depth.load(Ordering::Relaxed) as f64);
        line("sheds_total", self.sheds.load(Ordering::Relaxed) as f64);
        line(
            "disconnects_total",
            self.disconnects.load(Ordering::Relaxed) as f64,
        );
        line(
            "requests_total",
            self.requests_total.load(Ordering::Relaxed) as f64,
        );
        if let Ok(mut l) = self.lat.lock() {
            if !l.ttft_ms.is_empty() {
                line("stream_ttft_ms_p50", l.ttft_ms.pct(50.0));
                line("stream_ttft_ms_p95", l.ttft_ms.pct(95.0));
            }
            if !l.inter_token_ms.is_empty() {
                line("inter_token_ms_p50", l.inter_token_ms.pct(50.0));
                line("inter_token_ms_p95", l.inter_token_ms.pct(95.0));
            }
        }
        out
    }
}

/// Adaptation-loop gauges (`lkspec_adapt_*` namespace, DESIGN.md §12).
/// Owned by the scheduler's `AdaptDriver` and refreshed once per tick,
/// so plain fields suffice (single worker thread, like
/// [`SchedulerMetrics`]).
#[derive(Default, Clone, Debug)]
pub struct AdaptMetrics {
    /// Replay-ring depth right now (records held).
    pub buffer_depth: u64,
    /// Records evicted FIFO when the ring was full.
    pub buffer_evicted_total: u64,
    /// Records ever harvested from decode verdicts.
    pub records_harvested_total: u64,
    /// Trainer lifecycle gauge: 0 idle, 1 running, 2 last run swapped,
    /// 3 last run faulted.
    pub trainer_state: u64,
    /// Fine-tune subprocess launches.
    pub trainer_runs_total: u64,
    /// Typed trainer faults (crash / hang / malformed / rollback) — all
    /// transient by contract; serving continued on stale weights.
    pub trainer_faults_total: u64,
    /// Draft hot-swaps committed at a round boundary.
    pub swaps_total: u64,
    /// Fine-tunes whose checkpoint failed validate-then-commit (old
    /// weights kept serving).
    pub swap_rollbacks_total: u64,
    /// Empirical acceptance over the ring before the last fine-tune …
    pub alpha_hat_pre: f64,
    /// … and over records harvested after the last committed swap.
    pub alpha_hat_post: f64,
}

impl AdaptMetrics {
    /// Prometheus-style text block (lkspec_adapt_* namespace).
    pub fn render(&self, engine: &str) -> String {
        let mut out = String::new();
        let mut line = |name: &str, v: f64| {
            out.push_str(&format!("lkspec_adapt_{name}{{engine=\"{engine}\"}} {v}\n"));
        };
        line("buffer_depth", self.buffer_depth as f64);
        line("buffer_evicted_total", self.buffer_evicted_total as f64);
        line(
            "records_harvested_total",
            self.records_harvested_total as f64,
        );
        line("trainer_state", self.trainer_state as f64);
        line("trainer_runs_total", self.trainer_runs_total as f64);
        line("trainer_faults_total", self.trainer_faults_total as f64);
        line("swaps_total", self.swaps_total as f64);
        line("swap_rollbacks_total", self.swap_rollbacks_total as f64);
        line("alpha_hat_pre", self.alpha_hat_pre);
        line("alpha_hat_post", self.alpha_hat_post);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::accept::AcceptanceStats;

    fn result(latency_ms: f64, ttft_ms: f64, queue_ms: f64) -> RequestResult {
        let mut stats = AcceptanceStats::new(4);
        stats.record_round(4, 3);
        RequestResult {
            tokens: vec![1, 2, 3, 4],
            stats,
            latency_ms,
            ttft_ms,
            queue_ms,
            rounds: 1,
        }
    }

    #[test]
    fn observe_and_render() {
        let mut m = EngineMetrics::default();
        m.observe_request(&result(12.5, 4.0, 1.0));
        assert_eq!(m.requests, 1);
        assert_eq!(m.tokens_out, 4);
        assert_eq!(m.accepted, 3);
        assert!((m.acceptance_ratio() - 0.75).abs() < 1e-12);
        let text = m.render("test");
        assert!(text.contains("lkspec_requests_total{engine=\"test\"} 1"));
        assert!(text.contains("latency_ms_p50"));
        assert!(text.contains("ttft_ms_p50"));
    }

    #[test]
    fn transfer_counters_and_path_gauge() {
        let mut m = EngineMetrics {
            verify_path: "device",
            ..Default::default()
        };
        m.decode_rounds = 4;
        m.bytes_to_host = 4 * 256;
        assert!((m.bytes_to_host_per_round() - 256.0).abs() < 1e-12);
        let text = m.render("e");
        assert!(text.contains("lkspec_verify_path{engine=\"e\",path=\"device\"} 1"));
        assert!(text.contains("lkspec_bytes_to_host_per_round{engine=\"e\"} 256"));
        assert!(text.contains("lkspec_decode_rounds_total{engine=\"e\"} 4"));
        // unset path renders as the host fallback
        let mut m2 = EngineMetrics::default();
        assert!(m2.render("e").contains("path=\"host\""));
    }

    /// The whole point of the device verify path: per-round host traffic
    /// stops scaling with the vocabulary. At the manifest's own dims the
    /// reduction is >50× for every draft architecture.
    #[test]
    fn device_transfer_orders_of_magnitude_below_host() {
        let (vt, vocab, vd, d, f3) = (8usize, 512usize, 320usize, 96usize, 288usize);
        for (arch, k) in [("eagle3", 7usize), ("medusa", 6), ("mlp", 6)] {
            for b in [1usize, 4] {
                let host = host_verify_bytes_per_round(b, vt, vocab, f3)
                    + host_draft_bytes_per_round(arch, b, k, vocab, vd, d, vt);
                let dev = device_bytes_per_round(b, k, vt);
                assert!(
                    dev * 50 < host,
                    "{arch} b={b}: device {dev} not <50x below host {host}"
                );
                // device side is pure O(B·K) ints
                assert_eq!(dev, ((b + b * vt + b * k) * 4) as u64);
            }
        }
    }

    /// Tree rounds keep the device-path property: per-round host
    /// traffic is O(B·N) ints, independent of the vocabulary — for the
    /// parallel-head AND the recurrent tree backends.
    #[test]
    fn tree_transfer_closed_forms() {
        let (vt, vocab, vd, d, f3, kh) = (8usize, 512usize, 320usize, 96usize, 288usize, 6usize);
        for b in [1usize, 4] {
            let n = 6; // the 2x2 default tree
            let host = tree_host_bytes_per_round(b, vt, vocab, f3, kh);
            let dev = tree_device_bytes_per_round(b, n, vt);
            assert_eq!(dev, ((b + b * n + b * vt) * 4) as u64);
            assert!(
                dev * 50 < host,
                "b={b}: tree device {dev} not <50x below host {host}"
            );
            // recurrent tree: depth-2 2x2 — one tree_step q pull plus
            // the advance's extend q/h pulls on the host path; the
            // path-indices pull + [B] tok0 ints on the device path.
            let rhost = recurrent_tree_host_bytes_per_round(b, vt, vocab, f3, 2, vd, d);
            let rdev = recurrent_tree_device_bytes_per_round(b, n, vt);
            let extend_pull = (b * vt * (vd + d) * 4) as u64;
            assert_eq!(
                rhost,
                host_verify_bytes_per_round(b, vt, vocab, f3)
                    + (b * (vt - 1) * vd * 4) as u64
                    + extend_pull
            );
            assert_eq!(rdev, dev + (b * (vt - 1) * 4) as u64 + (b * 4) as u64);
            assert!(
                rdev * 50 < rhost,
                "b={b}: recurrent tree device {rdev} not <50x below host {rhost}"
            );
            // depth 1 needs no tree_step pull — the extend pull remains
            assert_eq!(
                recurrent_tree_host_bytes_per_round(b, vt, vocab, f3, 1, vd, d),
                host_verify_bytes_per_round(b, vt, vocab, f3) + extend_pull
            );
        }
    }

    #[test]
    fn round_shape_histogram_and_gauges() {
        let mut m = EngineMetrics::default();
        m.observe_round_row(6, 2); // tree round: 6 nodes, path len 2
        m.observe_round_row(6, 0);
        m.observe_round_row(7, 7); // chain round: K=7, clean sweep
        assert_eq!(m.row_rounds, 3);
        assert_eq!(m.path_len_hist, vec![1, 0, 1, 0, 0, 0, 0, 1]);
        assert!((m.nodes_per_round() - 19.0 / 3.0).abs() < 1e-12);
        assert!((m.mean_accepted_len() - 3.0).abs() < 1e-12);
        let text = m.render("e");
        assert!(text.contains("lkspec_nodes_per_round{engine=\"e\"}"));
        assert!(text.contains("lkspec_accepted_len_rounds{engine=\"e\",len=\"0\"} 1"));
        assert!(text.contains("lkspec_accepted_len_rounds{engine=\"e\",len=\"7\"} 1"));
        // fresh metrics render finite gauges, no NaN division
        let mut fresh = EngineMetrics::default();
        assert_eq!(fresh.nodes_per_round(), 0.0);
        assert_eq!(fresh.mean_accepted_len(), 0.0);
        assert!(!fresh.render("e").contains("NaN"));
    }

    #[test]
    fn controller_gauges_render() {
        let mut m = EngineMetrics::default();
        // gauges absent until the controller stamps a round
        assert!(!m.render("e").contains("adaptive_k_mean"));
        m.observe_controller(7, 7, &[0.9, 0.5]);
        m.observe_controller(3, 3, &[0.8, 0.4]);
        assert_eq!(m.adaptive_k_last, 3);
        assert!((m.adaptive_k.mean() - 5.0).abs() < 1e-12);
        assert_eq!(m.alpha_hat, vec![0.8, 0.4], "latest snapshot wins");
        let text = m.render("e");
        assert!(text.contains("lkspec_adaptive_k_last{engine=\"e\"} 3"));
        assert!(text.contains("lkspec_adaptive_k_mean{engine=\"e\"} 5"));
        assert!(text.contains("lkspec_alpha_hat{engine=\"e\",pos=\"0\"} 0.8"));
        assert!(text.contains("lkspec_alpha_hat{engine=\"e\",pos=\"1\"} 0.4"));
    }

    /// The occupancy-bias fix: idle ticks (requests pending, no group)
    /// must pull the means down instead of being silently skipped, and
    /// the time-weighted mean must weight samples by wall time — not by
    /// how often the driver happens to poll.
    #[test]
    fn occupancy_counts_idle_ticks() {
        use std::time::Duration;
        let mut m = SchedulerMetrics::default();
        let t0 = Instant::now();
        // One decode round at full occupancy that lasted 100 ms…
        m.observe_occupancy(1.0, t0);
        m.observe_occupancy(1.0, t0 + Duration::from_millis(100));
        // …then a burst of rapid idle polls covering 100 ms total.
        for i in 1..=10u64 {
            m.observe_occupancy(0.0, t0 + Duration::from_millis(100 + 10 * i));
            m.idle_ticks += 1;
        }
        // Per-sample mean is dragged down by the poll burst (2/12)…
        assert!((m.slot_occupancy.mean() - 2.0 / 12.0).abs() < 1e-12);
        // …but the time-weighted mean sees 100 ms busy / 200 ms total,
        // regardless of how many polls the idle window was split into.
        assert!((m.occupancy_time_mean() - 0.5).abs() < 1e-9);
        m.padded_row_rounds += 3;
        m.live_row_rounds += 1;
        m.downshifts += 1;
        m.upshifts += 1;
        let text = m.render("e");
        assert!(text.contains("lkspec_sched_idle_ticks_total{engine=\"e\"} 10"));
        assert!(text.contains("lkspec_sched_downshifts_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_upshifts_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_slot_occupancy_time_mean"));
        assert!(text.contains("lkspec_sched_padded_row_rounds_total{engine=\"e\"} 3"));
        assert!(text.contains("lkspec_sched_live_row_rounds_total{engine=\"e\"} 1"));
    }

    /// The migration-transfer contract: the device gather path reports
    /// ZERO host KV bytes, while the closed form shows what the old
    /// host repack would have moved at the manifest's own dims.
    #[test]
    fn migration_transfer_closed_forms() {
        let (l, h, smax, dh) = (4usize, 4usize, 88usize, 24usize);
        let dense = migration_host_kv_bytes_host_repack(l, 4, 1, h, smax, dh, true);
        let row = h * smax * dh * 4;
        assert_eq!(dense, ((l * 2 * 5 + 2 * 5) * row) as u64);
        assert_eq!(migration_host_kv_bytes_device(), 0);
        assert!(dense > 1_000_000, "host repack moves megabytes: {dense}");
        // The live gauge: device-path migrations observe 0 bytes each.
        let mut m = EngineMetrics::default();
        assert!(!m.render("e").contains("migrations_total"));
        m.observe_migration_host_kv_bytes(0);
        m.observe_migration_host_kv_bytes(0);
        assert_eq!(m.migrations, 2);
        assert_eq!(m.host_kv_bytes_per_migration(), 0.0);
        let text = m.render("e");
        assert!(text.contains("lkspec_migrations_total{engine=\"e\"} 2"));
        assert!(text.contains("lkspec_kv_host_bytes_per_migration{engine=\"e\"} 0"));
    }

    #[test]
    fn paged_kv_gauges_render() {
        let mut m = SchedulerMetrics {
            kv_blocks_live: 12,
            kv_blocks_free: 4,
            prefix_hit_rate: 0.625,
            kv_sheds: 2,
            kv_evictions: 3,
            ..Default::default()
        };
        let text = m.render("e");
        assert!(text.contains("lkspec_kv_blocks_live{engine=\"e\"} 12"));
        assert!(text.contains("lkspec_kv_blocks_free{engine=\"e\"} 4"));
        assert!(text.contains("lkspec_prefix_hit_rate{engine=\"e\"} 0.625"));
        assert!(text.contains("lkspec_sched_kv_sheds_total{engine=\"e\"} 2"));
        assert!(text.contains("lkspec_sched_kv_evictions_total{engine=\"e\"} 3"));
    }

    /// The fault/deadline/drain counters of DESIGN.md §9 render in both
    /// namespaces (engine-side execute faults, scheduler-side verdicts).
    #[test]
    fn fault_and_drain_counters_render() {
        let mut m = SchedulerMetrics {
            transient_retries: 2,
            session_faults: 1,
            engine_resets: 1,
            deadline_expired_queued: 3,
            deadline_expired_inflight: 1,
            cancelled: 2,
            draining: true,
            ..Default::default()
        };
        let text = m.render("e");
        assert!(text.contains("lkspec_sched_transient_retries_total{engine=\"e\"} 2"));
        assert!(text.contains("lkspec_sched_session_faults_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_engine_resets_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_deadline_expired_queued{engine=\"e\"} 3"));
        assert!(text.contains("lkspec_sched_deadline_expired_inflight{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_cancelled_total{engine=\"e\"} 2"));
        assert!(text.contains("lkspec_sched_draining{engine=\"e\"} 1"));

        let mut e = EngineMetrics {
            transient_faults: 4,
            verify_degrades: 1,
            verify_path: "host",
            ..Default::default()
        };
        let text = e.render("e");
        assert!(text.contains("lkspec_transient_faults_total{engine=\"e\"} 4"));
        assert!(text.contains("lkspec_verify_degrades_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_verify_path{engine=\"e\",path=\"host\"} 1"));
    }

    #[test]
    fn scheduler_metrics_gauges() {
        let mut m = SchedulerMetrics::default();
        assert_eq!(m.tokens_per_second(), 0.0);
        m.note_started();
        m.observe_session(&result(20.0, 5.0, 2.0));
        m.observe_session(&result(30.0, 6.0, 3.0));
        m.slot_occupancy.push(0.75);
        m.joins += 1;
        assert_eq!(m.sessions, 2);
        assert_eq!(m.tokens_out, 8);
        assert!(m.tokens_per_second() > 0.0);
        let text = m.render("e");
        assert!(text.contains("lkspec_sched_sessions_total{engine=\"e\"} 2"));
        assert!(text.contains("lkspec_sched_joins_total{engine=\"e\"} 1"));
        assert!(text.contains("lkspec_sched_slot_occupancy_mean"));
        assert!(text.contains("lkspec_sched_queue_wait_ms_p50"));
    }

    #[test]
    fn http_metrics_gauges() {
        let m = HttpMetrics::default();
        m.conns.fetch_add(2, Ordering::Relaxed);
        m.conns_total.fetch_add(5, Ordering::Relaxed);
        m.queue_depth.fetch_add(1, Ordering::Relaxed);
        m.sheds.fetch_add(3, Ordering::Relaxed);
        m.disconnects.fetch_add(1, Ordering::Relaxed);
        m.requests_total.fetch_add(4, Ordering::Relaxed);
        m.observe_ttft(12.0);
        m.observe_inter_token(1.5);
        m.observe_inter_token(2.5);
        let text = m.render();
        assert!(text.contains("lkspec_http_conns 2"));
        assert!(text.contains("lkspec_http_conns_total 5"));
        assert!(text.contains("lkspec_http_queue_depth 1"));
        assert!(text.contains("lkspec_http_sheds_total 3"));
        assert!(text.contains("lkspec_http_disconnects_total 1"));
        assert!(text.contains("lkspec_http_requests_total 4"));
        assert!(text.contains("lkspec_http_stream_ttft_ms_p50 12"));
        assert!(text.contains("lkspec_http_inter_token_ms_p50"));
        assert!(text.contains("lkspec_http_inter_token_ms_p95"));
    }
}
