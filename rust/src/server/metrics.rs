//! Engine metrics: counters + latency distributions, with a
//! Prometheus-style text exposition for scraping/debugging.

use crate::util::{OnlineStats, Percentiles};

use super::engine::RequestResult;

#[derive(Default)]
pub struct EngineMetrics {
    pub requests: u64,
    pub tokens_out: u64,
    pub rounds: u64,
    pub drafted: u64,
    pub accepted: u64,
    pub latency_ms: Percentiles,
    pub tau: OnlineStats,
}

impl EngineMetrics {
    pub fn observe_request(&mut self, r: &RequestResult) {
        self.requests += 1;
        self.tokens_out += r.tokens.len() as u64;
        self.rounds += r.rounds;
        self.drafted += r.stats.drafted.iter().sum::<u64>();
        self.accepted += r.stats.accepted.iter().sum::<u64>();
        self.latency_ms.push(r.latency_ms);
        self.tau.push(r.stats.tau());
    }

    pub fn acceptance_ratio(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Prometheus-style text block.
    pub fn render(&mut self, engine: &str) -> String {
        let mut out = String::new();
        let mut line = |name: &str, v: f64| {
            out.push_str(&format!("lkspec_{name}{{engine=\"{engine}\"}} {v}\n"));
        };
        line("requests_total", self.requests as f64);
        line("tokens_out_total", self.tokens_out as f64);
        line("rounds_total", self.rounds as f64);
        line("drafted_total", self.drafted as f64);
        line("accepted_total", self.accepted as f64);
        line("acceptance_ratio", self.acceptance_ratio());
        line("tau_mean", self.tau.mean());
        if !self.latency_ms.is_empty() {
            line("latency_ms_p50", self.latency_ms.pct(50.0));
            line("latency_ms_p95", self.latency_ms.pct(95.0));
            line("latency_ms_p99", self.latency_ms.pct(99.0));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::accept::AcceptanceStats;

    #[test]
    fn observe_and_render() {
        let mut m = EngineMetrics::default();
        let mut stats = AcceptanceStats::new(4);
        stats.record_round(4, 3);
        m.observe_request(&RequestResult {
            tokens: vec![1, 2, 3, 4],
            stats,
            latency_ms: 12.5,
            rounds: 1,
        });
        assert_eq!(m.requests, 1);
        assert_eq!(m.tokens_out, 4);
        assert_eq!(m.accepted, 3);
        assert!((m.acceptance_ratio() - 0.75).abs() < 1e-12);
        let text = m.render("test");
        assert!(text.contains("lkspec_requests_total{engine=\"test\"} 1"));
        assert!(text.contains("latency_ms_p50"));
    }
}
