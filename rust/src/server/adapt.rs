//! Online drafter adaptation (DESIGN.md §12): the serving→training→
//! serving loop that closes the paper's thesis inside the engine.
//!
//! Serving measures acceptance every round and — before this module —
//! threw the evidence away. Here the evidence becomes training signal:
//!
//!   * [`ReplayBuffer`] — a bounded FIFO ring of [`ReplayRecord`]s
//!     harvested from every decode path (host/device × chain/tree).
//!     Each record is one draft slot's outcome: context tail, draft
//!     token, accept/reject, and — on host-verify rounds, where the
//!     distributions are materialized anyway — the draft and target
//!     probabilities of the drafted token. Records are the sufficient
//!     statistics of the LK losses' acceptance objective collapsed onto
//!     the serving distribution.
//!   * [`TrainerHandle`] — orchestration of a background fine-tune
//!     subprocess under the gadogado `distill-train.py` contract
//!     (SNIPPETS.md Snippet 1): JSON config in (a file path argument),
//!     JSONL progress events out (`{"kind": .., "payload": ..}` lines
//!     on stdout), atomic checkpoint swap on the trainer side. Crash,
//!     hang (event deadline) and malformed output map to a typed
//!     [`TrainerFault`] whose [`FaultKind`] is ALWAYS `Transient`:
//!     adaptation is advisory, so no trainer failure may ever widen
//!     past "keep serving the stale weights".
//!   * [`AdaptDriver`] — the scheduler-resident stage: every
//!     `interval_rounds` decode rounds it snapshots the ring to a
//!     transcript JSONL, launches the trainer (epoch-tagged output
//!     dir), polls it between rounds, and on success hot-swaps the
//!     draft weights through [`SchedulerCore::swap_draft`] at a round
//!     boundary — validate-then-commit, rollback (keep old weights) on
//!     any load failure. Draining cancels an in-flight trainer.
//!
//! The exactness contract is untouched by construction: draft weights
//! only change WHAT is proposed, never the accept/resample rule, so
//! greedy decode stays the target's argmax path and stochastic decode
//! stays distribution-lossless across arbitrary swap boundaries
//! (`tests/adapt_loop.rs` pins both, plus the chaos matrix).

use std::collections::VecDeque;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::fault::FaultKind;
use super::metrics::AdaptMetrics;
use super::scheduler::SchedulerCore;
use crate::util::Json;

// ---------------------------------------------------------------------------
// Replay records + the bounded harvest ring
// ---------------------------------------------------------------------------

/// Committed-context tokens carried per record (the "context features"
/// of the harvest schema — enough for n-gram-conditioned calibration;
/// the fine-tuner recomputes full distributions from the checkpointed
/// models when it needs more than the tail).
pub const CTX_TAIL: usize = 4;

/// One draft slot's outcome, harvested at verdict time. The core fields
/// (everything except `q_draft`/`p_target`) are PATH-INDEPENDENT: host
/// and device verify emit identical records for identical verdicts —
/// the fused kernel returns only verdict integers, so the probability
/// fields are populated exclusively by host-verify rounds and are NaN
/// (serialized as `null`) otherwise.
#[derive(Clone, Debug)]
pub struct ReplayRecord {
    /// Session (request) id — keys the per-request RNG stream too.
    pub session: u64,
    /// The core's decode-round counter when the slot was judged.
    pub round: u64,
    /// Committed-sequence position the draft targeted.
    pub pos: u32,
    /// Draft slot within the round (head index `n` of the LK losses).
    pub slot: u8,
    /// Last `CTX_TAIL` committed tokens before `pos`, oldest first,
    /// left-padded with -1.
    pub ctx: [i32; CTX_TAIL],
    /// The proposed draft token.
    pub draft: i32,
    /// The exact-rejection verdict for this slot.
    pub accepted: bool,
    /// q(draft | ctx) — the draft model's probability (NaN off-host).
    pub q_draft: f32,
    /// p(draft | ctx) — the target's probability (NaN off-host).
    pub p_target: f32,
}

impl ReplayRecord {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("session", Json::Num(self.session as f64)),
            ("round", Json::Num(self.round as f64)),
            ("pos", Json::Num(self.pos as f64)),
            ("slot", Json::Num(self.slot as f64)),
            (
                "ctx",
                Json::Arr(self.ctx.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("draft", Json::Num(self.draft as f64)),
            ("accept", Json::Bool(self.accepted)),
        ];
        if self.q_draft.is_finite() {
            fields.push(("q", Json::Num(self.q_draft as f64)));
        }
        if self.p_target.is_finite() {
            fields.push(("p", Json::Num(self.p_target as f64)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<ReplayRecord> {
        let mut ctx = [-1i32; CTX_TAIL];
        let arr = v.get("ctx").as_arr().context("record missing ctx array")?;
        anyhow::ensure!(arr.len() == CTX_TAIL, "ctx tail must hold {CTX_TAIL} tokens");
        for (slot, t) in ctx.iter_mut().zip(arr) {
            *slot = t.as_f64().context("non-numeric ctx token")? as i32;
        }
        Ok(ReplayRecord {
            session: v.req_usize("session")? as u64,
            round: v.req_usize("round")? as u64,
            pos: v.req_usize("pos")? as u32,
            slot: v.req_usize("slot")? as u8,
            ctx,
            draft: v.req_f64("draft")? as i32,
            accepted: v.get("accept").as_bool().context("record missing accept")?,
            q_draft: v.get("q").as_f64().map_or(f32::NAN, |x| x as f32),
            p_target: v.get("p").as_f64().map_or(f32::NAN, |x| x as f32),
        })
    }
}

/// Bounded-memory FIFO ring of harvested records. `push` past capacity
/// evicts the OLDEST record (eviction order == insertion order), so the
/// ring always holds the freshest window of the serving distribution —
/// exactly what an online fine-tune should see.
pub struct ReplayBuffer {
    cap: usize,
    ring: VecDeque<ReplayRecord>,
    /// Records ever pushed / evicted (gauges; depth = pushed - evicted
    /// only until the first snapshot-less restart, so both are kept).
    pub pushed_total: u64,
    pub evicted_total: u64,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> ReplayBuffer {
        ReplayBuffer {
            cap: cap.max(1),
            ring: VecDeque::new(),
            pushed_total: 0,
            evicted_total: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn push(&mut self, rec: ReplayRecord) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.evicted_total += 1;
        }
        self.ring.push_back(rec);
        self.pushed_total += 1;
    }

    pub fn iter(&self) -> impl Iterator<Item = &ReplayRecord> {
        self.ring.iter()
    }

    /// Highest round stamp in the ring (swap-boundary bookkeeping).
    pub fn max_round(&self) -> u64 {
        self.ring.iter().map(|r| r.round).max().unwrap_or(0)
    }

    /// Accepted fraction over records with `round >= since` — the
    /// alpha_hat gauge the drift bench reads pre/post swap.
    pub fn alpha_hat_since(&self, since: u64) -> Option<f64> {
        let mut acc = 0u64;
        let mut n = 0u64;
        for r in self.ring.iter().filter(|r| r.round >= since) {
            n += 1;
            acc += r.accepted as u64;
        }
        (n > 0).then(|| acc as f64 / n as f64)
    }

    /// Serialize the ring (oldest first) as transcript JSONL.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for rec in &self.ring {
            out.push_str(&rec.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Write the transcript atomically (tmp + rename — the trainer may
    /// race the write on a slow filesystem otherwise).
    pub fn snapshot_jsonl(&self, path: &Path) -> Result<usize> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("jsonl.tmp");
        std::fs::write(&tmp, self.to_jsonl())?;
        std::fs::rename(&tmp, path)?;
        Ok(self.ring.len())
    }

    /// Parse a transcript back (tests + the built-in sim fine-tuner).
    pub fn parse_jsonl(text: &str) -> Result<Vec<ReplayRecord>> {
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                let v = Json::parse(l).map_err(|e| anyhow::anyhow!("transcript line: {e}"))?;
                ReplayRecord::from_json(&v)
            })
            .collect()
    }
}

/// Shared handle to the ring: the core pushes at verdict time (single
/// worker thread), the driver snapshots between rounds. A mutex rather
/// than `Rc<RefCell>` so cores stay `Send` for the router's worker
/// hand-off; contention is nil (one thread).
pub type ReplaySink = Arc<Mutex<ReplayBuffer>>;

pub fn replay_sink(cap: usize) -> ReplaySink {
    Arc::new(Mutex::new(ReplayBuffer::new(cap)))
}

/// Harvest one row's round verdict into the ring — THE single entry
/// point for all four decode paths, so host/device and chain/tree
/// harvests agree by construction wherever their verdicts agree.
///
/// `drafts_row` holds the slots that reached a verdict: the full chain
/// (accepted prefix + the first rejection) on the chain paths, the
/// accepted path on the tree paths (rejected siblings never form a
/// linear slot order). Slots `0..n_acc` are accepted; slot `n_acc`, if
/// present, is the first rejection. `committed` is the row's committed
/// tokens BEFORE this round's verdict is applied (the context source);
/// `probs[i] = (q_i, p_i)` where available (host verify), else empty.
#[allow(clippy::too_many_arguments)]
pub fn harvest_row(
    sink: &ReplaySink,
    session: u64,
    round: u64,
    pos0: usize,
    committed: &[i32],
    drafts_row: &[i32],
    n_acc: usize,
    probs: &[(f32, f32)],
) {
    let judged = drafts_row.len().min(n_acc + 1);
    let Ok(mut buf) = sink.lock() else { return };
    for i in 0..judged {
        let mut ctx = [-1i32; CTX_TAIL];
        // Context for slot i: last CTX_TAIL of committed ++ accepted
        // drafts before it (a draft conditions on the speculated
        // prefix, not just the committed one).
        let take_drafts = i.min(n_acc);
        let n_committed = CTX_TAIL.saturating_sub(take_drafts).min(committed.len());
        let mut w = CTX_TAIL;
        for &t in drafts_row[..take_drafts].iter().rev().take(CTX_TAIL) {
            w -= 1;
            ctx[w] = t;
        }
        for &t in committed[committed.len() - n_committed..].iter().rev() {
            if w == 0 {
                break;
            }
            w -= 1;
            ctx[w] = t;
        }
        let (q, p) = probs.get(i).copied().unwrap_or((f32::NAN, f32::NAN));
        buf.push(ReplayRecord {
            session,
            round,
            pos: (pos0 + i) as u32,
            slot: i as u8,
            ctx,
            draft: drafts_row[i],
            accepted: i < n_acc,
            q_draft: q,
            p_target: p,
        });
    }
}

/// Harvest one row's TREE verdict. The sequential multi-draft walk
/// judges, in BFS order, the earlier siblings of each accepted node
/// (all rejected — the walk descends at the first acceptance) and, when
/// it terminates early, every child of the final accepted node (all
/// rejected). That judged set is exactly reconstructible from the
/// topology (`parent_of`) plus the accepted `path`, so tree rounds
/// harvest true accept AND reject records even though the verdict only
/// names the accepted path. Node records use the node's LEVEL as the
/// slot (the draft head that proposed it); q/p are per-node in tree
/// coordinates and are not carried (NaN), like the device chain path.
#[allow(clippy::too_many_arguments)]
pub fn harvest_tree_row(
    sink: &ReplaySink,
    session: u64,
    round: u64,
    pos0: usize,
    committed: &[i32],
    candidates: &[i32],
    parent_of: impl Fn(usize) -> i32,
    path: &[usize],
) {
    let Ok(mut buf) = sink.lock() else { return };
    let n = candidates.len();
    let mut acc_prefix: Vec<i32> = Vec::with_capacity(path.len());
    let mut push = |buf: &mut ReplayBuffer, node: usize, level: usize, accepted: bool,
                    acc_prefix: &[i32]| {
        let mut ctx = [-1i32; CTX_TAIL];
        let take_acc = level.min(acc_prefix.len()).min(CTX_TAIL);
        let n_committed = CTX_TAIL.saturating_sub(take_acc).min(committed.len());
        let mut w = CTX_TAIL;
        for &t in acc_prefix[..take_acc].iter().rev() {
            w -= 1;
            ctx[w] = t;
        }
        for &t in committed[committed.len() - n_committed..].iter().rev() {
            if w == 0 {
                break;
            }
            w -= 1;
            ctx[w] = t;
        }
        buf.push(ReplayRecord {
            session,
            round,
            pos: (pos0 + level) as u32,
            slot: level as u8,
            ctx,
            draft: candidates[node],
            accepted,
            q_draft: f32::NAN,
            p_target: f32::NAN,
        });
    };
    let mut cur: i32 = -1;
    for (level, &a) in path.iter().enumerate() {
        for i in 0..a.min(n) {
            if parent_of(i) == cur {
                push(&mut buf, i, level, false, &acc_prefix);
            }
        }
        if a < n {
            push(&mut buf, a, level, true, &acc_prefix);
            acc_prefix.push(candidates[a]);
            cur = a as i32;
        }
    }
    // Early termination: every remaining child of the final accepted
    // node was judged and rejected (an accepted leaf has no children,
    // so this loop is empty on full-depth walks).
    let level = path.len();
    for i in 0..n {
        if parent_of(i) == cur {
            push(&mut buf, i, level, false, &acc_prefix);
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer subprocess orchestration (SNIPPETS.md Snippet 1 contract)
// ---------------------------------------------------------------------------

/// How the driver runs a fine-tune.
#[derive(Clone, Debug)]
pub enum TrainerSpec {
    /// Spawn `argv ++ ["--config", <path>]` — the Snippet-1 contract
    /// (e.g. `python3 python/train/lk_finetune.py`). Stdout must be
    /// JSONL events; the final event must be `kind == "done"` with a
    /// `checkpoint` payload path.
    Command(Vec<String>),
    /// In-process deterministic fine-tune over the snapshot (the same
    /// acceptance-profile fit `lk_finetune.py --mode sim` performs) —
    /// what the PJRT-free bench and tests use: no subprocess, no
    /// python, bit-deterministic.
    BuiltinSim,
}

/// A typed trainer failure. EVERY variant classifies as
/// [`FaultKind::Transient`]: the adaptation loop is advisory by
/// contract — a dead trainer means stale (still exact) draft weights,
/// never a degraded serving path.
#[derive(Clone, Debug, PartialEq)]
pub enum TrainerFault {
    /// Nonzero exit (or killed) before a `done` event.
    Crashed { code: Option<i32> },
    /// No stdout event within the deadline; the child was killed.
    Hang { after: Duration },
    /// A stdout line that is not a `{"kind", "payload"}` object.
    Protocol { line: String },
    /// The trainer reported a structured `error` event.
    Reported { message: String },
    /// Spawn / IO plumbing failed.
    Io { message: String },
}

impl TrainerFault {
    /// The blast radius of ANY trainer fault: transient — contained to
    /// the adaptation loop, serving continues on the stale weights.
    pub fn kind(&self) -> FaultKind {
        FaultKind::Transient
    }
}

impl std::fmt::Display for TrainerFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainerFault::Crashed { code } => write!(f, "trainer crashed (exit {code:?})"),
            TrainerFault::Hang { after } => {
                write!(f, "trainer hang: no event for {:.1}s", after.as_secs_f64())
            }
            TrainerFault::Protocol { line } => write!(f, "malformed trainer event: {line:?}"),
            TrainerFault::Reported { message } => write!(f, "trainer error: {message}"),
            TrainerFault::Io { message } => write!(f, "trainer io: {message}"),
        }
    }
}

/// One parsed `{"kind": .., "payload": ..}` stdout line.
#[derive(Clone, Debug)]
pub struct TrainerEvent {
    pub kind: String,
    pub payload: Json,
}

/// What a successful fine-tune hands back (the `done` payload).
#[derive(Clone, Debug)]
pub struct TrainerOutcome {
    pub checkpoint: PathBuf,
    pub epoch: u64,
    pub alpha_before: f64,
    pub alpha_after: f64,
}

enum ReaderMsg {
    Event(TrainerEvent),
    Malformed(String),
    Eof,
}

enum TrainerBody {
    Child {
        child: std::process::Child,
        rx: Receiver<ReaderMsg>,
        last_event: Instant,
        deadline: Duration,
        eof: bool,
    },
    /// BuiltinSim: resolved at launch.
    Immediate(Option<Result<TrainerOutcome, TrainerFault>>),
}

/// Poll result of an in-flight fine-tune.
pub enum TrainerPoll {
    Running,
    Finished(Result<TrainerOutcome, TrainerFault>),
}

/// A launched fine-tune: subprocess + stdout reader thread, or the
/// resolved built-in result. Dropping the handle kills the child.
pub struct TrainerHandle {
    body: TrainerBody,
    /// Events observed so far (progress surfacing / tests).
    pub events: Vec<TrainerEvent>,
    done: Option<TrainerOutcome>,
}

impl TrainerHandle {
    /// Spawn `argv ++ ["--config", config_path]` with stdout piped and
    /// a reader thread parsing the event stream.
    pub fn spawn(
        argv: &[String],
        config_path: &Path,
        deadline: Duration,
    ) -> std::result::Result<TrainerHandle, TrainerFault> {
        if argv.is_empty() {
            return Err(TrainerFault::Io {
                message: "empty trainer command".into(),
            });
        }
        let mut cmd = std::process::Command::new(&argv[0]);
        cmd.args(&argv[1..])
            .arg("--config")
            .arg(config_path)
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null());
        let mut child = cmd.spawn().map_err(|e| TrainerFault::Io {
            message: format!("spawning {:?}: {e}", argv[0]),
        })?;
        let stdout = child.stdout.take().ok_or_else(|| TrainerFault::Io {
            message: "no stdout pipe".into(),
        })?;
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let reader = std::io::BufReader::new(stdout);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if line.trim().is_empty() {
                    continue;
                }
                let msg = match Json::parse(&line) {
                    Ok(v) => match v.get("kind").as_str() {
                        Some(kind) => ReaderMsg::Event(TrainerEvent {
                            kind: kind.to_string(),
                            payload: v.get("payload").clone(),
                        }),
                        None => ReaderMsg::Malformed(line),
                    },
                    Err(_) => ReaderMsg::Malformed(line),
                };
                if tx.send(msg).is_err() {
                    return;
                }
            }
            let _ = tx.send(ReaderMsg::Eof);
        });
        Ok(TrainerHandle {
            body: TrainerBody::Child {
                child,
                rx,
                last_event: Instant::now(),
                deadline,
                eof: false,
            },
            events: Vec::new(),
            done: None,
        })
    }

    /// Wrap an already-computed outcome (the BuiltinSim path).
    pub fn immediate(result: Result<TrainerOutcome, TrainerFault>) -> TrainerHandle {
        TrainerHandle {
            body: TrainerBody::Immediate(Some(result)),
            events: Vec::new(),
            done: None,
        }
    }

    fn outcome_from_done(payload: &Json) -> Result<TrainerOutcome, TrainerFault> {
        let ckpt = payload.get("checkpoint").as_str().ok_or_else(|| {
            TrainerFault::Protocol {
                line: format!("done event without checkpoint: {}", payload.to_string()),
            }
        })?;
        Ok(TrainerOutcome {
            checkpoint: PathBuf::from(ckpt),
            epoch: payload.get("epoch").as_f64().unwrap_or(0.0) as u64,
            alpha_before: payload.get("alpha_before").as_f64().unwrap_or(f64::NAN),
            alpha_after: payload.get("alpha_after").as_f64().unwrap_or(f64::NAN),
        })
    }

    /// Drain events; detect completion, crash, hang, protocol breach.
    /// Non-blocking — called between decode rounds.
    pub fn poll(&mut self, now: Instant) -> TrainerPoll {
        match &mut self.body {
            TrainerBody::Immediate(slot) => match slot.take() {
                Some(r) => TrainerPoll::Finished(r),
                None => TrainerPoll::Running,
            },
            TrainerBody::Child {
                child,
                rx,
                last_event,
                deadline,
                eof,
            } => {
                loop {
                    match rx.try_recv() {
                        Ok(ReaderMsg::Event(ev)) => {
                            *last_event = now;
                            if ev.kind == "done" {
                                match Self::outcome_from_done(&ev.payload) {
                                    Ok(out) => self.done = Some(out),
                                    Err(f) => {
                                        let _ = child.kill();
                                        let _ = child.wait();
                                        return TrainerPoll::Finished(Err(f));
                                    }
                                }
                            } else if ev.kind == "error" {
                                let msg = ev
                                    .payload
                                    .get("message")
                                    .as_str()
                                    .unwrap_or("unspecified")
                                    .to_string();
                                let _ = child.kill();
                                let _ = child.wait();
                                return TrainerPoll::Finished(Err(TrainerFault::Reported {
                                    message: msg,
                                }));
                            }
                            self.events.push(ev);
                        }
                        Ok(ReaderMsg::Malformed(line)) => {
                            let _ = child.kill();
                            let _ = child.wait();
                            return TrainerPoll::Finished(Err(TrainerFault::Protocol { line }));
                        }
                        Ok(ReaderMsg::Eof) => {
                            *eof = true;
                            break;
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            *eof = true;
                            break;
                        }
                    }
                }
                if *eof {
                    // Stream closed: the exit status decides. `done`
                    // must have been seen AND the exit be clean.
                    let status = match child.wait() {
                        Ok(s) => s,
                        Err(e) => {
                            return TrainerPoll::Finished(Err(TrainerFault::Io {
                                message: format!("wait: {e}"),
                            }))
                        }
                    };
                    return TrainerPoll::Finished(match (self.done.take(), status.success()) {
                        (Some(out), true) => Ok(out),
                        (None, true) => Err(TrainerFault::Protocol {
                            line: "exit 0 without a done event".into(),
                        }),
                        (_, false) => Err(TrainerFault::Crashed {
                            code: status.code(),
                        }),
                    });
                }
                let quiet = now.saturating_duration_since(*last_event);
                if quiet > *deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    return TrainerPoll::Finished(Err(TrainerFault::Hang { after: quiet }));
                }
                TrainerPoll::Running
            }
        }
    }

    /// Kill an in-flight fine-tune (graceful drain / engine reset).
    pub fn cancel(&mut self) {
        if let TrainerBody::Child { child, .. } = &mut self.body {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

impl Drop for TrainerHandle {
    fn drop(&mut self) {
        self.cancel();
    }
}

// ---------------------------------------------------------------------------
// Built-in sim fine-tune (the PJRT-free loop closure)
// ---------------------------------------------------------------------------

/// The deterministic acceptance-profile fit `lk_finetune.py --mode sim`
/// performs, in-process: per-slot empirical acceptance over the
/// transcript, then a fitted profile that closes fraction `gain` of
/// each slot's acceptance gap — the stylized effect of an LK fine-tune
/// on the serving distribution (a drafter trained on its own rejections
/// recovers part of 1-alpha; `gain` is the modeled recovery). Returns
/// `(fitted per-slot profile, alpha_before, alpha_after)`.
pub fn sim_finetune(records: &[ReplayRecord], k: usize, gain: f64) -> (Vec<f64>, f64, f64) {
    let k = k.max(1);
    let mut acc = vec![0u64; k];
    let mut tot = vec![0u64; k];
    for r in records {
        let s = (r.slot as usize).min(k - 1);
        tot[s] += 1;
        acc[s] += r.accepted as u64;
    }
    let gain = gain.clamp(0.0, 1.0);
    let mut profile = Vec::with_capacity(k);
    let (mut a_n, mut a_d) = (0.0f64, 0.0f64);
    for i in 0..k {
        // Slots never exercised inherit the previous slot's estimate
        // (deep slots only run after shallow accepts).
        let alpha = if tot[i] > 0 {
            a_n += acc[i] as f64;
            a_d += tot[i] as f64;
            acc[i] as f64 / tot[i] as f64
        } else {
            profile.last().copied().unwrap_or(0.5)
        };
        profile.push((alpha + gain * (1.0 - alpha)).clamp(0.0, 1.0));
    }
    let alpha_before = if a_d > 0.0 { a_n / a_d } else { 0.0 };
    let alpha_after = alpha_before + gain * (1.0 - alpha_before);
    (profile, alpha_before, alpha_after)
}

/// Write the sim-draft checkpoint the [`SchedulerCore::swap_draft`] of
/// `SimCore` consumes: a JSON artifact tagged with the adaptation
/// epoch. Atomic (tmp + rename), like every checkpoint writer here.
pub fn write_sim_checkpoint(
    path: &Path,
    epoch: u64,
    profile: &[f64],
    alpha_before: f64,
    alpha_after: f64,
) -> Result<()> {
    let v = Json::obj(vec![
        ("format", Json::Str("lkspec-sim-draft".into())),
        ("epoch", Json::Num(epoch as f64)),
        ("profile", Json::arr_f64(profile)),
        ("alpha_before", Json::Num(alpha_before)),
        ("alpha_after", Json::Num(alpha_after)),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, v.to_string_pretty())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Parse + validate a sim-draft checkpoint (the validate half of
/// SimCore's validate-then-commit swap).
pub fn read_sim_checkpoint(path: &Path) -> Result<(u64, Vec<f64>)> {
    let v = Json::parse_file(path)?;
    anyhow::ensure!(
        v.get("format").as_str() == Some("lkspec-sim-draft"),
        "{}: not a sim-draft checkpoint",
        path.display()
    );
    let arr = v
        .get("profile")
        .as_arr()
        .context("sim-draft checkpoint missing profile")?;
    anyhow::ensure!(!arr.is_empty(), "sim-draft profile is empty");
    let mut profile = Vec::with_capacity(arr.len());
    for x in arr {
        let a = x.as_f64().context("non-numeric profile entry")?;
        anyhow::ensure!((0.0..=1.0).contains(&a), "profile entry {a} outside [0, 1]");
        profile.push(a);
    }
    Ok((v.get("epoch").as_f64().unwrap_or(0.0) as u64, profile))
}

// ---------------------------------------------------------------------------
// Trainer chaos vocabulary (ChaosCore extension, DESIGN.md §9/§12)
// ---------------------------------------------------------------------------

/// Deterministic trainer-fault injection: when the driver is about to
/// launch fine-tune run `at_run` (0-based), it launches a known-faulty
/// subprocess instead — exercising the REAL subprocess machinery
/// (reader thread, deadline, exit-status mapping), not a mock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrainerChaosKind {
    /// The child dies mid-stream after a valid first event.
    Kill,
    /// The child emits nothing until the (shrunk) deadline kills it.
    Hang,
    /// The child emits a line that is not a protocol event.
    Malformed,
}

#[derive(Clone, Copy, Debug)]
pub struct TrainerChaos {
    pub at_run: u64,
    pub kind: TrainerChaosKind,
}

// ---------------------------------------------------------------------------
// The adaptation driver
// ---------------------------------------------------------------------------

/// Adaptation-loop configuration (`Scheduler::with_adaptation`).
#[derive(Clone, Debug)]
pub struct AdaptConfig {
    /// Decode rounds between fine-tune launches.
    pub interval_rounds: u64,
    /// Replay-ring capacity (records).
    pub buffer_cap: usize,
    /// Do not launch with fewer harvested records than this.
    pub min_records: usize,
    /// How fine-tunes run.
    pub trainer: TrainerSpec,
    /// Hang deadline: a subprocess silent this long is killed.
    pub trainer_deadline: Duration,
    /// Epoch-tagged checkpoint/transcript dirs land under here.
    pub out_dir: PathBuf,
    /// BuiltinSim learning gain (fraction of the acceptance gap a
    /// fine-tune recovers; also forwarded to `lk_finetune.py --mode
    /// sim` via the config file).
    pub gain: f64,
    /// Deterministic trainer chaos (from `FaultPlan::trainer`).
    pub chaos: Vec<TrainerChaos>,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            interval_rounds: 64,
            buffer_cap: 4096,
            min_records: 32,
            trainer: TrainerSpec::BuiltinSim,
            trainer_deadline: Duration::from_secs(120),
            out_dir: PathBuf::from("runs/adapt"),
            gain: 0.5,
            chaos: Vec::new(),
        }
    }
}

impl AdaptConfig {
    /// Copy the trainer-chaos plan out of a ChaosCore
    /// [`FaultPlan`](super::scheduler::FaultPlan) — one declarative
    /// plan describes a whole scenario, engine and trainer faults
    /// included.
    pub fn with_chaos(mut self, chaos: Vec<TrainerChaos>) -> AdaptConfig {
        self.chaos = chaos;
        self
    }
}

/// The scheduler-resident adaptation stage. Owned by the scheduler and
/// stepped once per tick AFTER the decode round — every launch, poll
/// and hot-swap happens at a round boundary, never mid-round.
pub struct AdaptDriver {
    pub cfg: AdaptConfig,
    /// The harvest ring, shared with the core (`attach_replay`).
    pub buffer: ReplaySink,
    trainer: Option<TrainerHandle>,
    pub metrics: AdaptMetrics,
    /// Fine-tune epoch counter (tags checkpoint dirs).
    epoch: u64,
    /// Launches so far (keys the chaos plan).
    runs_launched: u64,
    last_launch_round: u64,
    /// Ring round stamp at the last committed swap (alpha_hat_post
    /// windows on records after it).
    swap_round: Option<u64>,
    /// Human-readable trainer-fault log (surfaced by tests/operators).
    pub faults: Vec<TrainerFault>,
}

impl AdaptDriver {
    pub fn new(cfg: AdaptConfig) -> AdaptDriver {
        let buffer = replay_sink(cfg.buffer_cap);
        AdaptDriver {
            buffer,
            trainer: None,
            metrics: AdaptMetrics::default(),
            epoch: 0,
            runs_launched: 0,
            last_launch_round: 0,
            swap_round: None,
            cfg,
            faults: Vec::new(),
        }
    }

    pub fn trainer_running(&self) -> bool {
        self.trainer.is_some()
    }

    /// Kill an in-flight fine-tune (drain / reset). The ring and the
    /// serving weights are untouched.
    pub fn cancel(&mut self) {
        if let Some(mut t) = self.trainer.take() {
            t.cancel();
            self.metrics.trainer_state = 0;
        }
    }

    fn launch(&mut self, rounds: u64) {
        let epoch = self.epoch + 1;
        let epoch_dir = self.cfg.out_dir.join(format!("epoch_{epoch:04}"));
        let transcript = epoch_dir.join("transcript.jsonl");
        let (snapshot, alpha_pre) = {
            let buf = match self.buffer.lock() {
                Ok(b) => b,
                Err(_) => return,
            };
            match buf.snapshot_jsonl(&transcript) {
                Ok(_) => {}
                Err(e) => {
                    self.faults.push(TrainerFault::Io {
                        message: format!("transcript snapshot: {e:#}"),
                    });
                    self.metrics.trainer_faults_total += 1;
                    return;
                }
            }
            (
                buf.iter().cloned().collect::<Vec<_>>(),
                buf.alpha_hat_since(0).unwrap_or(0.0),
            )
        };
        self.metrics.alpha_hat_pre = alpha_pre;
        let chaos = self
            .cfg
            .chaos
            .iter()
            .find(|c| c.at_run == self.runs_launched)
            .map(|c| c.kind);
        self.runs_launched += 1;
        self.last_launch_round = rounds;
        self.metrics.trainer_runs_total += 1;
        self.metrics.trainer_state = 1;
        let deadline = self.cfg.trainer_deadline;
        let handle = match chaos {
            // Chaos launches go through the REAL subprocess path.
            Some(TrainerChaosKind::Kill) => TrainerHandle::spawn(
                &[
                    "sh".into(),
                    "-c".into(),
                    r#"printf '%s\n' '{"kind":"start","payload":{}}'; exit 3"#.into(),
                ],
                &transcript,
                deadline,
            ),
            Some(TrainerChaosKind::Malformed) => TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "echo this is not a protocol event".into()],
                &transcript,
                deadline,
            ),
            Some(TrainerChaosKind::Hang) => TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "sleep 30".into()],
                &transcript,
                deadline.min(Duration::from_millis(50)),
            ),
            None => match &self.cfg.trainer {
                TrainerSpec::BuiltinSim => {
                    let k = 1 + snapshot.iter().map(|r| r.slot as usize).max().unwrap_or(0);
                    let (profile, a0, a1) = sim_finetune(&snapshot, k, self.cfg.gain);
                    let ckpt = epoch_dir.join("draft_sim.json");
                    Ok(TrainerHandle::immediate(
                        match write_sim_checkpoint(&ckpt, epoch, &profile, a0, a1) {
                            Ok(()) => Ok(TrainerOutcome {
                                checkpoint: ckpt,
                                epoch,
                                alpha_before: a0,
                                alpha_after: a1,
                            }),
                            Err(e) => Err(TrainerFault::Io {
                                message: format!("sim checkpoint: {e:#}"),
                            }),
                        },
                    ))
                }
                TrainerSpec::Command(argv) => {
                    let config = epoch_dir.join("config.json");
                    let cfg_json = Json::obj(vec![
                        ("transcript", Json::Str(transcript.display().to_string())),
                        ("out_dir", Json::Str(epoch_dir.display().to_string())),
                        ("epoch", Json::Num(epoch as f64)),
                        ("gain", Json::Num(self.cfg.gain)),
                    ]);
                    match cfg_json.write_file(&config) {
                        Ok(()) => TrainerHandle::spawn(argv, &config, deadline),
                        Err(e) => Err(TrainerFault::Io {
                            message: format!("trainer config: {e:#}"),
                        }),
                    }
                }
            },
        };
        match handle {
            Ok(h) => self.trainer = Some(h),
            Err(f) => {
                self.metrics.trainer_faults_total += 1;
                self.metrics.trainer_state = 3;
                self.faults.push(f);
            }
        }
    }

    /// The per-tick stage: refresh gauges, poll an in-flight trainer
    /// (hot-swapping on success, containing any fault), and launch a
    /// new fine-tune when the round cadence and harvest volume allow.
    pub fn step<C: SchedulerCore>(&mut self, core: &mut C, rounds: u64, now: Instant) {
        {
            if let Ok(buf) = self.buffer.lock() {
                self.metrics.buffer_depth = buf.len() as u64;
                self.metrics.buffer_evicted_total = buf.evicted_total;
                self.metrics.records_harvested_total = buf.pushed_total;
                if let Some(since) = self.swap_round {
                    if let Some(a) = buf.alpha_hat_since(since) {
                        self.metrics.alpha_hat_post = a;
                    }
                }
            }
        }
        if let Some(trainer) = self.trainer.as_mut() {
            match trainer.poll(now) {
                TrainerPoll::Running => {}
                TrainerPoll::Finished(Err(fault)) => {
                    // Typed, transient, contained: count it, keep the
                    // stale weights serving. Nothing downstream of the
                    // decode loop observes the failure.
                    debug_assert_eq!(fault.kind(), FaultKind::Transient);
                    self.trainer = None;
                    self.metrics.trainer_faults_total += 1;
                    self.metrics.trainer_state = 3;
                    self.faults.push(fault);
                }
                TrainerPoll::Finished(Ok(outcome)) => {
                    self.trainer = None;
                    self.metrics.trainer_state = 2;
                    // Validate-then-commit at a round boundary: the
                    // core re-reads + re-validates the checkpoint and
                    // only then replaces its live weights; ANY failure
                    // keeps the old weights (rollback = not swapping).
                    match core.swap_draft(&outcome.checkpoint) {
                        Ok(()) => {
                            self.epoch = outcome.epoch.max(self.epoch + 1);
                            self.metrics.swaps_total += 1;
                            if outcome.alpha_before.is_finite() {
                                self.metrics.alpha_hat_pre = outcome.alpha_before;
                            }
                            self.swap_round = Some(
                                self.buffer
                                    .lock()
                                    .map(|b| b.max_round() + 1)
                                    .unwrap_or(rounds),
                            );
                        }
                        Err(e) => {
                            self.metrics.swap_rollbacks_total += 1;
                            self.metrics.trainer_faults_total += 1;
                            self.faults.push(TrainerFault::Io {
                                message: format!("hot-swap rolled back: {e:#}"),
                            });
                        }
                    }
                }
            }
        }
        if self.trainer.is_none()
            && rounds.saturating_sub(self.last_launch_round) >= self.cfg.interval_rounds
        {
            let enough = self
                .buffer
                .lock()
                .map(|b| b.len() >= self.cfg.min_records)
                .unwrap_or(false);
            if enough {
                self.launch(rounds);
            }
        }
    }
}

/// Build an engine checkpoint-swap error with rollback context (shared
/// phrasing between the engine and sim cores).
pub fn swap_error(path: &Path, e: anyhow::Error) -> anyhow::Error {
    e.context(format!(
        "draft hot-swap validate failed for {} (old weights kept serving)",
        path.display()
    ))
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lk_adapt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn rec(session: u64, round: u64, slot: u8, accepted: bool) -> ReplayRecord {
        ReplayRecord {
            session,
            round,
            pos: 10 + slot as u32,
            slot,
            ctx: [-1, 7, 8, 9],
            draft: 1000 + slot as i32,
            accepted,
            q_draft: f32::NAN,
            p_target: f32::NAN,
        }
    }

    #[test]
    fn ring_bounded_fifo_eviction() {
        let mut buf = ReplayBuffer::new(3);
        for i in 0..5u64 {
            buf.push(rec(i, i, 0, true));
        }
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.pushed_total, 5);
        assert_eq!(buf.evicted_total, 2);
        // Oldest-first eviction: sessions 0 and 1 are gone.
        let sessions: Vec<u64> = buf.iter().map(|r| r.session).collect();
        assert_eq!(sessions, vec![2, 3, 4]);
    }

    #[test]
    fn transcript_jsonl_roundtrip() {
        let mut buf = ReplayBuffer::new(16);
        buf.push(ReplayRecord {
            q_draft: 0.25,
            p_target: 0.75,
            ..rec(1, 2, 0, true)
        });
        buf.push(rec(1, 2, 1, false)); // NaN q/p -> omitted fields
        let text = buf.to_jsonl();
        let back = ReplayBuffer::parse_jsonl(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].session, 1);
        assert_eq!(back[0].ctx, [-1, 7, 8, 9]);
        assert!((back[0].q_draft - 0.25).abs() < 1e-6);
        assert!((back[0].p_target - 0.75).abs() < 1e-6);
        assert!(back[0].accepted);
        assert!(!back[1].accepted);
        assert!(back[1].q_draft.is_nan() && back[1].p_target.is_nan());
        // File snapshot is parseable too (atomic write path).
        let path = tmpdir("rt").join("t.jsonl");
        assert_eq!(buf.snapshot_jsonl(&path).unwrap(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(ReplayBuffer::parse_jsonl(&text).unwrap().len(), 2);
    }

    #[test]
    fn harvest_parity_host_vs_device_shapes() {
        // The same verdict harvested host-style (probs present) and
        // device-style (verdict ints only) must agree on every
        // path-independent field — the parity the engine gets by
        // construction from the shared harvest_row entry point.
        let committed = vec![5, 6, 7, 8, 9];
        let drafts = vec![101, 102, 103, 104];
        let host = replay_sink(64);
        let dev = replay_sink(64);
        let probs = [(0.9f32, 0.8f32), (0.7, 0.6), (0.5, 0.1)];
        harvest_row(&host, 3, 12, 40, &committed, &drafts, 2, &probs);
        harvest_row(&dev, 3, 12, 40, &committed, &drafts, 2, &[]);
        let h = host.lock().unwrap();
        let d = dev.lock().unwrap();
        // n_acc = 2 over 4 drafts: accepted slots 0, 1 plus the first
        // rejection at slot 2 are judged; slot 3 never reached a
        // verdict and is NOT harvested.
        assert_eq!(h.len(), 3);
        assert_eq!(d.len(), 3);
        for (a, b) in h.iter().zip(d.iter()) {
            assert_eq!(a.session, b.session);
            assert_eq!(a.round, b.round);
            assert_eq!(a.pos, b.pos);
            assert_eq!(a.slot, b.slot);
            assert_eq!(a.ctx, b.ctx);
            assert_eq!(a.draft, b.draft);
            assert_eq!(a.accepted, b.accepted);
            assert!(b.q_draft.is_nan() && b.p_target.is_nan());
        }
        // Context chains through the speculated prefix: slot 2's tail
        // is [8, 9, 101, 102] (last committed ++ accepted drafts).
        let ctxs: Vec<[i32; CTX_TAIL]> = h.iter().map(|r| r.ctx).collect();
        assert_eq!(ctxs[0], [6, 7, 8, 9]);
        assert_eq!(ctxs[1], [7, 8, 9, 101]);
        assert_eq!(ctxs[2], [8, 9, 101, 102]);
        assert_eq!(h.iter().map(|r| r.accepted).collect::<Vec<_>>(), [true, true, false]);
        assert!((h.iter().next().unwrap().q_draft - 0.9).abs() < 1e-6);
    }

    #[test]
    fn sim_finetune_closes_the_gap() {
        let mut records = Vec::new();
        for i in 0..100u64 {
            records.push(rec(i, i, 0, i % 4 != 0)); // slot 0: alpha 0.75
            records.push(rec(i, i, 1, i % 2 == 0)); // slot 1: alpha 0.50
        }
        let (profile, a0, a1) = sim_finetune(&records, 3, 0.5);
        assert!((profile[0] - 0.875).abs() < 1e-9);
        assert!((profile[1] - 0.75).abs() < 1e-9);
        // Unexercised slot inherits its predecessor's fit.
        assert!((profile[2] - 0.75).abs() < 1e-9);
        assert!((a0 - 0.625).abs() < 1e-9);
        assert!(a1 > a0);
        // A gain of zero is the identity fit.
        let (p0, b0, b1) = sim_finetune(&records, 2, 0.0);
        assert!((p0[0] - 0.75).abs() < 1e-9 && (p0[1] - 0.5).abs() < 1e-9);
        assert!((b0 - b1).abs() < 1e-12);
    }

    #[test]
    fn sim_checkpoint_roundtrip_and_validation() {
        let dir = tmpdir("ckpt");
        let path = dir.join("draft_sim.json");
        write_sim_checkpoint(&path, 7, &[0.9, 0.6], 0.5, 0.75).unwrap();
        let (epoch, profile) = read_sim_checkpoint(&path).unwrap();
        assert_eq!(epoch, 7);
        assert_eq!(profile, vec![0.9, 0.6]);
        // Validation rejects wrong format and out-of-range entries.
        std::fs::write(&path, "{\"format\": \"other\"}").unwrap();
        assert!(read_sim_checkpoint(&path).is_err());
        std::fs::write(
            &path,
            "{\"format\": \"lkspec-sim-draft\", \"profile\": [1.5]}",
        )
        .unwrap();
        assert!(read_sim_checkpoint(&path).is_err());
    }

    #[test]
    fn trainer_protocol_happy_path() {
        let dir = tmpdir("ok");
        let ckpt = dir.join("out.json");
        write_sim_checkpoint(&ckpt, 1, &[0.5], 0.4, 0.7).unwrap();
        let script = format!(
            r#"printf '%s\n' '{{"kind":"start","payload":{{}}}}'; \
               printf '%s\n' '{{"kind":"progress","payload":{{"step":1,"loss":0.5}}}}'; \
               printf '%s\n' '{{"kind":"done","payload":{{"checkpoint":"{}","epoch":1,"alpha_before":0.4,"alpha_after":0.7}}}}'"#,
            ckpt.display()
        );
        let mut h =
            TrainerHandle::spawn(&["sh".into(), "-c".into(), script], &ckpt, Duration::from_secs(10))
                .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match h.poll(Instant::now()) {
                TrainerPoll::Running => {
                    assert!(Instant::now() < deadline, "trainer did not finish");
                    std::thread::sleep(Duration::from_millis(5));
                }
                TrainerPoll::Finished(r) => {
                    let out = r.expect("clean run");
                    assert_eq!(out.epoch, 1);
                    assert!((out.alpha_after - 0.7).abs() < 1e-9);
                    assert!(h.events.iter().any(|e| e.kind == "progress"));
                    break;
                }
            }
        }
    }

    fn run_to_fault(mut h: TrainerHandle) -> TrainerFault {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match h.poll(Instant::now()) {
                TrainerPoll::Running => {
                    assert!(Instant::now() < deadline, "fault never surfaced");
                    std::thread::sleep(Duration::from_millis(5));
                }
                TrainerPoll::Finished(r) => return r.expect_err("expected a fault"),
            }
        }
    }

    #[test]
    fn trainer_crash_hang_malformed_are_typed_transient() {
        let cfg = tmpdir("faults").join("cfg.json");
        std::fs::write(&cfg, "{}").unwrap();
        let crash = run_to_fault(
            TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "exit 3".into()],
                &cfg,
                Duration::from_secs(5),
            )
            .unwrap(),
        );
        assert!(matches!(crash, TrainerFault::Crashed { code: Some(3) }), "{crash}");
        let malformed = run_to_fault(
            TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "echo not-an-event".into()],
                &cfg,
                Duration::from_secs(5),
            )
            .unwrap(),
        );
        assert!(matches!(malformed, TrainerFault::Protocol { .. }), "{malformed}");
        let hang = run_to_fault(
            TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "sleep 30".into()],
                &cfg,
                Duration::from_millis(50),
            )
            .unwrap(),
        );
        assert!(matches!(hang, TrainerFault::Hang { .. }), "{hang}");
        // A clean exit without a done event is a protocol breach too.
        let silent = run_to_fault(
            TrainerHandle::spawn(
                &["sh".into(), "-c".into(), "true".into()],
                &cfg,
                Duration::from_secs(5),
            )
            .unwrap(),
        );
        assert!(matches!(silent, TrainerFault::Protocol { .. }), "{silent}");
        for f in [crash, malformed, hang, silent] {
            assert_eq!(f.kind(), FaultKind::Transient, "{f}");
        }
    }

    #[test]
    fn alpha_hat_windows_on_round() {
        let mut buf = ReplayBuffer::new(16);
        buf.push(rec(0, 1, 0, false));
        buf.push(rec(0, 1, 1, false));
        buf.push(rec(0, 5, 0, true));
        buf.push(rec(0, 6, 0, true));
        assert!((buf.alpha_hat_since(0).unwrap() - 0.5).abs() < 1e-9);
        assert!((buf.alpha_hat_since(5).unwrap() - 1.0).abs() < 1e-9);
        assert!(buf.alpha_hat_since(7).is_none());
        assert_eq!(buf.max_round(), 6);
    }
}
