//! Recurrent draft backend (EAGLE-3 / MTP): own KV cache + hidden-state
//! recurrence. Drafting chains `step` calls; bootstrap/advance extend the
//! draft KV with fused target features via the `extend_p` / `extend_k`
//! entries.
//!
//! Device verify path: the `*_sample` entries sample each draft token
//! in-graph from a host-fed uniform and keep the full-vocab q resident
//! as a literal; the extend entries additionally gather next round's
//! first draft (token + q + hidden) at the per-row accepted-prefix
//! index, so the old per-round `[B, T, Vd]` q-logits pull disappears.
//!
//! Multi-candidate (tree) drafting lives in [`RecurrentTree`]: the
//! drafter expands a candidate tree LEVEL-PARALLEL — one tree-attention
//! pass per level over all node slots (`tree_step_b{B}`), each node
//! recurring on its parent's hidden, with node `i`'s draft-KV entry at
//! slot `pos + i` — and the advance splices the accepted path's draft
//! KV back to consecutive slots (`dkv_path_gather_b{B}`, the draft twin
//! of the target's path splice; see the module-level per-path contract
//! in [`super`]) before the usual `extend_k` feature fusion over the
//! path-gathered verify features. The device path runs the whole
//! expansion in one `propose_tree_sample_b{B}` graph (node 0 is the
//! previous extend's in-graph first draft) and advances through
//! `extend_tree_sample_b{B}`, which linearizes the fused tree verify's
//! BLOCK-layout features in-graph — per round only O(B·N) ints cross to
//! the host, same as the MEDUSA tree.

use anyhow::{Context, Result};

use crate::runtime::{pack, DraftSpec, Runtime};
use crate::spec::sampling::TreeSpec;
use crate::tensor::HostTensor;

use super::{
    arg_refs, copy_kv_row_device, copy_literal_row, gather_kv_rows_device, lit_f32, lit_i32,
    lit_scalar_f32, lit_scalar_i32, lit_zeros_f32, migrate_hidden_rows, repack_literal_rows,
    spec_f32, tensor_row, upload, DraftBackend, EngineCx, GroupState, KvSide, QFlat,
    DKV_BATCH_AXIS, DUMMY_UNIFORM,
};

pub struct Recurrent;

/// Manifest entries the device path needs, per serve bucket.
const DEVICE_ENTRIES: [&str; 3] = ["step_sample", "extend_p_sample", "extend_k_sample"];

impl Recurrent {
    /// Chain-layout start position of a round's block for one row —
    /// where the verify block began, i.e. where the advance's extend
    /// writes from. `j` is the accepted prefix/path length; called
    /// POST-VERDICT (`len` already advanced past the accepted tokens).
    /// The single definition shared by the chain advances and the tree
    /// splice so the conventions can never drift apart.
    fn block_start(seq: &super::SeqState, j: usize) -> i32 {
        if seq.done {
            seq.len.saturating_sub(1 + j) as i32
        } else {
            (seq.len - 1 - j) as i32
        }
    }

    /// Shared host-path extend tail: run `extend_k_b{B}` over
    /// chain-layout fusion features / next-tokens / start positions and
    /// pick up next round's first-draft q-logits + hidden at `pick[row]`
    /// (the accepted prefix/path length). Used by the chain `advance`
    /// and, with path-gathered features, by the tree advance.
    fn extend_host(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        feats_in: &[f32],
        tnext: &[i32],
        pos: &[i32],
        pick: &[usize],
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_k_b{b}"))?;
        let dyn_in = [
            g.dkv.take().context("dkv")?,
            lit_f32(&[b, vt, fdim], feats_in)?,
            lit_i32(&[b, vt], tnext)?,
            lit_i32(&[b], pos)?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?;
        let h_all = extend.output_host(&outs, 1)?;
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for row in 0..b {
            let j = pick[row];
            let seq = &mut g.seqs[row];
            seq.q1 = tensor_row(&q_all, row, &[b, vt, vd], j);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, vt, d], j));
        }
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    /// Draft-side path splice (`dkv_path_gather_b{B}`): per row, gather
    /// the draft-KV entries at the accepted path's absolute positions
    /// and scatter them linearly from the round's block start `pos0` —
    /// the draft twin of the engine's target `kv_path_gather` call, run
    /// in the same round (see the module-level per-path contract). Rows
    /// with an empty path splice the identity (a no-op).
    fn splice_dkv_path(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        paths: &[Vec<usize>],
        pos0: &[i32],
    ) -> Result<()> {
        let b = g.b;
        let kq = cx.rt.manifest.verify_t - 1;
        let mut sel = vec![0i32; b * kq];
        for row in 0..b {
            for (t, s) in sel[row * kq..(row + 1) * kq].iter_mut().enumerate() {
                *s = pos0[row] + t as i32; // identity default
            }
            for (t, &node) in paths[row].iter().enumerate() {
                sel[row * kq + t] = pos0[row] + node as i32;
            }
        }
        let gather = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("dkv_path_gather_b{b}"))?;
        let dkv = g.dkv.take().context("splice: dkv")?;
        let sel_lit = lit_i32(&[b, kq], &sel)?;
        let dst0_lit = lit_i32(&[b], pos0)?;
        let outs = gather.run_lits(&[&dkv, &sel_lit, &dst0_lit])?;
        g.dkv = outs.into_iter().next();
        Ok(())
    }

    /// Shared tail of the device-path extend calls: run the given
    /// `extend_*_sample` entry and adopt its (token0, q0, h_sel, dkv')
    /// outputs as next round's first-draft state.
    fn run_extend_sample(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        entry: &str,
        mut dyn_in: Vec<xla::Literal>,
    ) -> Result<()> {
        if let Some(vm) = cx.vocab_map_lit()? {
            dyn_in.push(vm);
        }
        let exe = cx.rt.draft_entry(&cx.dspec.name, entry)?;
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = exe.run_bufs(&args)?;
        let tok0 = exe.output_host(&outs, 0)?; // [B] i32 — O(B) ints
        g.tok0 = tok0.as_i32();
        g.dkv_spec = Some(exe.spec.outputs[3].clone());
        let mut it = outs.into_iter();
        let _tok0_lit = it.next();
        g.q0_dev = it.next();
        g.h_prev = it.next();
        g.dkv = it.next();
        Ok(())
    }
}

impl DraftBackend for Recurrent {
    fn name(&self) -> &'static str {
        "recurrent"
    }

    fn max_k(&self, rt: &Runtime, _dspec: &DraftSpec) -> usize {
        // May exceed the K=6 trained heads up to verify_t - 1 = 7.
        rt.manifest.verify_t - 1
    }

    fn supports_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest.serve_batches.iter().all(|&b| {
            DEVICE_ENTRIES
                .iter()
                .all(|e| rt.has_draft_entry(&dspec.name, &format!("{e}_b{b}")))
        })
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let sp = cx.rt.manifest.prompt_len;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let mut tnext = vec![0i32; b * sp];
        for (row, seq) in g.seqs.iter().enumerate() {
            let c = seq.len;
            for t in 0..c - 1 {
                tnext[row * sp + t] = tok_flat[row * sp + t + 1];
            }
            tnext[row * sp + c - 1] = seq.last_token;
        }
        let dkv0 = lit_zeros_f32(&[
            2,
            b,
            cx.tspec.n_heads,
            cx.tspec.max_seq,
            cx.tspec.head_dim,
        ])?;

        if cx.device_verify {
            // Device path: feed the FULL [B, Sp, 3d] prefill features
            // (the entry slices its fusion columns in-graph) and let the
            // entry sample the first round's draft 0 at sel = len-1.
            let sel: Vec<i32> = g.seqs.iter().map(|s| (s.len - 1) as i32).collect();
            let u: Vec<f32> = g
                .seqs
                .iter_mut()
                .map(|s| cx.draft_uniform(&mut s.rng))
                .collect();
            let dyn_in = vec![
                dkv0,
                pack::to_literal(feats)?,
                lit_i32(&[b, sp], &tnext)?,
                lit_i32(&[b], &vec![0i32; b])?,
                lit_i32(&[b], &sel)?,
                lit_f32(&[b], &u)?,
                lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
                lit_scalar_i32(cx.opts.mode.device_code())?,
            ];
            return self.run_extend_sample(cx, g, &format!("extend_p_sample_b{b}"), dyn_in);
        }

        // Host path: slice the fusion columns here and pull the q/h
        // planes back for host-side pickup.
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * sp * fdim];
        for row in 0..b {
            for t in 0..sp {
                let base = (row * sp + t) * f3;
                feats_in[(row * sp + t) * fdim..(row * sp + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
        }
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_p_b{b}"))?;
        let dyn_in = [
            dkv0,
            lit_f32(&[b, sp, fdim], &feats_in)?,
            lit_i32(&[b, sp], &tnext)?,
            lit_i32(&[b], &vec![0i32; b])?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?; // [B,Sp,Vd]
        let h_all = extend.output_host(&outs, 1)?; // [B,Sp,d]
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            let c = seq.len;
            seq.q1 = tensor_row(&q_all, row, &[b, sp, vd], c - 1);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, sp, d], c - 1));
        }
        g.dkv_spec = Some(extend.spec.outputs[2].clone());
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_b{b}"))?;
        let vd = cx.dspec.draft_vocab;
        let mut q_logits: Vec<Vec<f32>> = g.seqs.iter().map(|s| s.q1.clone()).collect();
        for i in 0..k {
            let mut toks = vec![0i32; b];
            for row in 0..b {
                let (full, compact) = q.slot(row, i);
                cx.write_draft_dist(&q_logits[row], compact, full);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, compact);
                drafts[row][i] = cx.draft_token_id(xi);
                toks[row] = drafts[row][i];
            }
            if i + 1 == k {
                break; // q_{k+1} never needed
            }
            let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i) as i32).collect();
            let dyn_in = [
                g.dkv.take().context("dkv")?,
                g.h_prev.take().context("h_prev")?,
                lit_i32(&[b], &toks)?,
                lit_i32(&[b], &pos)?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let ql = step.output_host(&outs, 0)?;
            for row in 0..b {
                q_logits[row] = tensor_row(&ql, row, &[b, vd], 0);
            }
            let mut it = outs.into_iter();
            let _ = it.next(); // logits
            g.h_prev = Some(it.next().unwrap());
            g.dkv = Some(it.next().unwrap());
        }
        Ok(())
    }

    fn propose_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        // Position 0 was sampled in-graph by the previous extend call
        // (stream-order-identical to the host path's first propose draw).
        anyhow::ensure!(
            g.tok0.len() == b && g.q0_dev.is_some(),
            "device propose without extend-sampled first draft"
        );
        for (row, d) in drafts.iter_mut().enumerate() {
            d[0] = g.tok0[row];
        }
        q_dev.push(g.q0_dev.take().unwrap());
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_sample_b{b}"))?;
        let mut toks: Vec<i32> = drafts.iter().map(|d| d[0]).collect();
        for i in 1..k {
            let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i - 1) as i32).collect();
            let u: Vec<f32> = g
                .seqs
                .iter_mut()
                .map(|s| cx.draft_uniform(&mut s.rng))
                .collect();
            let mut dyn_in = vec![
                g.dkv.take().context("dkv")?,
                g.h_prev.take().context("h_prev")?,
                lit_i32(&[b], &toks)?,
                lit_i32(&[b], &pos)?,
                lit_f32(&[b], &u)?,
                lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
                lit_scalar_i32(cx.opts.mode.device_code())?,
            ];
            if let Some(vm) = cx.vocab_map_lit()? {
                dyn_in.push(vm);
            }
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let tok = step.output_host(&outs, 0)?.as_i32(); // [B] — O(B) ints
            for (row, d) in drafts.iter_mut().enumerate() {
                d[i] = tok[row];
            }
            toks = tok;
            let mut it = outs.into_iter();
            let _tok_lit = it.next();
            q_dev.push(it.next().unwrap());
            g.h_prev = it.next();
            g.dkv = it.next();
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * vt * fdim];
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = n_acc[row];
            for t in 0..vt {
                let base = (row * vt + t) * f3;
                feats_in[(row * vt + t) * fdim..(row * vt + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
            for (t, item) in drafts[row].iter().enumerate().take(j) {
                tnext[row * vt + t] = *item;
            }
            tnext[row * vt + j] = seq.last_token;
            // extend starts where this round's verify block started
            pos[row] = Self::block_start(seq, j);
        }
        self.extend_host(cx, g, &feats_in, &tnext, &pos, n_acc)
    }

    fn advance_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        n_acc_lit: xla::Literal,
        feats: xla::Literal,
        _h_sel: xla::Literal,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = n_acc[row];
            for (t, item) in drafts[row].iter().enumerate().take(j) {
                tnext[row * vt + t] = *item;
            }
            tnext[row * vt + j] = seq.last_token;
            pos[row] = Self::block_start(seq, j);
        }
        // Next round's first-draft uniform, drawn NOW so the per-stream
        // order matches the host path (which draws it first thing in the
        // next propose).
        let u: Vec<f32> = g
            .seqs
            .iter_mut()
            .map(|s| cx.draft_uniform(&mut s.rng))
            .collect();
        let dyn_in = vec![
            g.dkv.take().context("dkv")?,
            feats, // verify_fused output, fed back without a host pull
            lit_i32(&[b, vt], &tnext)?,
            lit_i32(&[b], &pos)?,
            n_acc_lit, // per-row q/h gather index, in-graph
            lit_f32(&[b], &u)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        self.run_extend_sample(cx, g, &format!("extend_k_sample_b{b}"), dyn_in)
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        // Draft KV row: device splice when the artifact carries the
        // entry, host strided copy otherwise.
        let dst_dkv = dst.dkv.take().context("adopt_row: dst dkv")?;
        let src_dkv = src.dkv.as_ref().context("adopt_row: src dkv")?;
        let dkv = match copy_kv_row_device(cx, KvSide::Draft, dst.b, src.b, &dst_dkv, src_dkv, dst_row)?
        {
            Some(dkv) => dkv,
            None => copy_literal_row(
                &dst_dkv,
                dst.dkv_spec.as_ref().context("adopt_row: dst dkv spec")?,
                dst_row,
                src_dkv,
                src.dkv_spec.as_ref().context("adopt_row: src dkv spec")?,
                src_row,
                DKV_BATCH_AXIS,
            )?,
        };
        dst.dkv = Some(dkv);
        // Hidden carry row [B, d].
        let d = cx.tspec.d_model;
        let dst_h = dst.h_prev.take().context("adopt_row: dst h_prev")?;
        let h = copy_literal_row(
            &dst_h,
            &spec_f32(vec![dst.b, d]),
            dst_row,
            src.h_prev.as_ref().context("adopt_row: src h_prev")?,
            &spec_f32(vec![src.b, d]),
            src_row,
            0,
        )?;
        dst.h_prev = Some(h);
        // Device path: the extend-sampled first-draft q row rides along
        // (tok0 is moved by the engine with the session state).
        if cx.device_verify {
            let v = cx.tspec.vocab;
            let dst_q = dst.q0_dev.take().context("adopt_row: dst q0")?;
            let q = copy_literal_row(
                &dst_q,
                &spec_f32(vec![dst.b, v]),
                dst_row,
                src.q0_dev.as_ref().context("adopt_row: src q0")?,
                &spec_f32(vec![src.b, v]),
                src_row,
                0,
            )?;
            dst.q0_dev = Some(q);
        }
        Ok(())
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        // Packed draft KV: device-side row gather — zero draft-KV bytes
        // through the host (the entry covers every ordered bucket pair;
        // older artifact sets must be re-lowered).
        let src_dkv = src.dkv.as_ref().context("migrate_rows: src dkv")?;
        let src_spec = src.dkv_spec.as_ref().context("migrate_rows: src dkv spec")?;
        let dkv = match gather_kv_rows_device(cx, KvSide::Draft, src.b, dst.b, src_dkv, src_map)? {
            Some(dkv) => dkv,
            None => anyhow::bail!(
                "migrate_rows: artifact set lacks dkv_gather_rows_b{}x{} — \
                 re-lower the artifacts: python/compile/aot.py",
                src.b,
                dst.b
            ),
        };
        let mut dkv_spec = src_spec.clone();
        dkv_spec.name = String::new();
        dkv_spec.shape[DKV_BATCH_AXIS] = dst.b;
        dst.dkv = Some(dkv);
        dst.dkv_spec = Some(dkv_spec);
        // Hidden carry [B, d] (both paths for recurrent archs).
        migrate_hidden_rows(cx, dst, src, src_map)?;
        // Device path: the extend-sampled first-draft q row rides along
        // (tok0 is moved by the engine with the session state).
        if cx.device_verify {
            let v = cx.tspec.vocab;
            let src_q = src.q0_dev.as_ref().context("migrate_rows: src q0")?;
            let (q, _) = repack_literal_rows(src_q, &spec_f32(vec![src.b, v]), src_map, 0)?;
            dst.q0_dev = Some(q);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// multi-candidate (tree) drafting over the recurrent drafter
// ---------------------------------------------------------------------------

/// Tree drafting for the recurrent (EAGLE-3 / MTP) family — the
/// highest-alpha drafter feeding the multi-candidate tree verify.
///
/// Unlike MEDUSA's token-independent heads, the recurrent drafter's
/// candidates are PATH-DEPENDENT: a node's distribution conditions on
/// its ancestor candidates through the hidden recurrence and the draft
/// KV. The expansion is level-parallel (`tree_step_b{B}`): one
/// tree-attention pass over all node slots per level, node `i`'s KV at
/// draft slot `pos + i`, input hidden = its parent's output hidden.
/// Level 0 samples from the round's `q1` (host) / resident `q0`
/// (device) — exactly where a chain round's first draft comes from —
/// so `depth - 1` passes expand any topology, and a chain topology
/// replays the chained `draft_step` path (chain degeneracy,
/// property-tested in `tests/properties.rs` and at the graph level in
/// `python/tests/test_recurrent_tree.py`).
///
/// Candidate selection per node follows the fixed-uniform contract
/// (one draft draw per node in node order; greedy takes
/// sibling-rank-th-largest); the advance owns the draft-side path
/// splice (`dkv_path_gather_b{B}`) and then re-extends over the
/// path-gathered verify features — see the module-level per-path
/// draft-KV contract.
pub struct RecurrentTree;

/// Host-path manifest entries the tree duties need, per serve bucket.
const TREE_HOST_ENTRIES: [&str; 2] = ["tree_step", "dkv_path_gather"];
/// Device-path additions (on top of the chain `DEVICE_ENTRIES`, which
/// the bootstrap/advance flow still uses).
const TREE_DEVICE_ENTRIES: [&str; 3] =
    ["propose_tree_sample", "extend_tree_sample", "dkv_path_gather"];

impl RecurrentTree {
    /// Chain-row -> block-slot gather map for one row: row 0 is the
    /// root (slot 0), row `t <= j` the t-th accepted node's slot, rows
    /// past the path clamp to the stop slot (their values feed only
    /// overwritten-or-masked state; see the module contract).
    fn blk_map(path: &[usize], vt: usize, out: &mut [i32]) {
        let mut cur = 0i32;
        for (t, slot) in out.iter_mut().enumerate().take(vt) {
            if t >= 1 && t <= path.len() {
                cur = path[t - 1] as i32 + 1;
            }
            *slot = cur;
        }
    }
}

impl DraftBackend for RecurrentTree {
    fn name(&self) -> &'static str {
        "recurrent-tree"
    }

    fn max_k(&self, rt: &Runtime, dspec: &DraftSpec) -> usize {
        Recurrent.max_k(rt, dspec)
    }

    /// Chained cost: every tree LEVEL is one more `tree_step` dispatch
    /// (siblings ride the same batched pass), so the planner prices
    /// depth and treats width as near-free — the opposite regime from
    /// MEDUSA's free parallel heads.
    fn cost_model(&self) -> crate::spec::adaptive::CostModel {
        Recurrent.cost_model()
    }

    fn supports_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        Recurrent.supports_device(rt, dspec)
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        Recurrent.bootstrap(cx, g, tok_flat, feats)
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        Recurrent.propose(cx, g, k, drafts, q)
    }

    fn propose_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        Recurrent.propose_device(cx, g, k, drafts, q_dev)
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        Recurrent.advance(cx, g, drafts, n_acc, feats)
    }

    fn advance_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        n_acc_lit: xla::Literal,
        feats: xla::Literal,
        h_sel: xla::Literal,
    ) -> Result<()> {
        Recurrent.advance_device(cx, g, drafts, n_acc, n_acc_lit, feats, h_sel)
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        Recurrent.adopt_row(cx, dst, dst_row, src, src_row)
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        Recurrent.migrate_rows(cx, dst, src, src_map)
    }

    // ------------------------------------------------------------------
    // tree duties
    // ------------------------------------------------------------------

    fn supports_tree(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest.serve_batches.iter().all(|&b| {
            TREE_HOST_ENTRIES
                .iter()
                .all(|e| rt.has_draft_entry(&dspec.name, &format!("{e}_b{b}")))
        })
    }

    /// Host-path tree proposal: level 0 samples siblings from the
    /// round's `q1` logits, then one `tree_step_b{B}` call per deeper
    /// level expands all of that level's nodes from their parents'
    /// hiddens in one batched tree-attention pass (the engine pulls the
    /// `[B, N, Vd]` q-logits per call — the host path's nature). A
    /// depth-d tree costs d-1 draft dispatches, same as a d-chain.
    fn propose_tree(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tree: &TreeSpec,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let n = tree.len();
        let kq = cx.rt.manifest.verify_t - 1;
        let d = cx.tspec.d_model;
        let vd = cx.dspec.draft_vocab;
        let depth = tree.depth();
        let mut rank_scratch = Vec::new();
        // --- level 0: the extend-produced first-draft distribution ----
        for row in 0..b {
            for node in 0..n {
                if tree.level(node) != 0 {
                    break; // BFS order: level-0 nodes are a prefix
                }
                let (full, compact) = q.slot(row, node);
                cx.write_draft_dist(&g.seqs[row].q1, compact, full);
                let xi = cx.sample_draft_tree(
                    &mut g.seqs[row].rng,
                    compact,
                    tree.rank(node),
                    &mut rank_scratch,
                );
                drafts[row][node] = cx.draft_token_id(xi);
            }
        }
        if depth <= 1 {
            return Ok(());
        }
        // --- levels 1..depth: one batched tree_step per level ---------
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("tree_step_b{b}"))?;
        let parents_lit = lit_i32(&[kq], &tree.parents_padded(kq))?;
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        let pos_lit = lit_i32(&[b], &pos)?;
        // h_prev/parents/pos are reused across the level calls: upload
        // once, keep the literals alive through the loop (async-copy
        // safety contract).
        let h_prev_lit = g.h_prev.take().context("tree propose: h_prev")?;
        let h_prev_buf = cx.rt.to_buffer(&h_prev_lit)?;
        let parents_buf = cx.rt.to_buffer(&parents_lit)?;
        let pos_buf = cx.rt.to_buffer(&pos_lit)?;
        let mut dkv = g.dkv.take().context("tree propose: dkv")?;
        let mut h_all: Option<xla::Literal> = None;
        for lvl in 1..depth {
            let mut toks = vec![0i32; b * kq];
            for (row, dr) in drafts.iter().enumerate() {
                for (i, &t) in dr.iter().enumerate() {
                    toks[row * kq + i] = t;
                }
            }
            let h_all_lit = match h_all.take() {
                Some(h) => h,
                None => lit_zeros_f32(&[b, kq, d])?,
            };
            let own_in = [dkv, h_all_lit, lit_i32(&[b, kq], &toks)?];
            let own_b = upload(cx.rt, &own_in)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                cx.tparams.iter().chain(cx.dparams.iter()).collect();
            args.push(&own_b[0]); // dkv
            args.push(&h_prev_buf);
            args.push(&own_b[1]); // h_all
            args.push(&own_b[2]); // tokens
            args.push(&pos_buf);
            args.push(&parents_buf);
            let outs = step.run_bufs(&args)?;
            let qlog = step.output_host(&outs, 0)?; // [B, kq, Vd]
            for row in 0..b {
                for node in 0..n {
                    if tree.level(node) != lvl {
                        continue;
                    }
                    let parent = tree.parent(node) as usize;
                    let lrow = tensor_row(&qlog, row, &[b, kq, vd], parent);
                    let (full, compact) = q.slot(row, node);
                    cx.write_draft_dist(&lrow, compact, full);
                    let xi = cx.sample_draft_tree(
                        &mut g.seqs[row].rng,
                        compact,
                        tree.rank(node),
                        &mut rank_scratch,
                    );
                    drafts[row][node] = cx.draft_token_id(xi);
                }
            }
            let mut it = outs.into_iter();
            let _qlog_lit = it.next();
            h_all = it.next();
            dkv = it.next().context("tree_step: dkv out")?;
        }
        g.dkv = Some(dkv);
        g.h_prev = Some(h_prev_lit);
        Ok(())
    }

    /// Tree advance: splice the accepted path's draft KV to consecutive
    /// slots, then run the SAME `extend_k` feature fusion a chain round
    /// would — over the path-gathered verify features and the accepted
    /// tokens — picking up next round's q1/hidden at the path length.
    fn advance_tree(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        paths: &[Vec<usize>],
        _stop_blk: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * vt * fdim];
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        let mut pick = vec![0usize; b];
        let mut blk = vec![0i32; vt];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = paths[row].len();
            pick[row] = j;
            pos[row] = Recurrent::block_start(seq, j);
            Self::blk_map(&paths[row], vt, &mut blk);
            for t in 0..vt {
                let base = (row * vt + blk[t] as usize) * f3;
                feats_in[(row * vt + t) * fdim..(row * vt + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
            for (t, &node) in paths[row].iter().enumerate() {
                tnext[row * vt + t] = drafts[row][node];
            }
            tnext[row * vt + j] = seq.last_token;
        }
        Recurrent.splice_dkv_path(cx, g, paths, &pos)?;
        Recurrent.extend_host(cx, g, &feats_in, &tnext, &pos, &pick)
    }

    fn supports_tree_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        // The device tree flow still bootstraps/extends through the
        // chain device entries (tok0/q0 ride from extend_*_sample).
        Recurrent.supports_device(rt, dspec)
            && rt.manifest.serve_batches.iter().all(|&b| {
                TREE_DEVICE_ENTRIES
                    .iter()
                    .all(|e| rt.has_draft_entry(&dspec.name, &format!("{e}_b{b}")))
            })
    }

    /// Stateful: the advances build the draft-splice maps (sel/blk)
    /// from the accepted-path node indices.
    fn tree_paths_needed(&self) -> bool {
        true
    }

    /// Device-path tree proposal: one `propose_tree_sample_b{B}` call
    /// runs the whole level-parallel expansion in-graph. Node 0 is the
    /// previous extend's in-graph first draft (tok0/q0, device-resident
    /// — its uniform was drawn at that advance, the chain convention);
    /// the host draws uniforms for nodes 1.. now, in node order. Only
    /// the candidate ids come back (a `[B, Vt-1]` tensor — lowered node
    /// slots — with the first `n` live); the per-node q tensors flow
    /// straight into `verify_tree_fused_b{B}`.
    fn propose_tree_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tree: &TreeSpec,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        let n = tree.len();
        let kq = cx.rt.manifest.verify_t - 1;
        anyhow::ensure!(
            g.tok0.len() == b && g.q0_dev.is_some(),
            "device tree propose without extend-sampled first draft"
        );
        let mut u = vec![DUMMY_UNIFORM; b * kq];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            for i in 1..n {
                u[row * kq + i] = cx.draft_uniform(&mut seq.rng);
            }
        }
        let ranks: Vec<i32> = (0..kq)
            .map(|i| if i < n { tree.rank(i) as i32 } else { 0 })
            .collect();
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_tree_sample_b{b}"))?;
        let mut dyn_in = vec![
            g.dkv.take().context("tree propose: dkv")?,
            g.h_prev.take().context("tree propose: h_prev")?,
            lit_i32(&[b], &g.tok0)?,
            g.q0_dev.take().context("tree propose: q0")?,
            lit_f32(&[b, kq], &u)?,
            lit_i32(&[kq], &tree.parents_padded(kq))?,
            lit_i32(&[kq], &ranks)?,
            lit_i32(&[b], &pos)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        if let Some(vm) = cx.vocab_map_lit()? {
            dyn_in.push(vm);
        }
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = propose.run_bufs(&args)?;
        // [B, Vt-1]: the entry is lowered at kq node slots; the first n
        // are this round's live candidates (row stride is kq, not n).
        let toks = propose.output_host(&outs, 0)?.as_i32();
        for (row, dr) in drafts.iter_mut().enumerate() {
            for (i, slot) in dr.iter_mut().enumerate() {
                *slot = toks[row * kq + i];
            }
        }
        let mut it = outs.into_iter();
        let _toks_lit = it.next();
        for _ in 0..kq {
            q_dev.push(it.next().context("tree propose: q out")?);
        }
        g.dkv = it.next();
        Ok(())
    }

    /// Device-path tree advance: draft-KV path splice, then
    /// `extend_tree_sample_b{B}` — the extend_k_sample flow with the
    /// fused verify's BLOCK-layout features linearized in-graph (blk
    /// maps chain row -> block slot) and next round's first draft
    /// sampled at the in-graph path-length index (`n_path_lit`).
    fn advance_tree_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        paths: &[Vec<usize>],
        n_path_lit: xla::Literal,
        feats: xla::Literal,
        _h_sel: xla::Literal,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let mut tnext = vec![0i32; b * vt];
        let mut blk = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = paths[row].len();
            pos[row] = Recurrent::block_start(seq, j);
            Self::blk_map(&paths[row], vt, &mut blk[row * vt..(row + 1) * vt]);
            for (t, &node) in paths[row].iter().enumerate() {
                tnext[row * vt + t] = drafts[row][node];
            }
            tnext[row * vt + j] = seq.last_token;
        }
        Recurrent.splice_dkv_path(cx, g, paths, &pos)?;
        // Next round's first-draft uniform, drawn NOW so the per-stream
        // order matches the host path (node 0 of the next propose).
        let u: Vec<f32> = g
            .seqs
            .iter_mut()
            .map(|s| cx.draft_uniform(&mut s.rng))
            .collect();
        let dyn_in = vec![
            g.dkv.take().context("tree advance: dkv")?,
            feats, // verify_tree_fused output, fed back without a pull
            lit_i32(&[b, vt], &blk)?,
            lit_i32(&[b, vt], &tnext)?,
            lit_i32(&[b], &pos)?,
            n_path_lit, // per-row q/h gather index, in-graph
            lit_f32(&[b], &u)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        Recurrent.run_extend_sample(cx, g, &format!("extend_tree_sample_b{b}"), dyn_in)
    }
}
