//! Recurrent draft backend (EAGLE-3 / MTP): own KV cache + hidden-state
//! recurrence. Drafting chains `step` calls; bootstrap/advance extend the
//! draft KV with fused target features via the `extend_p` / `extend_k`
//! entries.

use anyhow::{Context, Result};

use crate::runtime::{DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    arg_refs, copy_literal_row, lit_f32, lit_i32, lit_zeros_f32, spec_f32, tensor_row, upload,
    DraftBackend, EngineCx, GroupState, DKV_BATCH_AXIS,
};

pub struct Recurrent;

impl DraftBackend for Recurrent {
    fn name(&self) -> &'static str {
        "recurrent"
    }

    fn max_k(&self, rt: &Runtime, _dspec: &DraftSpec) -> usize {
        // May exceed the K=6 trained heads up to verify_t - 1 = 7.
        rt.manifest.verify_t - 1
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let sp = cx.rt.manifest.prompt_len;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * sp * fdim];
        let mut tnext = vec![0i32; b * sp];
        for (row, seq) in g.seqs.iter().enumerate() {
            let c = seq.len;
            for t in 0..sp {
                let base = (row * sp + t) * f3;
                feats_in[(row * sp + t) * fdim..(row * sp + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
            for t in 0..c - 1 {
                tnext[row * sp + t] = tok_flat[row * sp + t + 1];
            }
            tnext[row * sp + c - 1] = seq.last_token;
        }
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_p_b{b}"))?;
        let dkv0 = lit_zeros_f32(&[
            2,
            b,
            cx.tspec.n_heads,
            cx.tspec.max_seq,
            cx.tspec.head_dim,
        ])?;
        let dyn_in = [
            dkv0,
            lit_f32(&[b, sp, fdim], &feats_in)?,
            lit_i32(&[b, sp], &tnext)?,
            lit_i32(&[b], &vec![0i32; b])?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?; // [B,Sp,Vd]
        let h_all = extend.output_host(&outs, 1)?; // [B,Sp,d]
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            let c = seq.len;
            seq.q1 = tensor_row(&q_all, row, &[b, sp, vd], c - 1);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, sp, d], c - 1));
        }
        g.dkv_spec = Some(extend.spec.outputs[2].clone());
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &mut [Vec<i32>],
        q_full: &mut [Vec<Vec<f32>>],
    ) -> Result<()> {
        let b = g.b;
        let k = cx.k;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_b{b}"))?;
        let vd = cx.dspec.draft_vocab;
        let mut q_logits: Vec<Vec<f32>> = g.seqs.iter().map(|s| s.q1.clone()).collect();
        for i in 0..k {
            let mut toks = vec![0i32; b];
            for row in 0..b {
                let (qf, qc) = cx.draft_dist(&q_logits[row]);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, &qc);
                drafts[row][i] = cx.draft_token_id(xi);
                q_full[row].push(qf);
                toks[row] = drafts[row][i];
            }
            if i + 1 == k {
                break; // q_{k+1} never needed
            }
            let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i) as i32).collect();
            let dyn_in = [
                g.dkv.take().context("dkv")?,
                g.h_prev.take().context("h_prev")?,
                lit_i32(&[b], &toks)?,
                lit_i32(&[b], &pos)?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let ql = step.output_host(&outs, 0)?;
            for row in 0..b {
                q_logits[row] = tensor_row(&ql, row, &[b, vd], 0);
            }
            let mut it = outs.into_iter();
            let _ = it.next(); // logits
            g.h_prev = Some(it.next().unwrap());
            g.dkv = Some(it.next().unwrap());
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * vt * fdim];
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = n_acc[row];
            for t in 0..vt {
                let base = (row * vt + t) * f3;
                feats_in[(row * vt + t) * fdim..(row * vt + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
            for (t, item) in drafts[row].iter().enumerate().take(j) {
                tnext[row * vt + t] = *item;
            }
            tnext[row * vt + j] = seq.last_token;
            // extend starts where this round's verify block started
            pos[row] = if seq.done {
                (seq.len.saturating_sub(1 + j)) as i32
            } else {
                (seq.len - 1 - j) as i32
            };
        }
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_k_b{b}"))?;
        let dyn_in = [
            g.dkv.take().context("dkv")?,
            lit_f32(&[b, vt, fdim], &feats_in)?,
            lit_i32(&[b, vt], &tnext)?,
            lit_i32(&[b], &pos)?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?;
        let h_all = extend.output_host(&outs, 1)?;
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for row in 0..b {
            let j = n_acc[row];
            let seq = &mut g.seqs[row];
            seq.q1 = tensor_row(&q_all, row, &[b, vt, vd], j);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, vt, d], j));
        }
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        // Draft KV row.
        let dst_dkv = dst.dkv.take().context("adopt_row: dst dkv")?;
        let dkv = copy_literal_row(
            &dst_dkv,
            dst.dkv_spec.as_ref().context("adopt_row: dst dkv spec")?,
            dst_row,
            src.dkv.as_ref().context("adopt_row: src dkv")?,
            src.dkv_spec.as_ref().context("adopt_row: src dkv spec")?,
            src_row,
            DKV_BATCH_AXIS,
        )?;
        dst.dkv = Some(dkv);
        // Hidden carry row [B, d].
        let d = cx.tspec.d_model;
        let dst_h = dst.h_prev.take().context("adopt_row: dst h_prev")?;
        let h = copy_literal_row(
            &dst_h,
            &spec_f32(vec![dst.b, d]),
            dst_row,
            src.h_prev.as_ref().context("adopt_row: src h_prev")?,
            &spec_f32(vec![src.b, d]),
            src_row,
            0,
        )?;
        dst.h_prev = Some(h);
        Ok(())
    }
}
