//! Recurrent draft backend (EAGLE-3 / MTP): own KV cache + hidden-state
//! recurrence. Drafting chains `step` calls; bootstrap/advance extend the
//! draft KV with fused target features via the `extend_p` / `extend_k`
//! entries.
//!
//! Device verify path: the `*_sample` entries sample each draft token
//! in-graph from a host-fed uniform and keep the full-vocab q resident
//! as a literal; the extend entries additionally gather next round's
//! first draft (token + q + hidden) at the per-row accepted-prefix
//! index, so the old per-round `[B, T, Vd]` q-logits pull disappears.

use anyhow::{Context, Result};

use crate::runtime::{pack, DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    arg_refs, copy_kv_row_device, copy_literal_row, lit_f32, lit_i32, lit_scalar_f32,
    lit_scalar_i32, lit_zeros_f32, migrate_hidden_rows, repack_literal_rows, spec_f32,
    tensor_row, upload, DraftBackend, EngineCx, GroupState, KvSide, QFlat, DKV_BATCH_AXIS,
};

pub struct Recurrent;

/// Manifest entries the device path needs, per serve bucket.
const DEVICE_ENTRIES: [&str; 3] = ["step_sample", "extend_p_sample", "extend_k_sample"];

impl Recurrent {
    /// Shared tail of the device-path extend calls: run the given
    /// `extend_*_sample` entry and adopt its (token0, q0, h_sel, dkv')
    /// outputs as next round's first-draft state.
    fn run_extend_sample(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        entry: &str,
        mut dyn_in: Vec<xla::Literal>,
    ) -> Result<()> {
        if let Some(vm) = cx.vocab_map_lit()? {
            dyn_in.push(vm);
        }
        let exe = cx.rt.draft_entry(&cx.dspec.name, entry)?;
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = exe.run_bufs(&args)?;
        let tok0 = exe.output_host(&outs, 0)?; // [B] i32 — O(B) ints
        g.tok0 = tok0.as_i32();
        g.dkv_spec = Some(exe.spec.outputs[3].clone());
        let mut it = outs.into_iter();
        let _tok0_lit = it.next();
        g.q0_dev = it.next();
        g.h_prev = it.next();
        g.dkv = it.next();
        Ok(())
    }
}

impl DraftBackend for Recurrent {
    fn name(&self) -> &'static str {
        "recurrent"
    }

    fn max_k(&self, rt: &Runtime, _dspec: &DraftSpec) -> usize {
        // May exceed the K=6 trained heads up to verify_t - 1 = 7.
        rt.manifest.verify_t - 1
    }

    fn supports_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest.serve_batches.iter().all(|&b| {
            DEVICE_ENTRIES
                .iter()
                .all(|e| rt.has_draft_entry(&dspec.name, &format!("{e}_b{b}")))
        })
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let sp = cx.rt.manifest.prompt_len;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let mut tnext = vec![0i32; b * sp];
        for (row, seq) in g.seqs.iter().enumerate() {
            let c = seq.len;
            for t in 0..c - 1 {
                tnext[row * sp + t] = tok_flat[row * sp + t + 1];
            }
            tnext[row * sp + c - 1] = seq.last_token;
        }
        let dkv0 = lit_zeros_f32(&[
            2,
            b,
            cx.tspec.n_heads,
            cx.tspec.max_seq,
            cx.tspec.head_dim,
        ])?;

        if cx.device_verify {
            // Device path: feed the FULL [B, Sp, 3d] prefill features
            // (the entry slices its fusion columns in-graph) and let the
            // entry sample the first round's draft 0 at sel = len-1.
            let sel: Vec<i32> = g.seqs.iter().map(|s| (s.len - 1) as i32).collect();
            let u: Vec<f32> = g
                .seqs
                .iter_mut()
                .map(|s| cx.draft_uniform(&mut s.rng))
                .collect();
            let dyn_in = vec![
                dkv0,
                pack::to_literal(feats)?,
                lit_i32(&[b, sp], &tnext)?,
                lit_i32(&[b], &vec![0i32; b])?,
                lit_i32(&[b], &sel)?,
                lit_f32(&[b], &u)?,
                lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
                lit_scalar_i32(cx.opts.mode.device_code())?,
            ];
            return self.run_extend_sample(cx, g, &format!("extend_p_sample_b{b}"), dyn_in);
        }

        // Host path: slice the fusion columns here and pull the q/h
        // planes back for host-side pickup.
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * sp * fdim];
        for row in 0..b {
            for t in 0..sp {
                let base = (row * sp + t) * f3;
                feats_in[(row * sp + t) * fdim..(row * sp + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
        }
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_p_b{b}"))?;
        let dyn_in = [
            dkv0,
            lit_f32(&[b, sp, fdim], &feats_in)?,
            lit_i32(&[b, sp], &tnext)?,
            lit_i32(&[b], &vec![0i32; b])?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?; // [B,Sp,Vd]
        let h_all = extend.output_host(&outs, 1)?; // [B,Sp,d]
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            let c = seq.len;
            seq.q1 = tensor_row(&q_all, row, &[b, sp, vd], c - 1);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, sp, d], c - 1));
        }
        g.dkv_spec = Some(extend.spec.outputs[2].clone());
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_b{b}"))?;
        let vd = cx.dspec.draft_vocab;
        let mut q_logits: Vec<Vec<f32>> = g.seqs.iter().map(|s| s.q1.clone()).collect();
        for i in 0..k {
            let mut toks = vec![0i32; b];
            for row in 0..b {
                let (full, compact) = q.slot(row, i);
                cx.write_draft_dist(&q_logits[row], compact, full);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, compact);
                drafts[row][i] = cx.draft_token_id(xi);
                toks[row] = drafts[row][i];
            }
            if i + 1 == k {
                break; // q_{k+1} never needed
            }
            let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i) as i32).collect();
            let dyn_in = [
                g.dkv.take().context("dkv")?,
                g.h_prev.take().context("h_prev")?,
                lit_i32(&[b], &toks)?,
                lit_i32(&[b], &pos)?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let ql = step.output_host(&outs, 0)?;
            for row in 0..b {
                q_logits[row] = tensor_row(&ql, row, &[b, vd], 0);
            }
            let mut it = outs.into_iter();
            let _ = it.next(); // logits
            g.h_prev = Some(it.next().unwrap());
            g.dkv = Some(it.next().unwrap());
        }
        Ok(())
    }

    fn propose_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        // Position 0 was sampled in-graph by the previous extend call
        // (stream-order-identical to the host path's first propose draw).
        anyhow::ensure!(
            g.tok0.len() == b && g.q0_dev.is_some(),
            "device propose without extend-sampled first draft"
        );
        for (row, d) in drafts.iter_mut().enumerate() {
            d[0] = g.tok0[row];
        }
        q_dev.push(g.q0_dev.take().unwrap());
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_sample_b{b}"))?;
        let mut toks: Vec<i32> = drafts.iter().map(|d| d[0]).collect();
        for i in 1..k {
            let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i - 1) as i32).collect();
            let u: Vec<f32> = g
                .seqs
                .iter_mut()
                .map(|s| cx.draft_uniform(&mut s.rng))
                .collect();
            let mut dyn_in = vec![
                g.dkv.take().context("dkv")?,
                g.h_prev.take().context("h_prev")?,
                lit_i32(&[b], &toks)?,
                lit_i32(&[b], &pos)?,
                lit_f32(&[b], &u)?,
                lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
                lit_scalar_i32(cx.opts.mode.device_code())?,
            ];
            if let Some(vm) = cx.vocab_map_lit()? {
                dyn_in.push(vm);
            }
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let tok = step.output_host(&outs, 0)?.as_i32(); // [B] — O(B) ints
            for (row, d) in drafts.iter_mut().enumerate() {
                d[i] = tok[row];
            }
            toks = tok;
            let mut it = outs.into_iter();
            let _tok_lit = it.next();
            q_dev.push(it.next().unwrap());
            g.h_prev = it.next();
            g.dkv = it.next();
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let d = cx.tspec.d_model;
        let fdim = cx.dspec.fuse_dim;
        let f3 = cx.tspec.feat_dim;
        let feats_full = feats.as_f32();
        let mut feats_in = vec![0f32; b * vt * fdim];
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = n_acc[row];
            for t in 0..vt {
                let base = (row * vt + t) * f3;
                feats_in[(row * vt + t) * fdim..(row * vt + t + 1) * fdim]
                    .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
            }
            for (t, item) in drafts[row].iter().enumerate().take(j) {
                tnext[row * vt + t] = *item;
            }
            tnext[row * vt + j] = seq.last_token;
            // extend starts where this round's verify block started
            pos[row] = if seq.done {
                (seq.len.saturating_sub(1 + j)) as i32
            } else {
                (seq.len - 1 - j) as i32
            };
        }
        let extend = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("extend_k_b{b}"))?;
        let dyn_in = [
            g.dkv.take().context("dkv")?,
            lit_f32(&[b, vt, fdim], &feats_in)?,
            lit_i32(&[b, vt], &tnext)?,
            lit_i32(&[b], &pos)?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
        let outs = extend.run_bufs(&args)?;
        let q_all = extend.output_host(&outs, 0)?;
        let h_all = extend.output_host(&outs, 1)?;
        let vd = cx.dspec.draft_vocab;
        let mut hprev = vec![0f32; b * d];
        for row in 0..b {
            let j = n_acc[row];
            let seq = &mut g.seqs[row];
            seq.q1 = tensor_row(&q_all, row, &[b, vt, vd], j);
            hprev[row * d..(row + 1) * d]
                .copy_from_slice(&tensor_row(&h_all, row, &[b, vt, d], j));
        }
        g.dkv = Some(outs.into_iter().nth(2).unwrap());
        g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
        Ok(())
    }

    fn advance_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        n_acc_lit: xla::Literal,
        feats: xla::Literal,
        _h_sel: xla::Literal,
    ) -> Result<()> {
        let b = g.b;
        let vt = cx.rt.manifest.verify_t;
        let mut tnext = vec![0i32; b * vt];
        let mut pos = vec![0i32; b];
        for row in 0..b {
            let seq = &g.seqs[row];
            let j = n_acc[row];
            for (t, item) in drafts[row].iter().enumerate().take(j) {
                tnext[row * vt + t] = *item;
            }
            tnext[row * vt + j] = seq.last_token;
            pos[row] = if seq.done {
                (seq.len.saturating_sub(1 + j)) as i32
            } else {
                (seq.len - 1 - j) as i32
            };
        }
        // Next round's first-draft uniform, drawn NOW so the per-stream
        // order matches the host path (which draws it first thing in the
        // next propose).
        let u: Vec<f32> = g
            .seqs
            .iter_mut()
            .map(|s| cx.draft_uniform(&mut s.rng))
            .collect();
        let dyn_in = vec![
            g.dkv.take().context("dkv")?,
            feats, // verify_fused output, fed back without a host pull
            lit_i32(&[b, vt], &tnext)?,
            lit_i32(&[b], &pos)?,
            n_acc_lit, // per-row q/h gather index, in-graph
            lit_f32(&[b], &u)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        self.run_extend_sample(cx, g, &format!("extend_k_sample_b{b}"), dyn_in)
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        // Draft KV row: device splice when the artifact carries the
        // entry, host strided copy otherwise.
        let dst_dkv = dst.dkv.take().context("adopt_row: dst dkv")?;
        let src_dkv = src.dkv.as_ref().context("adopt_row: src dkv")?;
        let dkv = match copy_kv_row_device(cx, KvSide::Draft, dst.b, src.b, &dst_dkv, src_dkv, dst_row)?
        {
            Some(dkv) => dkv,
            None => copy_literal_row(
                &dst_dkv,
                dst.dkv_spec.as_ref().context("adopt_row: dst dkv spec")?,
                dst_row,
                src_dkv,
                src.dkv_spec.as_ref().context("adopt_row: src dkv spec")?,
                src_row,
                DKV_BATCH_AXIS,
            )?,
        };
        dst.dkv = Some(dkv);
        // Hidden carry row [B, d].
        let d = cx.tspec.d_model;
        let dst_h = dst.h_prev.take().context("adopt_row: dst h_prev")?;
        let h = copy_literal_row(
            &dst_h,
            &spec_f32(vec![dst.b, d]),
            dst_row,
            src.h_prev.as_ref().context("adopt_row: src h_prev")?,
            &spec_f32(vec![src.b, d]),
            src_row,
            0,
        )?;
        dst.h_prev = Some(h);
        // Device path: the extend-sampled first-draft q row rides along
        // (tok0 is moved by the engine with the session state).
        if cx.device_verify {
            let v = cx.tspec.vocab;
            let dst_q = dst.q0_dev.take().context("adopt_row: dst q0")?;
            let q = copy_literal_row(
                &dst_q,
                &spec_f32(vec![dst.b, v]),
                dst_row,
                src.q0_dev.as_ref().context("adopt_row: src q0")?,
                &spec_f32(vec![src.b, v]),
                src_row,
                0,
            )?;
            dst.q0_dev = Some(q);
        }
        Ok(())
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        // Packed draft KV: one host repack of the selected rows.
        let src_dkv = src.dkv.as_ref().context("migrate_rows: src dkv")?;
        let src_spec = src.dkv_spec.as_ref().context("migrate_rows: src dkv spec")?;
        let (dkv, dkv_spec) = repack_literal_rows(src_dkv, src_spec, src_map, DKV_BATCH_AXIS)?;
        dst.dkv = Some(dkv);
        dst.dkv_spec = Some(dkv_spec);
        // Hidden carry [B, d] (both paths for recurrent archs).
        migrate_hidden_rows(cx, dst, src, src_map)?;
        // Device path: the extend-sampled first-draft q row rides along
        // (tok0 is moved by the engine with the session state).
        if cx.device_verify {
            let v = cx.tspec.vocab;
            let src_q = src.q0_dev.as_ref().context("migrate_rows: src q0")?;
            let (q, _) = repack_literal_rows(src_q, &spec_f32(vec![src.b, v]), src_map, 0)?;
            dst.q0_dev = Some(q);
        }
        Ok(())
    }
}
