//! MLP-speculator draft backend: a per-head recurrent MLP state threaded
//! through K chained `step` calls. Like MEDUSA there is no draft-side KV;
//! the conditioning hidden lives in `SeqState` (host path) or the packed
//! `h_prev` literal (device path) and joins are cheap.
//!
//! Device verify path: each chained `step_sample` call samples its token
//! in-graph from a host-fed uniform and keeps the full-vocab q resident
//! for the fused verify entry; only the `[B]` token ids come back.

use anyhow::{Context, Result};

use crate::runtime::{DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    adopt_hidden_row, arg_refs, hidden_lit, lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32,
    migrate_hidden_rows, pickup_hidden_advance, pickup_hidden_bootstrap, tensor_row_into, upload,
    DraftBackend, EngineCx, GroupState, QFlat,
};

pub struct Mlp;

impl DraftBackend for Mlp {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn max_k(&self, _rt: &Runtime, dspec: &DraftSpec) -> usize {
        dspec.k_heads
    }

    fn supports_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest
            .serve_batches
            .iter()
            .all(|&b| rt.has_draft_entry(&dspec.name, &format!("step_sample_b{b}")))
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_bootstrap(cx, g, feats);
        if cx.device_verify {
            g.h_prev = Some(hidden_lit(g, cx.tspec.d_model)?);
        }
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let d = cx.tspec.d_model;
        let vocab = cx.tspec.vocab;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_b{b}"))?;
        let mut state = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter().enumerate() {
            state[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
        }
        let mut state_t = lit_f32(&[b, d], &state)?;
        let mut toks: Vec<i32> = g.seqs.iter().map(|s| s.last_token).collect();
        for i in 0..k {
            let dyn_in = [
                state_t,
                lit_i32(&[b], &toks)?,
                lit_scalar_i32(i as i32)?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let lg = step.output_host(&outs, 0)?;
            let mut lrow = Vec::new();
            for row in 0..b {
                tensor_row_into(&lg, row, &[b, vocab], 0, &mut lrow);
                let (full, compact) = q.slot(row, i);
                cx.write_draft_dist(&lrow, compact, full);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, compact);
                drafts[row][i] = cx.draft_token_id(xi);
                toks[row] = drafts[row][i];
            }
            state_t = outs.into_iter().nth(1).unwrap();
        }
        Ok(())
    }

    fn propose_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_sample_b{b}"))?;
        let mut state_t = g.h_prev.take().context("mlp device state")?;
        let mut toks: Vec<i32> = g.seqs.iter().map(|s| s.last_token).collect();
        for i in 0..k {
            let u: Vec<f32> = g
                .seqs
                .iter_mut()
                .map(|s| cx.draft_uniform(&mut s.rng))
                .collect();
            let dyn_in = [
                state_t,
                lit_i32(&[b], &toks)?,
                lit_scalar_i32(i as i32)?,
                lit_f32(&[b], &u)?,
                lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
                lit_scalar_i32(cx.opts.mode.device_code())?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let tok = step.output_host(&outs, 0)?.as_i32(); // [B] — O(B) ints
            for (row, dr) in drafts.iter_mut().enumerate() {
                dr[i] = tok[row];
            }
            toks = tok;
            let mut it = outs.into_iter();
            let _tok_lit = it.next();
            q_dev.push(it.next().unwrap());
            state_t = it.next().unwrap();
        }
        // The chained state is per-round scratch (host path discards it
        // too); next round conditions on the verify-picked hidden.
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_advance(cx, g, n_acc, feats);
        Ok(())
    }

    fn advance_device(
        &self,
        _cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _n_acc: &[usize],
        _n_acc_lit: xla::Literal,
        _feats: xla::Literal,
        h_sel: xla::Literal,
    ) -> Result<()> {
        g.h_prev = Some(h_sel);
        Ok(())
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        if cx.device_verify {
            adopt_hidden_row(cx, dst, dst_row, src, src_row)?;
        }
        Ok(())
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        if cx.device_verify {
            migrate_hidden_rows(cx, dst, src, src_map)?;
        }
        Ok(())
    }
}
