//! MLP-speculator draft backend: a per-head recurrent MLP state threaded
//! through K chained `step` calls. Like MEDUSA there is no draft-side KV;
//! the conditioning hidden lives in `SeqState` and joins are free.

use anyhow::Result;

use crate::runtime::{DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    arg_refs, lit_f32, lit_i32, lit_scalar_i32, pickup_hidden_advance, pickup_hidden_bootstrap,
    tensor_row, upload, DraftBackend, EngineCx, GroupState,
};

pub struct Mlp;

impl DraftBackend for Mlp {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn max_k(&self, _rt: &Runtime, dspec: &DraftSpec) -> usize {
        dspec.k_heads
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_bootstrap(cx, g, feats);
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &mut [Vec<i32>],
        q_full: &mut [Vec<Vec<f32>>],
    ) -> Result<()> {
        let b = g.b;
        let k = cx.k;
        let d = cx.tspec.d_model;
        let vocab = cx.tspec.vocab;
        let step = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("step_b{b}"))?;
        let mut state = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter().enumerate() {
            state[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
        }
        let mut state_t = lit_f32(&[b, d], &state)?;
        let mut toks: Vec<i32> = g.seqs.iter().map(|s| s.last_token).collect();
        for i in 0..k {
            let dyn_in = [
                state_t,
                lit_i32(&[b], &toks)?,
                lit_scalar_i32(i as i32)?,
            ];
            let dyn_b = upload(cx.rt, &dyn_in)?;
            let args = arg_refs(&cx.tparams, &cx.dparams, &dyn_b);
            let outs = step.run_bufs(&args)?;
            let lg = step.output_host(&outs, 0)?;
            for row in 0..b {
                let lrow = tensor_row(&lg, row, &[b, vocab], 0);
                let (qf, qc) = cx.draft_dist(&lrow);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, &qc);
                drafts[row][i] = cx.draft_token_id(xi);
                q_full[row].push(qf);
                toks[row] = drafts[row][i];
            }
            state_t = outs.into_iter().nth(1).unwrap();
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_advance(cx, g, n_acc, feats);
        Ok(())
    }

    fn adopt_row(
        &self,
        _cx: &EngineCx,
        _dst: &mut GroupState,
        _dst_row: usize,
        _src: &GroupState,
        _src_row: usize,
    ) -> Result<()> {
        Ok(())
    }
}
