//! Draft-architecture backends behind the `DraftBackend` trait.
//!
//! The decode loop in `server::engine` is architecture-agnostic: it owns
//! the target prefill/verify calls, the exact acceptance rule and all
//! sequence bookkeeping, and delegates every draft-model interaction to a
//! `DraftBackend`. A new draft architecture plugs in by implementing the
//! trait and registering in `make_backend` — the engine itself never
//! matches on an architecture enum.
//!
//! The trait has four duties, mirroring the four places the old engine
//! dispatched on its private `Kind`:
//!
//!   * `bootstrap` — build draft-side state from the target prefill
//!     (draft-KV extension for recurrent archs, hidden pickup for
//!     parallel-head archs);
//!   * `propose`   — produce K draft tokens + full-vocab q distributions
//!     per batch row (all sampling host-side via `spec::sampling`);
//!   * `advance`   — roll draft state past this round's accepted prefix
//!     using the verify pass's features;
//!   * `adopt_row` — copy one row of packed draft state between groups
//!     (the continuous-batching join path; per-sequence host state moves
//!     with the `SeqState` itself).

pub mod medusa;
pub mod mlp;
pub mod recurrent;

use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{pack, DraftSpec, Runtime, TargetSpec, TensorSpec};
use crate::spec::accept::AcceptanceStats;
use crate::spec::sampling::{self, SamplingMode};
use crate::tensor::{DType, HostTensor};
use crate::util::Pcg64;

use super::engine::EngineOpts;
use super::kv;

/// Batch axis of the packed target KV cache [L, 2, B, H, Smax, Dh].
pub const TKV_BATCH_AXIS: usize = 2;
/// Batch axis of the packed draft KV cache [2, B, H, Smax, Dh].
pub const DKV_BATCH_AXIS: usize = 1;

/// Shared engine context every backend call receives: the runtime, model
/// specs, cached parameter buffers and the sampling configuration.
pub struct EngineCx<'rt> {
    pub rt: &'rt Runtime,
    pub tspec: TargetSpec,
    pub dspec: DraftSpec,
    pub tparams: Vec<xla::PjRtBuffer>,
    pub dparams: Vec<xla::PjRtBuffer>,
    // Source literals MUST outlive the buffers: BufferFromHostLiteral's
    // h2d copy is asynchronous and references the literal from a worker
    // thread (upstream xla_rs awaits the ready future for this reason).
    pub(crate) _param_lits: Vec<xla::Literal>,
    pub vocab_map: Option<Vec<i32>>,
    pub opts: EngineOpts,
    /// Drafts per round (opts.k_draft clamped to the backend's max).
    pub k: usize,
}

impl<'rt> EngineCx<'rt> {
    /// Smallest lowered serve bucket that fits `n` sequences.
    pub fn bucket(&self, n: usize) -> usize {
        *self
            .rt
            .manifest
            .serve_batches
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.rt.manifest.serve_batches.last().unwrap())
    }

    /// Draft logits (possibly truncated vocab) -> (q over full vocab,
    /// q over draft vocab) at the engine temperature.
    pub fn draft_dist(&self, logits: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let qc = sampling::softmax_t(logits, self.opts.temperature.max(1e-3));
        match &self.vocab_map {
            None => (qc.clone(), qc),
            Some(map) => {
                let mut full = vec![0f32; self.tspec.vocab];
                for (i, &fid) in map.iter().enumerate() {
                    full[fid as usize] = qc[i];
                }
                (full, qc)
            }
        }
    }

    pub fn draft_token_id(&self, compact_idx: usize) -> i32 {
        match &self.vocab_map {
            None => compact_idx as i32,
            Some(map) => map[compact_idx],
        }
    }

    pub fn sample_draft(&self, rng: &mut Pcg64, q_compact: &[f32]) -> usize {
        match self.opts.mode {
            SamplingMode::Stochastic => sampling::sample_categorical(rng, q_compact),
            SamplingMode::Greedy | SamplingMode::GreedyDraft => sampling::argmax(q_compact),
        }
    }

    pub fn sample_target(&self, rng: &mut Pcg64, p: &[f32]) -> i32 {
        match self.opts.mode {
            SamplingMode::Greedy => sampling::argmax(p) as i32,
            _ => sampling::sample_categorical(rng, p) as i32,
        }
    }
}

/// Per-sequence decode state. Host-side only; the packed KV rows live in
/// `GroupState`. Index contract (mirrors python/compile/drafts.py):
/// `len` = processed target positions; `last_token` = accepted but not
/// yet processed; a round's verify block occupies positions len..len+K
/// and its logits[i] give p(·| …, block[..=i]).
pub struct SeqState {
    /// Stable request id; also keys the RNG stream, so results do not
    /// depend on batch composition or admission order.
    pub id: u64,
    pub len: usize,
    pub last_token: i32,
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub rng: Pcg64,
    pub stats: AcceptanceStats,
    pub done: bool,
    /// [d] MEDUSA/MLP conditioning hidden.
    pub hidden: Vec<f32>,
    /// Recurrent archs: q-logits for draft 1 of the next round.
    pub q1: Vec<f32>,
    /// Submission time (queue wait + latency are measured from here).
    pub enqueued: Instant,
    pub queue_ms: f64,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub rounds: u64,
}

/// A decode group with packed caches. Rows are slot-mapped sessions
/// under the scheduler (a finished row is freed and reused mid-flight);
/// under the lockstep `generate_batch` path rows are fixed for the
/// group's lifetime.
pub struct GroupState {
    pub b: usize,
    /// Row-indexed sequence states (padding rows start `done`).
    pub seqs: Vec<SeqState>,
    pub tkv: xla::Literal,
    /// Shape/dtype of `tkv` (for host row copies on join).
    pub tkv_spec: TensorSpec,
    pub dkv: Option<xla::Literal>,
    pub dkv_spec: Option<TensorSpec>,
    /// [B, d] recurrent hidden carry.
    pub h_prev: Option<xla::Literal>,
}

/// Behaviour class of a draft architecture. Object-safe: the engine
/// stores a `Box<dyn DraftBackend>`.
pub trait DraftBackend {
    /// Human-readable architecture tag (diagnostics only).
    fn name(&self) -> &'static str;

    /// Maximum chain length this architecture supports per round.
    fn max_k(&self, rt: &Runtime, dspec: &DraftSpec) -> usize;

    /// Build draft-side state for a freshly prefilled group. `tok_flat`
    /// is the [B*Sp] prompt block fed to the target prefill; `feats` its
    /// [B, Sp, feat_dim] feature output. Sequence lengths and bootstrap
    /// tokens are read from `g.seqs`.
    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()>;

    /// Draft `cx.k` tokens per row, filling `drafts[row][i]` (full-vocab
    /// token ids) and `q_full[row][i]` (full-vocab draft distributions).
    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &mut [Vec<i32>],
        q_full: &mut [Vec<Vec<f32>>],
    ) -> Result<()>;

    /// Advance draft state past this round's accepted prefixes.
    /// `n_acc[row]` is the accepted prefix length; `feats` the verify
    /// pass's [B, Vt, feat_dim] features.
    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()>;

    /// Copy row `src_row` of `src`'s packed draft state into row
    /// `dst_row` of `dst` (continuous-batching join). Per-sequence host
    /// state (`SeqState`) is moved by the caller.
    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()>;
}

/// Registry: architecture string -> backend.
pub fn make_backend(arch: &str) -> Result<Box<dyn DraftBackend>> {
    match arch {
        "eagle3" | "mtp" => Ok(Box::new(recurrent::Recurrent)),
        "medusa" => Ok(Box::new(medusa::Medusa)),
        "mlp" => Ok(Box::new(mlp::Mlp)),
        other => bail!("unknown draft arch '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// shared hidden-pickup helpers (parallel-head archs: MEDUSA, MLP)
// ---------------------------------------------------------------------------

/// Bootstrap: pick up the last prompt position's hidden slice per row.
pub(crate) fn pickup_hidden_bootstrap(cx: &EngineCx, g: &mut GroupState, feats: &HostTensor) {
    let sp = cx.rt.manifest.prompt_len;
    let d = cx.tspec.d_model;
    let f3 = cx.tspec.feat_dim;
    let feats_full = feats.as_f32();
    for (row, seq) in g.seqs.iter_mut().enumerate() {
        let c = seq.len;
        let off = (row * sp + c - 1) * f3 + (f3 - d);
        seq.hidden = feats_full[off..off + d].to_vec();
    }
}

/// Advance: pick up the hidden at the accepted-prefix boundary per row.
pub(crate) fn pickup_hidden_advance(
    cx: &EngineCx,
    g: &mut GroupState,
    n_acc: &[usize],
    feats: &HostTensor,
) {
    let vt = cx.rt.manifest.verify_t;
    let d = cx.tspec.d_model;
    let f3 = cx.tspec.feat_dim;
    let feats_full = feats.as_f32();
    for row in 0..g.b {
        let j = n_acc[row];
        let off = (row * vt + j) * f3 + (f3 - d);
        g.seqs[row].hidden = feats_full[off..off + d].to_vec();
    }
}

// ---------------------------------------------------------------------------
// literal plumbing shared by the engine and the backends
// ---------------------------------------------------------------------------

/// Upload dynamic inputs. SAFETY CONTRACT: the source literals must stay
/// alive until the call consuming these buffers has been synced (the h2d
/// copy is async and borrows the literal) — every call site keeps the
/// `dyn_in` array in scope across `run_bufs`, which force-syncs outputs.
pub(crate) fn upload(rt: &Runtime, lits: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
    lits.iter().map(|l| rt.to_buffer(l)).collect()
}

/// Upload parameters, returning the buffers AND the literals backing
/// them — the engine stores both so the async copies can never outlive
/// their source (the crash mode this fixed is documented in
/// EXPERIMENTS.md §Perf).
pub(crate) fn upload_params(
    rt: &Runtime,
    params: &[HostTensor],
) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
    let lits: Vec<xla::Literal> = params.iter().map(pack::to_literal).collect::<Result<_>>()?;
    let bufs: Vec<xla::PjRtBuffer> =
        lits.iter().map(|l| rt.to_buffer(l)).collect::<Result<_>>()?;
    Ok((bufs, lits))
}

pub(crate) fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_f32(shape, data))
}

pub(crate) fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_i32(shape, data))
}

pub(crate) fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::scalar_i32(v))
}

pub(crate) fn lit_zeros_f32(shape: &[usize]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::zeros(DType::F32, shape))
}

/// params1 ++ params2 ++ dynamic — as the &buffer slice run_bufs wants.
pub(crate) fn arg_refs<'a>(
    p1: &'a [xla::PjRtBuffer],
    p2: &'a [xla::PjRtBuffer],
    dynamic: &'a [xla::PjRtBuffer],
) -> Vec<&'a xla::PjRtBuffer> {
    p1.iter().chain(p2.iter()).chain(dynamic.iter()).collect()
}

/// Extract `tensor[row, idx, :]` from a [B, N, D]-shaped host tensor (or
/// `tensor[row, :]` from [B, D] with idx = 0).
pub(crate) fn tensor_row(t: &HostTensor, row: usize, shape: &[usize], idx: usize) -> Vec<f32> {
    debug_assert_eq!(t.shape, shape);
    let dlast = *shape.last().unwrap();
    let n_mid = if shape.len() == 3 { shape[1] } else { 1 };
    let off = (row * n_mid + idx) * dlast;
    t.data[off * 4..(off + dlast) * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Copy one batch row between two packed literals (join path). Both
/// literals round-trip through the host; the strided move itself is
/// `kv::copy_row`. Returns the updated destination literal.
pub(crate) fn copy_literal_row(
    dst: &xla::Literal,
    dst_spec: &TensorSpec,
    dst_row: usize,
    src: &xla::Literal,
    src_spec: &TensorSpec,
    src_row: usize,
    axis: usize,
) -> Result<xla::Literal> {
    let mut host_dst = pack::from_literal(dst, dst_spec, "copy_literal_row:dst")?;
    let host_src = pack::from_literal(src, src_spec, "copy_literal_row:src")?;
    kv::copy_row(&mut host_dst, dst_row, &host_src, src_row, axis)?;
    pack::to_literal(&host_dst)
}

/// Ad-hoc tensor spec for literals whose shape the engine knows exactly
/// (e.g. the [B, d] recurrent hidden carry).
pub(crate) fn spec_f32(shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: String::new(),
        shape,
        dtype: DType::F32,
    }
}
