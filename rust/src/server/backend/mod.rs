//! Draft-architecture backends behind the `DraftBackend` trait.
//!
//! The decode loop in `server::engine` is architecture-agnostic: it owns
//! the target prefill/verify calls, the exact acceptance rule and all
//! sequence bookkeeping, and delegates every draft-model interaction to a
//! `DraftBackend`. A new draft architecture plugs in by implementing the
//! trait and registering in `make_backend` — the engine itself never
//! matches on an architecture enum.
//!
//! The trait has four duties, mirroring the four places the old engine
//! dispatched on its private `Kind`:
//!
//!   * `bootstrap` — build draft-side state from the target prefill
//!     (draft-KV extension for recurrent archs, hidden pickup for
//!     parallel-head archs);
//!   * `propose`   — produce the round's `k` draft tokens + full-vocab q
//!     distributions per batch row (all sampling host-side via
//!     `spec::sampling`); `k` is a PER-ROUND runtime value — the
//!     engine's speculation controller may change it every round, so
//!     backends must not cache it (`cx.k` is only the lifetime maximum);
//!   * `advance`   — roll draft state past this round's accepted prefix
//!     using the verify pass's features;
//!   * `adopt_row` — copy one row of packed draft state between groups
//!     (the continuous-batching join path; per-sequence host state moves
//!     with the `SeqState` itself);
//!   * `migrate_rows` — repack the listed rows of a group's draft state
//!     into a freshly-allocated smaller group (the scheduler's long-tail
//!     downshift; the engine moves `SeqState`s/target KV itself).
//!
//! Backends that carry the device-sampling artifacts additionally serve
//! the DEVICE verify path (`supports_device` / `propose_device` /
//! `advance_device`): draft tokens are sampled in-graph from host-fed
//! uniforms, the full-vocab q distributions stay on device as literals
//! flowing straight into the target's fused verify entry, and the
//! conditioning hidden rides back from the verify pass — per round only
//! O(B·K) token ids cross to the host. The host-side `propose`/`advance`
//! remain as the fallback for artifact sets lowered before the device
//! entries existed (and for forced-host parity testing).
//!
//! A third optional duty set serves MULTI-CANDIDATE (tree) drafting
//! (`supports_tree` / `propose_tree` / `advance_tree` plus device
//! variants): `propose_tree` fills one candidate per
//! [`TreeSpec`] node instead of K chain slots, and the engine verifies
//! the whole tree in one tree-attention pass under the exact multi-draft
//! rule (`spec::sampling::verify_tree`). The `-tree` arch suffix in
//! [`make_backend`] selects these variants; see DESIGN.md §3/§6.
//!
//! # The per-path draft-KV contract (stateful tree backends)
//!
//! A stateless tree backend (MEDUSA) carries no per-round draft state
//! beyond the conditioning hidden. A STATEFUL one (the recurrent
//! EAGLE-3/MTP family) additionally owns a draft KV cache whose tree
//! rounds mirror the target cache's: during `propose_tree` node `i`'s
//! draft-KV entry is written at slot `pos + i` (node-index layout, tree
//! attention over each node's root path), and after the verdict the
//! accepted path must be SPLICED back to consecutive slots so the
//! committed draft cache stays linear and the next round is
//! topology-agnostic. Ownership is split exactly like the target side:
//!
//!   * the ENGINE owns the target splice (`kv_path_gather_b{B}` on the
//!     host path, in-graph inside `verify_tree_fused_b{B}`);
//!   * the BACKEND owns the draft splice — `advance_tree` /
//!     `advance_tree_device` run `dkv_path_gather_b{B}` (the draft-side
//!     twin: gather entries at the path's absolute positions, scatter
//!     linearly from the round's block start) BEFORE rolling state
//!     forward, in the same round as the target splice.
//!
//! What `dkv_path_gather_b{B}` guarantees: rows are independent (batch
//! rows never overlap), gathers read the pre-update cache, and slots
//! outside `dst0..dst0+N` are untouched — so a row whose splice map is
//! the identity (done/padding rows) is a no-op. The subsequent
//! `extend_k` feature fusion then overwrites the spliced block with
//! target-feature-fused entries — identical arithmetic to a chain round
//! over the accepted tokens, which is what keeps chain-degeneracy exact
//! (`tests/properties.rs`) and the committed cache state bit-compatible
//! with the chain backend's.

pub mod medusa;
pub mod mlp;
pub mod recurrent;
pub mod tree;

use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{pack, DraftSpec, Runtime, TargetSpec, TensorSpec};
use crate::spec::accept::AcceptanceStats;
use crate::spec::sampling::{self, SamplingMode, TreeSpec};
use crate::tensor::{DType, HostTensor};
use crate::util::Pcg64;

use super::engine::EngineOpts;
use super::kv;

/// Batch axis of the packed target KV cache [L, 2, B, H, Smax, Dh].
pub const TKV_BATCH_AXIS: usize = 2;
/// Batch axis of the packed draft KV cache [2, B, H, Smax, Dh].
pub const DKV_BATCH_AXIS: usize = 1;

/// Placeholder uniform fed to device entries for draws the host path
/// would not consume (greedy modes, finished/padding rows). Any value in
/// (0, 1) works — the in-graph decision it feeds is ignored or forced.
pub const DUMMY_UNIFORM: f32 = 0.5;

/// Shared engine context every backend call receives: the runtime, model
/// specs, cached parameter buffers and the sampling configuration.
pub struct EngineCx<'rt> {
    pub rt: &'rt Runtime,
    pub tspec: TargetSpec,
    pub dspec: DraftSpec,
    pub tparams: Vec<xla::PjRtBuffer>,
    pub dparams: Vec<xla::PjRtBuffer>,
    // Source literals MUST outlive the buffers: BufferFromHostLiteral's
    // h2d copy is asynchronous and references the literal from a worker
    // thread (upstream xla_rs awaits the ready future for this reason).
    pub(crate) _param_lits: Vec<xla::Literal>,
    pub vocab_map: Option<Vec<i32>>,
    pub opts: EngineOpts,
    /// MAXIMUM drafts per round (opts.k_draft clamped to the backend's
    /// max). The actual per-round chain length is the `k` argument the
    /// engine passes to `propose`/`propose_device` — the speculation
    /// controller may choose any value in 1..=this each round.
    pub k: usize,
    /// True when this engine runs the device-resident verify path —
    /// backends branch their bootstrap/adopt plumbing on it.
    pub device_verify: bool,
}

impl<'rt> EngineCx<'rt> {
    /// Smallest lowered serve bucket that fits `n` sequences.
    pub fn bucket(&self, n: usize) -> usize {
        *self
            .rt
            .manifest
            .serve_batches
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.rt.manifest.serve_batches.last().unwrap())
    }

    /// Draft logits (possibly truncated vocab) -> temperature softmax
    /// written into `compact` (draft vocab) and scattered into `full`
    /// (full vocab; caller guarantees it arrives zeroed). Flat-buffer
    /// variant of the old per-round nested-Vec allocation.
    pub fn write_draft_dist(&self, logits: &[f32], compact: &mut Vec<f32>, full: &mut [f32]) {
        compact.clear();
        compact.resize(logits.len(), 0.0);
        sampling::softmax_t_into(logits, self.opts.temperature.max(1e-3), compact);
        match &self.vocab_map {
            None => full.copy_from_slice(compact),
            Some(map) => {
                for (i, &fid) in map.iter().enumerate() {
                    full[fid as usize] = compact[i];
                }
            }
        }
    }

    pub fn draft_token_id(&self, compact_idx: usize) -> i32 {
        match &self.vocab_map {
            None => compact_idx as i32,
            Some(map) => map[compact_idx],
        }
    }

    /// Host-side draft sampling under the explicit-uniform contract:
    /// stochastic mode consumes exactly one stream draw per position
    /// (mirroring the device entries' host-fed `u` input), greedy modes
    /// consume none.
    pub fn sample_draft(&self, rng: &mut Pcg64, q_compact: &[f32]) -> usize {
        match self.opts.mode {
            SamplingMode::Stochastic => {
                sampling::categorical_from_uniform(q_compact, rng.uniform() as f32)
            }
            SamplingMode::Greedy | SamplingMode::GreedyDraft => sampling::argmax(q_compact),
        }
    }

    /// Tree-node variant of [`EngineCx::sample_draft`]: stochastic mode
    /// samples i.i.d. through the node's stream draw (the exactness of
    /// the multi-draft rule wants candidates drawn from the per-node
    /// q), the greedy modes take the node's sibling-rank-th largest
    /// candidate so siblings enumerate distinct top-k tokens — both
    /// formulated identically to the device `tree_draft_sample`.
    pub fn sample_draft_tree(
        &self,
        rng: &mut Pcg64,
        q_compact: &[f32],
        rank: usize,
        scratch: &mut Vec<f32>,
    ) -> usize {
        match self.opts.mode {
            SamplingMode::Stochastic => {
                sampling::categorical_from_uniform(q_compact, rng.uniform() as f32)
            }
            SamplingMode::Greedy | SamplingMode::GreedyDraft => {
                sampling::argmax_rank(q_compact, rank, scratch)
            }
        }
    }

    /// The uniform a device-sampling entry receives for one row/position:
    /// a real stream draw in stochastic mode (the draw the host path
    /// would have consumed), an inert constant otherwise.
    pub fn draft_uniform(&self, rng: &mut Pcg64) -> f32 {
        if self.opts.mode == SamplingMode::Stochastic {
            rng.uniform() as f32
        } else {
            DUMMY_UNIFORM
        }
    }

    /// Truncated-vocab map as a device literal (eagle3 device entries).
    pub fn vocab_map_lit(&self) -> Result<Option<xla::Literal>> {
        match &self.vocab_map {
            None => Ok(None),
            Some(map) => Ok(Some(lit_i32(&[map.len()], map)?)),
        }
    }

    pub fn sample_target(&self, rng: &mut Pcg64, p: &[f32]) -> i32 {
        match self.opts.mode {
            SamplingMode::Greedy => sampling::argmax(p) as i32,
            _ => sampling::sample_categorical(rng, p) as i32,
        }
    }
}

/// Per-sequence decode state. Host-side only; the packed KV rows live in
/// `GroupState`. Index contract (mirrors python/compile/drafts.py):
/// `len` = processed target positions; `last_token` = accepted but not
/// yet processed; a round's verify block occupies positions len..len+K
/// and its `logits[i]` give `p(·| …, block[..=i])`.
pub struct SeqState {
    /// Stable request id; also keys the RNG stream, so results do not
    /// depend on batch composition or admission order.
    pub id: u64,
    pub len: usize,
    pub last_token: i32,
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub rng: Pcg64,
    pub stats: AcceptanceStats,
    pub done: bool,
    /// `[d]` MEDUSA/MLP conditioning hidden.
    pub hidden: Vec<f32>,
    /// Recurrent archs: q-logits for draft 1 of the next round.
    pub q1: Vec<f32>,
    /// Submission time (queue wait + latency are measured from here).
    pub enqueued: Instant,
    pub queue_ms: f64,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub rounds: u64,
}

/// A decode group with packed caches. Rows are slot-mapped sessions
/// under the scheduler (a finished row is freed and reused mid-flight);
/// under the lockstep `generate_batch` path rows are fixed for the
/// group's lifetime.
pub struct GroupState {
    pub b: usize,
    /// Row-indexed sequence states (padding rows start `done`).
    pub seqs: Vec<SeqState>,
    pub tkv: xla::Literal,
    /// Shape/dtype of `tkv` (for host row copies on join).
    pub tkv_spec: TensorSpec,
    pub dkv: Option<xla::Literal>,
    pub dkv_spec: Option<TensorSpec>,
    /// [B, d] draft conditioning carry: the recurrent hidden (both
    /// paths), or the verify-picked hidden for MEDUSA/MLP on the device
    /// path (host path keeps theirs in `SeqState::hidden`).
    pub h_prev: Option<xla::Literal>,
    /// Device path, recurrent archs: next round's first drafted token
    /// per row (sampled in-graph by the extend entries)…
    pub tok0: Vec<i32>,
    /// …and its full-vocab q distribution, resident as a literal.
    pub q0_dev: Option<xla::Literal>,
}

/// Flat reusable [B, K, V] buffer of full-vocab draft distributions plus
/// a compact-vocab scratch row — replaces the per-round
/// `Vec<Vec<Vec<f32>>>` allocation churn on the host verify path.
#[derive(Default)]
pub struct QFlat {
    k: usize,
    v: usize,
    full: Vec<f32>,
    compact: Vec<f32>,
}

impl QFlat {
    /// Size for this round and zero the full-vocab plane (the scatter
    /// for truncated-vocab drafts relies on zeroed slots).
    pub fn reset(&mut self, b: usize, k: usize, v: usize) {
        self.k = k;
        self.v = v;
        self.full.clear();
        self.full.resize(b * k * v, 0.0);
    }

    /// Full-vocab q for (row, position).
    pub fn row(&self, row: usize, i: usize) -> &[f32] {
        let off = (row * self.k + i) * self.v;
        &self.full[off..off + self.v]
    }

    /// Contiguous [K, V] block for one row (what `verify_round` takes).
    pub fn row_block(&self, row: usize) -> &[f32] {
        let off = row * self.k * self.v;
        &self.full[off..off + self.k * self.v]
    }

    /// Mutable (full-vocab slot, compact scratch) pair for one position —
    /// disjoint fields, so backends can softmax into the scratch and
    /// scatter into the slot without temporaries.
    pub fn slot(&mut self, row: usize, i: usize) -> (&mut [f32], &mut Vec<f32>) {
        let off = (row * self.k + i) * self.v;
        (&mut self.full[off..off + self.v], &mut self.compact)
    }
}

/// Behaviour class of a draft architecture. Object-safe: the engine
/// stores a `Box<dyn DraftBackend>`.
pub trait DraftBackend {
    /// Human-readable architecture tag (diagnostics only).
    fn name(&self) -> &'static str;

    /// Maximum chain length this architecture supports per round.
    fn max_k(&self, rt: &Runtime, dspec: &DraftSpec) -> usize;

    /// Per-round cost structure in verify-call units — what the
    /// speculation controller trades expected accepted tokens against.
    /// Chained archs pay one draft dispatch per token; parallel-head
    /// archs price every head in one propose pass.
    fn cost_model(&self) -> crate::spec::adaptive::CostModel {
        crate::spec::adaptive::CostModel::chained(0.25)
    }

    /// Build draft-side state for a freshly prefilled group. `tok_flat`
    /// is the [B*Sp] prompt block fed to the target prefill; `feats` its
    /// [B, Sp, feat_dim] feature output. Sequence lengths and bootstrap
    /// tokens are read from `g.seqs`.
    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()>;

    /// Draft `k` tokens per row (`1 <= k <= cx.k`, chosen per round by
    /// the engine), filling `drafts[row][..k]` (full-vocab token ids)
    /// and `q.row(row, i)` (full-vocab draft distributions in the
    /// engine's flat scratch). Stochastic mode consumes exactly `k`
    /// stream draws per row regardless of architecture.
    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()>;

    /// Advance draft state past this round's accepted prefixes.
    /// `n_acc[row]` is the accepted prefix length; `feats` the verify
    /// pass's [B, Vt, feat_dim] features.
    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()>;

    // ------------------------------------------------------------------
    // device verify path (optional; default = unsupported)
    // ------------------------------------------------------------------

    /// True when the manifest carries every device-sampling entry this
    /// backend needs (all serve buckets); gates the engine's path choice.
    fn supports_device(&self, _rt: &Runtime, _dspec: &DraftSpec) -> bool {
        false
    }

    /// Device-path proposal: fill `drafts` with the round's `k` sampled
    /// token ids (read back as O(B·K) ints) and push one [B, V]
    /// full-vocab q LITERAL per position onto `q_dev` — sampling happens
    /// in-graph from host-fed uniforms; the q distributions never reach
    /// the host. Like `propose`, `k` is per-round.
    fn propose_device(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _k: usize,
        _drafts: &mut [Vec<i32>],
        _q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        bail!("backend '{}' has no device verify path", self.name())
    }

    /// Device-path advance. Consumes the fused verify entry's outputs by
    /// value: `n_acc_lit` (`[B]` i32, doubles as the in-graph gather
    /// index), `feats` ([B, Vt, 3d]) and `h_sel` ([B, d], the
    /// verify-picked conditioning hidden). `n_acc` is the host copy with
    /// finished rows forced to 0.
    fn advance_device(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _n_acc: &[usize],
        _n_acc_lit: xla::Literal,
        _feats: xla::Literal,
        _h_sel: xla::Literal,
    ) -> Result<()> {
        bail!("backend '{}' has no device verify path", self.name())
    }

    // ------------------------------------------------------------------
    // multi-candidate (tree) drafting (optional; default = unsupported)
    // ------------------------------------------------------------------

    /// True when this backend can propose candidate trees on the HOST
    /// path (the engine additionally gates on the target's
    /// `verify_tree_b{B}` / `kv_path_gather_b{B}` entries).
    fn supports_tree(&self, _rt: &Runtime, _dspec: &DraftSpec) -> bool {
        false
    }

    /// Tree proposal: fill `drafts[row][i]` with candidate node `i`'s
    /// full-vocab token id and `q.row(row, i)` with the distribution it
    /// was drawn from (the node's LEVEL head for parallel-head archs).
    /// Stochastic mode consumes one stream draw per node per row (node
    /// order); the greedy modes take sibling-rank-th-largest candidates
    /// and consume none.
    fn propose_tree(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _tree: &TreeSpec,
        _drafts: &mut [Vec<i32>],
        _q: &mut QFlat,
    ) -> Result<()> {
        bail!("backend '{}' has no tree drafting path", self.name())
    }

    /// Roll draft state past a tree round. `drafts[row]` holds the
    /// round's candidate tokens per node, `paths[row]` the accepted node
    /// indices root-to-leaf (empty for done rows), `stop_blk[row]` the
    /// block position whose hidden conditions the next round (the
    /// deepest accepted node's slot, or 0 after a full rejection);
    /// `feats` the tree pass's `[B, T, 3d]` features in BLOCK layout.
    /// Stateful backends splice their per-path draft KV here (see the
    /// module-level contract).
    fn advance_tree(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _paths: &[Vec<usize>],
        _stop_blk: &[usize],
        _feats: &HostTensor,
    ) -> Result<()> {
        bail!("backend '{}' has no tree drafting path", self.name())
    }

    /// True when the manifest carries the backend's in-graph tree
    /// sampling entries (all serve buckets).
    fn supports_tree_device(&self, _rt: &Runtime, _dspec: &DraftSpec) -> bool {
        false
    }

    /// True when this backend's tree advances consume the accepted-path
    /// node indices (stateful backends building draft-splice maps).
    /// Gates the engine's `[B, Vt-1]` path readback on the device tree
    /// round — stateless backends keep their leaner transfer profile.
    fn tree_paths_needed(&self) -> bool {
        false
    }

    /// Device-path tree proposal: fill `drafts` with the sampled
    /// candidate ids (O(B·N) ints) and push the lowered arity of
    /// per-node `[B, V]` q LITERALS onto `q_dev` — they flow straight
    /// into `verify_tree_fused_b{B}` without touching the host.
    fn propose_tree_device(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _tree: &TreeSpec,
        _drafts: &mut [Vec<i32>],
        _q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        bail!("backend '{}' has no tree drafting path", self.name())
    }

    /// Device-path tree advance. `n_path_lit` is the fused entry's
    /// `[B]` accepted-path-length output (doubles as the in-graph q/h
    /// gather index, like the chain's `n_acc_lit`), `feats` its
    /// `[B, T, 3d]` BLOCK-layout features literal, `h_sel` the in-graph
    /// hidden pickup at the stop position (target KV was already
    /// path-spliced in-graph). Stateless backends adopt `h_sel`;
    /// stateful ones splice their draft KV and re-extend from `feats`
    /// (see the module-level contract).
    fn advance_tree_device(
        &self,
        _cx: &EngineCx,
        _g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _paths: &[Vec<usize>],
        _n_path_lit: xla::Literal,
        _feats: xla::Literal,
        _h_sel: xla::Literal,
    ) -> Result<()> {
        bail!("backend '{}' has no tree drafting path", self.name())
    }

    /// Copy row `src_row` of `src`'s packed draft state into row
    /// `dst_row` of `dst` (continuous-batching join). Per-sequence host
    /// state (`SeqState`) is moved by the caller.
    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()>;

    /// Long-tail downshift: repack rows `src_map[i]` of `src`'s packed
    /// draft state into rows `i` of the freshly-allocated smaller group
    /// `dst` (`dst.b == src_map.len()`, `dst.seqs`/target KV already
    /// moved by the engine). KV-bearing backends route the repack
    /// through the device `dkv_gather_rows_b{Bsrc}x{Bdst}` entry so no
    /// draft-KV bytes cross the host; only the small `[B, d]` / `[B, V]`
    /// conditioning carries still round-trip.
    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()>;
}

/// Registry: architecture string -> backend. The `-tree` suffix selects
/// the multi-candidate variant of an architecture (the engine appends it
/// when `EngineOpts::tree` is set).
pub fn make_backend(arch: &str) -> Result<Box<dyn DraftBackend>> {
    match arch {
        "eagle3" | "mtp" => Ok(Box::new(recurrent::Recurrent)),
        "eagle3-tree" | "mtp-tree" => Ok(Box::new(recurrent::RecurrentTree)),
        "medusa" => Ok(Box::new(medusa::Medusa)),
        "medusa-tree" => Ok(Box::new(tree::MedusaTree)),
        "mlp" => Ok(Box::new(mlp::Mlp)),
        other => match other.strip_suffix("-tree") {
            // The engine synthesizes '<arch>-tree' from --tree; report
            // the real cause, not the synthetic name.
            Some(base) => bail!(
                "draft arch '{base}' has no multi-candidate/tree backend \
                 (tree drafting needs parallel heads ('medusa') or a \
                 recurrent drafter ('eagle3'/'mtp'))"
            ),
            None => bail!("unknown draft arch '{other}'"),
        },
    }
}

// ---------------------------------------------------------------------------
// shared hidden-pickup helpers (parallel-head archs: MEDUSA, MLP)
// ---------------------------------------------------------------------------

/// Bootstrap: pick up the last prompt position's hidden slice per row.
pub(crate) fn pickup_hidden_bootstrap(cx: &EngineCx, g: &mut GroupState, feats: &HostTensor) {
    let sp = cx.rt.manifest.prompt_len;
    let d = cx.tspec.d_model;
    let f3 = cx.tspec.feat_dim;
    let feats_full = feats.as_f32();
    for (row, seq) in g.seqs.iter_mut().enumerate() {
        let c = seq.len;
        let off = (row * sp + c - 1) * f3 + (f3 - d);
        seq.hidden = feats_full[off..off + d].to_vec();
    }
}

/// Advance: pick up the hidden at the accepted-prefix boundary per row.
pub(crate) fn pickup_hidden_advance(
    cx: &EngineCx,
    g: &mut GroupState,
    n_acc: &[usize],
    feats: &HostTensor,
) {
    let vt = cx.rt.manifest.verify_t;
    let d = cx.tspec.d_model;
    let f3 = cx.tspec.feat_dim;
    let feats_full = feats.as_f32();
    for row in 0..g.b {
        let j = n_acc[row];
        let off = (row * vt + j) * f3 + (f3 - d);
        g.seqs[row].hidden = feats_full[off..off + d].to_vec();
    }
}

// ---------------------------------------------------------------------------
// literal plumbing shared by the engine and the backends
// ---------------------------------------------------------------------------

/// Upload dynamic inputs. SAFETY CONTRACT: the source literals must stay
/// alive until the call consuming these buffers has been synced (the h2d
/// copy is async and borrows the literal) — every call site keeps the
/// `dyn_in` array in scope across `run_bufs`, which force-syncs outputs.
pub(crate) fn upload(rt: &Runtime, lits: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
    lits.iter().map(|l| rt.to_buffer(l)).collect()
}

/// Upload parameters, returning the buffers AND the literals backing
/// them — the engine stores both so the async copies can never outlive
/// their source (the crash mode this fixed is documented in
/// EXPERIMENTS.md §Perf).
pub(crate) fn upload_params(
    rt: &Runtime,
    params: &[HostTensor],
) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
    let lits: Vec<xla::Literal> = params.iter().map(pack::to_literal).collect::<Result<_>>()?;
    let bufs: Vec<xla::PjRtBuffer> =
        lits.iter().map(|l| rt.to_buffer(l)).collect::<Result<_>>()?;
    Ok((bufs, lits))
}

pub(crate) fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_f32(shape, data))
}

pub(crate) fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_i32(shape, data))
}

pub(crate) fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::scalar_i32(v))
}

pub(crate) fn lit_scalar_f32(v: f32) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::scalar_f32(v))
}

pub(crate) fn lit_zeros_f32(shape: &[usize]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::zeros(DType::F32, shape))
}

/// params1 ++ params2 ++ dynamic — as the &buffer slice run_bufs wants.
pub(crate) fn arg_refs<'a>(
    p1: &'a [xla::PjRtBuffer],
    p2: &'a [xla::PjRtBuffer],
    dynamic: &'a [xla::PjRtBuffer],
) -> Vec<&'a xla::PjRtBuffer> {
    p1.iter().chain(p2.iter()).chain(dynamic.iter()).collect()
}

/// Extract `tensor[row, idx, :]` from a [B, N, D]-shaped host tensor (or
/// `tensor[row, :]` from [B, D] with idx = 0).
pub(crate) fn tensor_row(t: &HostTensor, row: usize, shape: &[usize], idx: usize) -> Vec<f32> {
    let mut out = Vec::new();
    tensor_row_into(t, row, shape, idx, &mut out);
    out
}

/// Allocation-free `tensor_row` for the per-round hot loop.
pub(crate) fn tensor_row_into(
    t: &HostTensor,
    row: usize,
    shape: &[usize],
    idx: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(t.shape, shape);
    let dlast = *shape.last().unwrap();
    let n_mid = if shape.len() == 3 { shape[1] } else { 1 };
    let off = (row * n_mid + idx) * dlast;
    out.clear();
    out.extend(
        t.data[off * 4..(off + dlast) * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
}

/// Which AOT row-copy entry a device splice targets.
#[derive(Clone, Copy)]
pub(crate) enum KvSide {
    /// Target KV (`kv_copy_row_b{B}`, a target entry).
    Target,
    /// Draft KV (`dkv_copy_row_b{B}`, a draft entry).
    Draft,
}

/// Device-side one-row KV splice via the AOT copy entry. Ok(None) when
/// the artifact set predates the entry or the source is not the
/// bucket-1 shape the entry was lowered for — callers fall back to the
/// host `copy_literal_row` path. The restriction is harmless: the only
/// caller with a non-bucket-1 source is cross-bucket MIGRATION, which
/// routes through `gather_kv_rows_device` instead (the
/// `kv_gather_rows_b{Bsrc}x{Bdst}` entries cover every ordered bucket
/// pair, so no migration falls back to a host repack).
pub(crate) fn copy_kv_row_device(
    cx: &EngineCx,
    side: KvSide,
    b: usize,
    src_b: usize,
    dst: &xla::Literal,
    src: &xla::Literal,
    row: usize,
) -> Result<Option<xla::Literal>> {
    if src_b != 1 {
        return Ok(None);
    }
    let exe = match side {
        KvSide::Target => {
            let entry = format!("kv_copy_row_b{b}");
            if !cx.rt.has_target_entry(&cx.tspec.name, &entry) {
                return Ok(None);
            }
            cx.rt.target_entry(&cx.tspec.name, &entry)?
        }
        KvSide::Draft => {
            let entry = format!("dkv_copy_row_b{b}");
            if !cx.rt.has_draft_entry(&cx.dspec.name, &entry) {
                return Ok(None);
            }
            cx.rt.draft_entry(&cx.dspec.name, &entry)?
        }
    };
    let row_lit = lit_scalar_i32(row as i32)?;
    let outs = exe.run_lits(&[dst, src, &row_lit])?;
    Ok(outs.into_iter().next())
}

/// Device-side cross-bucket KV row gather via the AOT
/// `kv_gather_rows_b{Bsrc}x{Bdst}` / `dkv_gather_rows_b{Bsrc}x{Bdst}`
/// entries: result row `i` is source row `row_map[i]` (`row_map` may
/// repeat rows — migration clones a live row into padding slots). The
/// semantics mirror `kv::gather_rows` exactly; the bit-for-bit parity
/// is property-tested in `tests/properties.rs` / `tests/integration.rs`.
/// Ok(None) when the artifact set predates the entry — the migration
/// path treats that as a hard error (re-lower) rather than falling back
/// to a host repack, so device-path migrations move ZERO KV bytes
/// through the host.
pub(crate) fn gather_kv_rows_device(
    cx: &EngineCx,
    side: KvSide,
    src_b: usize,
    dst_b: usize,
    src: &xla::Literal,
    row_map: &[usize],
) -> Result<Option<xla::Literal>> {
    anyhow::ensure!(
        row_map.len() == dst_b,
        "gather row_map len {} != dst bucket {dst_b}",
        row_map.len()
    );
    let exe = match side {
        KvSide::Target => {
            let entry = format!("kv_gather_rows_b{src_b}x{dst_b}");
            if !cx.rt.has_target_entry(&cx.tspec.name, &entry) {
                return Ok(None);
            }
            cx.rt.target_entry(&cx.tspec.name, &entry)?
        }
        KvSide::Draft => {
            let entry = format!("dkv_gather_rows_b{src_b}x{dst_b}");
            if !cx.rt.has_draft_entry(&cx.dspec.name, &entry) {
                return Ok(None);
            }
            cx.rt.draft_entry(&cx.dspec.name, &entry)?
        }
    };
    let map: Vec<i32> = row_map.iter().map(|&r| r as i32).collect();
    let map_lit = lit_i32(&[dst_b], &map)?;
    let outs = exe.run_lits(&[src, &map_lit])?;
    Ok(outs.into_iter().next())
}

/// Pack the per-sequence host hiddens into the device-path `[B, d]`
/// conditioning literal (MEDUSA/MLP bootstrap).
pub(crate) fn hidden_lit(g: &GroupState, d: usize) -> Result<xla::Literal> {
    let b = g.b;
    let mut flat = vec![0f32; b * d];
    for (row, seq) in g.seqs.iter().enumerate() {
        flat[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
    }
    lit_f32(&[b, d], &flat)
}

/// Device-path join plumbing shared by the parallel-head backends: move
/// one row of the packed `[B, d]` conditioning literal between groups.
pub(crate) fn adopt_hidden_row(
    cx: &EngineCx,
    dst: &mut GroupState,
    dst_row: usize,
    src: &GroupState,
    src_row: usize,
) -> Result<()> {
    use anyhow::Context;
    let d = cx.tspec.d_model;
    let dst_h = dst.h_prev.take().context("adopt_row: dst hidden")?;
    let h = copy_literal_row(
        &dst_h,
        &spec_f32(vec![dst.b, d]),
        dst_row,
        src.h_prev.as_ref().context("adopt_row: src hidden")?,
        &spec_f32(vec![src.b, d]),
        src_row,
        0,
    )?;
    dst.h_prev = Some(h);
    Ok(())
}

/// Repack selected batch rows of a packed literal into a literal of a
/// different batch size: row `i` of the result is row `src_map[i]` of
/// `src` (`src_map` may repeat rows — padding rows clone a live one,
/// mirroring the bootstrap convention). One host round-trip total, not
/// one per row. Since the device gather entries took over KV migration
/// this only moves the SMALL conditioning carries (`[B, d]` hidden,
/// `[B, V]` q0) — never a KV cache.
pub(crate) fn repack_literal_rows(
    src: &xla::Literal,
    src_spec: &TensorSpec,
    src_map: &[usize],
    axis: usize,
) -> Result<(xla::Literal, TensorSpec)> {
    let host_src = pack::from_literal(src, src_spec, "repack_rows:src")?;
    let mut spec = src_spec.clone();
    spec.name = String::new();
    spec.shape[axis] = src_map.len();
    let mut host_dst = HostTensor::zeros(spec.dtype, &spec.shape);
    for (dst_row, &src_row) in src_map.iter().enumerate() {
        kv::copy_row(&mut host_dst, dst_row, &host_src, src_row, axis)?;
    }
    Ok((pack::to_literal(&host_dst)?, spec))
}

/// Downshift plumbing shared by the parallel-head backends (and the
/// recurrent hidden carry): repack the `[B, d]` conditioning literal.
pub(crate) fn migrate_hidden_rows(
    cx: &EngineCx,
    dst: &mut GroupState,
    src: &GroupState,
    src_map: &[usize],
) -> Result<()> {
    use anyhow::Context;
    let d = cx.tspec.d_model;
    let src_h = src.h_prev.as_ref().context("migrate_rows: src hidden")?;
    let (h, _) = repack_literal_rows(src_h, &spec_f32(vec![src.b, d]), src_map, 0)?;
    dst.h_prev = Some(h);
    Ok(())
}

/// Copy one batch row between two packed literals (join path). Both
/// literals round-trip through the host; the strided move itself is
/// `kv::copy_row`. Returns the updated destination literal.
pub(crate) fn copy_literal_row(
    dst: &xla::Literal,
    dst_spec: &TensorSpec,
    dst_row: usize,
    src: &xla::Literal,
    src_spec: &TensorSpec,
    src_row: usize,
    axis: usize,
) -> Result<xla::Literal> {
    let mut host_dst = pack::from_literal(dst, dst_spec, "copy_literal_row:dst")?;
    let host_src = pack::from_literal(src, src_spec, "copy_literal_row:src")?;
    kv::copy_row(&mut host_dst, dst_row, &host_src, src_row, axis)?;
    pack::to_literal(&host_dst)
}

/// Ad-hoc tensor spec for literals whose shape the engine knows exactly
/// (e.g. the [B, d] recurrent hidden carry).
pub(crate) fn spec_f32(shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: String::new(),
        shape,
        dtype: DType::F32,
    }
}
