//! Multi-candidate (tree) drafting over MEDUSA heads.
//!
//! MEDUSA's K parallel heads are token-independent: head `l` predicts
//! the token at offset `l + 1` from one conditioning hidden, regardless
//! of which candidates were picked in between. That makes it the natural
//! first tree backend — one `propose` pass feeds EVERY node of a
//! candidate tree, with node `i` drawing from its LEVEL's head
//! distribution (the classic MEDUSA tree construction; Yang et al. 2024
//! multi-candidate speculative decoding). Verification is the engine's
//! tree round: one tree-attention target pass judging all candidates,
//! the exact multi-draft rejection walk (`spec::sampling::verify_tree`),
//! then the accepted path's KV spliced back to consecutive positions.
//!
//! Candidate selection per node follows the fixed-uniform contract:
//! stochastic mode samples i.i.d. from the level distribution through
//! one host-drawn uniform per node (i.i.d. candidates + the residual
//! updates in the verify walk keep the output distribution exactly `p`);
//! the greedy modes enumerate distinct sibling-rank-th-largest tokens.
//!
//! Like the chain MEDUSA backend there is no draft-side KV: joins move
//! only the conditioning hidden, so `bootstrap`/`adopt_row` (and the
//! chain duties, for completeness) delegate to [`Medusa`].
//!
//! Device path: one `propose_tree_sample_b{B}` call samples every node
//! in-graph and hands the per-node full-vocab q tensors straight to
//! `verify_tree_fused_b{B}`; only the `[B, N]` candidate ids come back,
//! and the next round's conditioning hidden is the verify pass's
//! in-graph pickup at the stop position.

use anyhow::{Context, Result};

use crate::runtime::{DraftSpec, Runtime};
use crate::spec::sampling::TreeSpec;
use crate::tensor::HostTensor;

use super::medusa::Medusa;
use super::{
    arg_refs, lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, pickup_hidden_advance, upload,
    DraftBackend, EngineCx, GroupState, QFlat, DUMMY_UNIFORM,
};

pub struct MedusaTree;

impl DraftBackend for MedusaTree {
    fn name(&self) -> &'static str {
        "medusa-tree"
    }

    /// Depth cap: a path accepts at most one node per trained head.
    fn max_k(&self, rt: &Runtime, dspec: &DraftSpec) -> usize {
        Medusa.max_k(rt, dspec)
    }

    fn cost_model(&self) -> crate::spec::adaptive::CostModel {
        Medusa.cost_model()
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        Medusa.bootstrap(cx, g, tok_flat, feats)
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        Medusa.propose(cx, g, k, drafts, q)
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        Medusa.advance(cx, g, drafts, n_acc, feats)
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        Medusa.adopt_row(cx, dst, dst_row, src, src_row)
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        Medusa.migrate_rows(cx, dst, src, src_map)
    }

    // ------------------------------------------------------------------
    // tree duties
    // ------------------------------------------------------------------

    fn supports_tree(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest
            .serve_batches
            .iter()
            .all(|&b| rt.has_draft_entry(&dspec.name, &format!("propose_b{b}")))
    }

    fn propose_tree(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tree: &TreeSpec,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let n = tree.len();
        let d = cx.tspec.d_model;
        let vocab = cx.tspec.vocab;
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_b{b}"))?;
        let mut hidden = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter().enumerate() {
            hidden[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
        }
        let dyn_in = [lit_f32(&[b, d], &hidden)?];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.dparams, &[], &dyn_b);
        let outs = propose.run_bufs(&args)?;
        let logits = propose.output_host(&outs, 0)?.as_f32(); // [K, B, V]
        let mut rank_scratch = Vec::new();
        for row in 0..b {
            for node in 0..n {
                let off = (tree.level(node) * b + row) * vocab;
                let (full, compact) = q.slot(row, node);
                cx.write_draft_dist(&logits[off..off + vocab], compact, full);
                let xi = cx.sample_draft_tree(
                    &mut g.seqs[row].rng,
                    compact,
                    tree.rank(node),
                    &mut rank_scratch,
                );
                drafts[row][node] = cx.draft_token_id(xi);
            }
        }
        Ok(())
    }

    fn advance_tree(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _paths: &[Vec<usize>],
        stop_blk: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        // Stateless tree backend: no draft KV to splice (the per-path
        // contract's stateful half is a no-op here). The stop position
        // generalizes the chain's accepted-prefix boundary; the shared
        // pickup indexes feats by block slot.
        pickup_hidden_advance(cx, g, stop_blk, feats);
        Ok(())
    }

    fn supports_tree_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest
            .serve_batches
            .iter()
            .all(|&b| rt.has_draft_entry(&dspec.name, &format!("propose_tree_sample_b{b}")))
    }

    fn propose_tree_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        tree: &TreeSpec,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        let n = tree.len();
        let kq = cx.rt.manifest.verify_t - 1; // node slots the entry was lowered with
        // Node-order uniform draws mirror the host path's per-row loop;
        // slots beyond this tree get inert constants.
        let mut u = vec![DUMMY_UNIFORM; b * kq];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            for i in 0..n {
                u[row * kq + i] = cx.draft_uniform(&mut seq.rng);
            }
        }
        let level: Vec<i32> = (0..kq)
            .map(|i| if i < n { tree.level(i) as i32 } else { 0 })
            .collect();
        let rank: Vec<i32> = (0..kq)
            .map(|i| if i < n { tree.rank(i) as i32 } else { 0 })
            .collect();
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_tree_sample_b{b}"))?;
        let dyn_in = [
            g.h_prev.take().context("medusa-tree device hidden")?,
            lit_f32(&[b, kq], &u)?,
            lit_i32(&[kq], &level)?,
            lit_i32(&[kq], &rank)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.dparams, &[], &dyn_b);
        let outs = propose.run_bufs(&args)?;
        let toks = propose.output_host(&outs, 0)?.as_i32(); // [B, N] — O(B·N) ints
        for (row, dr) in drafts.iter_mut().enumerate() {
            for (i, slot) in dr.iter_mut().enumerate() {
                *slot = toks[row * kq + i];
            }
        }
        // All lowered q slots ride to verify_tree_fused; n_active masks
        // the slots beyond this tree in-graph.
        q_dev.extend(outs.into_iter().skip(1));
        Ok(())
    }

    fn advance_tree_device(
        &self,
        _cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _paths: &[Vec<usize>],
        _n_path_lit: xla::Literal,
        _feats: xla::Literal,
        h_sel: xla::Literal,
    ) -> Result<()> {
        // The fused tree pass already picked the stop position's hidden
        // in-graph; it conditions the next round as-is.
        g.h_prev = Some(h_sel);
        Ok(())
    }
}
