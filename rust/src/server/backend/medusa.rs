//! MEDUSA draft backend: K parallel heads proposing from one conditioning
//! hidden state; no draft-side KV, so continuous-batching joins move only
//! the per-sequence hidden (carried inside `SeqState`).

use anyhow::Result;

use crate::runtime::{DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    arg_refs, lit_f32, pickup_hidden_advance, pickup_hidden_bootstrap, upload, DraftBackend,
    EngineCx, GroupState,
};

pub struct Medusa;

impl DraftBackend for Medusa {
    fn name(&self) -> &'static str {
        "medusa"
    }

    fn max_k(&self, _rt: &Runtime, dspec: &DraftSpec) -> usize {
        dspec.k_heads
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_bootstrap(cx, g, feats);
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        drafts: &mut [Vec<i32>],
        q_full: &mut [Vec<Vec<f32>>],
    ) -> Result<()> {
        let b = g.b;
        let k = cx.k;
        let d = cx.tspec.d_model;
        let vocab = cx.tspec.vocab;
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_b{b}"))?;
        let mut hidden = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter().enumerate() {
            hidden[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
        }
        let dyn_in = [lit_f32(&[b, d], &hidden)?];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.dparams, &[], &dyn_b);
        let outs = propose.run_bufs(&args)?;
        let logits = propose.output_host(&outs, 0)?.as_f32(); // [K,B,V]
        for row in 0..b {
            for i in 0..k {
                let off = (i * b + row) * vocab;
                let (qf, qc) = cx.draft_dist(&logits[off..off + vocab]);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, &qc);
                drafts[row][i] = cx.draft_token_id(xi);
                q_full[row].push(qf);
            }
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_advance(cx, g, n_acc, feats);
        Ok(())
    }

    fn adopt_row(
        &self,
        _cx: &EngineCx,
        _dst: &mut GroupState,
        _dst_row: usize,
        _src: &GroupState,
        _src_row: usize,
    ) -> Result<()> {
        // All draft state is per-sequence host state; nothing packed.
        Ok(())
    }
}
