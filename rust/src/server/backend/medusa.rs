//! MEDUSA draft backend: K parallel heads proposing from one conditioning
//! hidden state; no draft-side KV, so continuous-batching joins move only
//! the per-sequence hidden (carried inside `SeqState` on the host path,
//! as the `[B, d]` `h_prev` literal on the device path).
//!
//! Device verify path: one `propose_sample` call samples every head
//! in-graph from host-fed uniforms and hands the K full-vocab q tensors
//! straight to the fused verify entry; the conditioning hidden for the
//! next round is the verify pass's in-graph pickup (`h_sel`).

use anyhow::{Context, Result};

use crate::runtime::{DraftSpec, Runtime};
use crate::tensor::HostTensor;

use super::{
    adopt_hidden_row, arg_refs, hidden_lit, lit_f32, lit_scalar_f32, lit_scalar_i32,
    migrate_hidden_rows, pickup_hidden_advance, pickup_hidden_bootstrap, upload, DraftBackend,
    EngineCx, GroupState, QFlat, DUMMY_UNIFORM,
};

pub struct Medusa;

impl DraftBackend for Medusa {
    fn name(&self) -> &'static str {
        "medusa"
    }

    fn max_k(&self, _rt: &Runtime, dspec: &DraftSpec) -> usize {
        dspec.k_heads
    }

    fn cost_model(&self) -> crate::spec::adaptive::CostModel {
        // One propose pass prices every head: drafting deeper is free.
        crate::spec::adaptive::CostModel::parallel()
    }

    fn supports_device(&self, rt: &Runtime, dspec: &DraftSpec) -> bool {
        rt.manifest
            .serve_batches
            .iter()
            .all(|&b| rt.has_draft_entry(&dspec.name, &format!("propose_sample_b{b}")))
    }

    fn bootstrap(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _tok_flat: &[i32],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_bootstrap(cx, g, feats);
        if cx.device_verify {
            g.h_prev = Some(hidden_lit(g, cx.tspec.d_model)?);
        }
        Ok(())
    }

    fn propose(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q: &mut QFlat,
    ) -> Result<()> {
        let b = g.b;
        let d = cx.tspec.d_model;
        let vocab = cx.tspec.vocab;
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_b{b}"))?;
        let mut hidden = vec![0f32; b * d];
        for (row, seq) in g.seqs.iter().enumerate() {
            hidden[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
        }
        let dyn_in = [lit_f32(&[b, d], &hidden)?];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.dparams, &[], &dyn_b);
        let outs = propose.run_bufs(&args)?;
        let logits = propose.output_host(&outs, 0)?.as_f32(); // [K,B,V]
        for row in 0..b {
            for i in 0..k {
                let off = (i * b + row) * vocab;
                let (full, compact) = q.slot(row, i);
                cx.write_draft_dist(&logits[off..off + vocab], compact, full);
                let xi = cx.sample_draft(&mut g.seqs[row].rng, compact);
                drafts[row][i] = cx.draft_token_id(xi);
            }
        }
        Ok(())
    }

    fn propose_device(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        k: usize,
        drafts: &mut [Vec<i32>],
        q_dev: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        let b = g.b;
        let kh = cx.dspec.k_heads;
        // Row-major uniform draws mirror the host path's per-row loop;
        // heads beyond this round's k get inert constants (their
        // in-graph samples are discarded).
        let mut u = vec![DUMMY_UNIFORM; b * kh];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            for i in 0..k {
                u[row * kh + i] = cx.draft_uniform(&mut seq.rng);
            }
        }
        let propose = cx
            .rt
            .draft_entry(&cx.dspec.name, &format!("propose_sample_b{b}"))?;
        let dyn_in = [
            g.h_prev.take().context("medusa device hidden")?,
            lit_f32(&[b, kh], &u)?,
            lit_scalar_f32(cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(cx.opts.mode.device_code())?,
        ];
        let dyn_b = upload(cx.rt, &dyn_in)?;
        let args = arg_refs(&cx.dparams, &[], &dyn_b);
        let outs = propose.run_bufs(&args)?;
        let toks = propose.output_host(&outs, 0)?.as_i32(); // [B, Kh] — O(B·K) ints
        for (row, dr) in drafts.iter_mut().enumerate() {
            for (i, slot) in dr.iter_mut().enumerate().take(k) {
                *slot = toks[row * kh + i];
            }
        }
        for (i, lit) in outs.into_iter().enumerate().skip(1) {
            if i <= k {
                q_dev.push(lit); // q_0..q_{k-1}, device-resident
            }
        }
        Ok(())
    }

    fn advance(
        &self,
        cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        n_acc: &[usize],
        feats: &HostTensor,
    ) -> Result<()> {
        pickup_hidden_advance(cx, g, n_acc, feats);
        Ok(())
    }

    fn advance_device(
        &self,
        _cx: &EngineCx,
        g: &mut GroupState,
        _drafts: &[Vec<i32>],
        _n_acc: &[usize],
        _n_acc_lit: xla::Literal,
        _feats: xla::Literal,
        h_sel: xla::Literal,
    ) -> Result<()> {
        // The verify pass already picked the accepted-boundary hidden
        // in-graph; it becomes next round's conditioning as-is.
        g.h_prev = Some(h_sel);
        Ok(())
    }

    fn adopt_row(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        dst_row: usize,
        src: &GroupState,
        src_row: usize,
    ) -> Result<()> {
        // Host path: all draft state is per-sequence host state. Device
        // path: the conditioning hidden lives in the packed literal.
        if cx.device_verify {
            adopt_hidden_row(cx, dst, dst_row, src, src_row)?;
        }
        Ok(())
    }

    fn migrate_rows(
        &self,
        cx: &EngineCx,
        dst: &mut GroupState,
        src: &GroupState,
        src_map: &[usize],
    ) -> Result<()> {
        // Host path: all draft state is per-sequence (`SeqState::hidden`,
        // moved by the engine). Device path: repack the hidden carry.
        if cx.device_verify {
            migrate_hidden_rows(cx, dst, src, src_map)?;
        }
        Ok(())
    }
}
