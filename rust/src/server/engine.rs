//! The speculative-decoding engine: draft-then-verify decode loop.
//!
//! One `SpecEngine` serves one (target, draft) pair. Sequences decode in
//! lockstep *groups* whose KV caches live packed in batched XLA literals
//! that flow executable-to-executable without host round-trips (only
//! logits/features — a few KB — are pulled to the host each round). Per
//! round, for a group:
//!
//!   1. drafts: K tokens per sequence — chained `draft step` calls for
//!      recurrent archs (EAGLE-3 / MTP), one `propose` for MEDUSA, K
//!      `mlp step`s for the MLP speculator; ALL sampling happens here in
//!      Rust (`spec::sampling`), the executables only produce logits;
//!   2. verify: one target call over [last_token, draft_1..draft_K];
//!   3. acceptance: the exact Leviathan rule per position (or the greedy
//!      / greedy-draft variants), residual resampling, bonus token;
//!   4. state advance: draft-cache extension with the accepted positions'
//!      fused features (recurrent) or hidden pickup (MEDUSA/MLP).
//!
//! Index contract (mirrors python/compile/drafts.py):
//!   `len` = processed target positions; `last_token` = accepted but not
//!   yet processed; the verify block occupies positions len..len+K and
//!   its logits[i] give p(·| …, block[..=i]).

use anyhow::{bail, Context, Result};

use crate::runtime::{pack, DraftSpec, Runtime, TargetSpec};
use crate::spec::accept::AcceptanceStats;
use crate::spec::sampling::{self, SamplingMode, Verdict};
use crate::tensor::{Checkpoint, HostTensor};

use crate::train::checkpoint_to_params;
use crate::util::Pcg64;

/// Draft-architecture behaviour class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Recurrent, // eagle3 / mtp: own KV cache + hidden-state recurrence
    Medusa,    // parallel heads from one hidden state
    Mlp,       // per-head recurrent MLP state
}

#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Draft tokens per round (chain length). Recurrent archs may exceed
    /// the K=6 trained heads up to verify_t - 1 = 7; parallel-head archs
    /// are capped at their head count.
    pub k_draft: usize,
    pub temperature: f32,
    pub mode: SamplingMode,
    pub seed: u64,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            k_draft: 7,
            temperature: 1.0,
            mode: SamplingMode::Stochastic,
            seed: 1234,
        }
    }
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub tokens: Vec<i32>,
    pub stats: AcceptanceStats,
    pub latency_ms: f64,
    pub rounds: u64,
}

struct SeqState {
    len: usize,      // processed target positions
    last_token: i32, // accepted, unprocessed
    generated: Vec<i32>,
    max_new: usize,
    rng: Pcg64,
    stats: AcceptanceStats,
    done: bool,
    hidden: Vec<f32>, // [d] MEDUSA/MLP conditioning hidden
    q1: Vec<f32>,     // recurrent: q-logits for draft 1 of next round
}

/// A lockstep decode group with packed caches (literals stay device-side).
struct Group {
    b: usize,
    seqs: Vec<SeqState>, // indices == batch rows (padding rows cloned)
    tkv: xla::Literal,
    dkv: Option<xla::Literal>,
    h_prev: Option<xla::Literal>, // [B, d]
}

pub struct SpecEngine<'rt> {
    pub rt: &'rt Runtime,
    tspec: TargetSpec,
    dspec: DraftSpec,
    kind: Kind,
    tparams: Vec<xla::PjRtBuffer>,
    dparams: Vec<xla::PjRtBuffer>,
    // Source literals MUST outlive the buffers: BufferFromHostLiteral's
    // h2d copy is asynchronous and references the literal from a worker
    // thread (upstream xla_rs awaits the ready future for this reason).
    _param_lits: Vec<xla::Literal>,
    vocab_map: Option<Vec<i32>>,
    pub opts: EngineOpts,
    k: usize, // drafts per round
    pub metrics: super::metrics::EngineMetrics,
    next_seed: u64,
}

impl<'rt> SpecEngine<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        draft_name: &str,
        tckpt: &Checkpoint,
        dckpt: &Checkpoint,
        vocab_map: Option<Vec<i32>>,
        opts: EngineOpts,
    ) -> Result<SpecEngine<'rt>> {
        let dspec = rt.manifest.draft(draft_name)?.clone();
        let tspec = rt.manifest.target(&dspec.target)?.clone();
        let kind = match dspec.arch.as_str() {
            "eagle3" | "mtp" => Kind::Recurrent,
            "medusa" => Kind::Medusa,
            "mlp" => Kind::Mlp,
            other => bail!("unknown arch {other}"),
        };
        if dspec.arch == "eagle3" && vocab_map.is_none() {
            bail!("eagle3 needs a vocab map");
        }
        let max_k = match kind {
            Kind::Recurrent => rt.manifest.verify_t - 1,
            _ => dspec.k_heads,
        };
        let mut opts = opts;
        opts.k_draft = opts.k_draft.min(max_k);
        // Parameters are uploaded ONCE as device buffers and reused by
        // every call — the single biggest serving-path optimization on
        // this runtime (no per-call h2d of the full model).
        let (tparams, tlits) = upload_params(rt, &checkpoint_to_params(&tspec.params, tckpt)?)?;
        let (dparams, dlits) = upload_params(rt, &checkpoint_to_params(&dspec.params, dckpt)?)?;
        let mut _param_lits = tlits;
        _param_lits.extend(dlits);
        Ok(SpecEngine {
            rt,
            tspec,
            dspec,
            kind,
            tparams,
            dparams,
            _param_lits,
            vocab_map,
            k: opts.k_draft,
            opts,
            metrics: super::metrics::EngineMetrics::default(),
            next_seed: 1,
        })
    }

    pub fn target_name(&self) -> &str {
        &self.tspec.name
    }

    pub fn k_draft(&self) -> usize {
        self.k
    }

    fn bucket(&self, n: usize) -> usize {
        *self
            .rt
            .manifest
            .serve_batches
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.rt.manifest.serve_batches.last().unwrap())
    }

    // ------------------------------------------------------------------
    // distribution helpers
    // ------------------------------------------------------------------

    /// Draft logits (possibly truncated vocab) -> (q over full vocab,
    /// q over draft vocab) at the engine temperature.
    fn draft_dist(&self, logits: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let qc = sampling::softmax_t(logits, self.opts.temperature.max(1e-3));
        match &self.vocab_map {
            None => (qc.clone(), qc),
            Some(map) => {
                let mut full = vec![0f32; self.tspec.vocab];
                for (i, &fid) in map.iter().enumerate() {
                    full[fid as usize] = qc[i];
                }
                (full, qc)
            }
        }
    }

    fn draft_token_id(&self, compact_idx: usize) -> i32 {
        match &self.vocab_map {
            None => compact_idx as i32,
            Some(map) => map[compact_idx],
        }
    }

    fn sample_draft(&self, rng: &mut Pcg64, q_compact: &[f32]) -> usize {
        match self.opts.mode {
            SamplingMode::Stochastic => sampling::sample_categorical(rng, q_compact),
            SamplingMode::Greedy | SamplingMode::GreedyDraft => sampling::argmax(q_compact),
        }
    }

    fn sample_target(&self, rng: &mut Pcg64, p: &[f32]) -> i32 {
        match self.opts.mode {
            SamplingMode::Greedy => sampling::argmax(p) as i32,
            _ => sampling::sample_categorical(rng, p) as i32,
        }
    }

    // ------------------------------------------------------------------
    // group construction (prefill path)
    // ------------------------------------------------------------------

    fn make_group(&mut self, prompts: &[Vec<i32>], max_new: usize) -> Result<Group> {
        let n = prompts.len();
        let b = self.bucket(n);
        let sp = self.rt.manifest.prompt_len;
        let d = self.tspec.d_model;
        let vocab = self.tspec.vocab;

        // --- target prefill ------------------------------------------
        let mut tok_flat = vec![0i32; b * sp];
        let mut lens = vec![0usize; b];
        for row in 0..b {
            let p = &prompts[row.min(n - 1)]; // clone last prompt into padding
            anyhow::ensure!(p.len() >= 2 && p.len() <= sp, "prompt length {} not in 2..={sp}", p.len());
            lens[row] = p.len();
            tok_flat[row * sp..row * sp + p.len()].copy_from_slice(p);
        }
        let prefill = self.rt.target_entry(&self.tspec.name, &format!("prefill_b{b}"))?;
        let dyn_in = [
            lit_i32(&[b, sp], &tok_flat)?,
            lit_scalar_i32(lens[0] as i32)?,
        ];
        let dyn_b = upload(self.rt, &dyn_in)?;
        let args = arg_refs(&self.tparams, &[], &dyn_b);
        let outs = prefill.run_bufs(&args)?;
        let logits = prefill.output_host(&outs, 0)?;
        let feats = prefill.output_host(&outs, 2)?;
        let tkv = outs.into_iter().nth(1).unwrap();

        // --- per-sequence bootstrap -----------------------------------
        let mut seqs = Vec::with_capacity(b);
        for row in 0..b {
            let c = lens[row];
            let mut rng = Pcg64::new(self.opts.seed, self.next_seed);
            self.next_seed += 1;
            let lrow = tensor_row(&logits, row, &[b, sp, vocab], c - 1);
            let p = sampling::softmax_t(&lrow, self.opts.temperature.max(1e-3));
            let first = self.sample_target(&mut rng, &p);
            seqs.push(SeqState {
                len: c,
                last_token: first,
                generated: vec![first],
                max_new,
                rng,
                stats: AcceptanceStats::new(self.k),
                done: row >= n, // padding rows start done
                hidden: Vec::new(),
                q1: Vec::new(),
            });
        }

        let mut group = Group {
            b,
            seqs,
            tkv,
            dkv: None,
            h_prev: None,
        };

        // --- draft bootstrap -------------------------------------------
        match self.kind {
            Kind::Recurrent => {
                let fdim = self.dspec.fuse_dim;
                let f3 = self.tspec.feat_dim;
                let feats_full = feats.as_f32();
                let mut feats_in = vec![0f32; b * sp * fdim];
                let mut tnext = vec![0i32; b * sp];
                for (row, seq) in group.seqs.iter().enumerate() {
                    let c = seq.len;
                    for t in 0..sp {
                        let base = (row * sp + t) * f3;
                        feats_in[(row * sp + t) * fdim..(row * sp + t + 1) * fdim]
                            .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
                    }
                    for t in 0..c - 1 {
                        tnext[row * sp + t] = tok_flat[row * sp + t + 1];
                    }
                    tnext[row * sp + c - 1] = seq.last_token;
                }
                let extend = self
                    .rt
                    .draft_entry(&self.dspec.name, &format!("extend_p_b{b}"))?;
                let dkv0 = lit_zeros_f32(&[
                    2,
                    b,
                    self.tspec.n_heads,
                    self.tspec.max_seq,
                    self.tspec.head_dim,
                ])?;
                let dyn_in = [
                    dkv0,
                    lit_f32(&[b, sp, fdim], &feats_in)?,
                    lit_i32(&[b, sp], &tnext)?,
                    lit_i32(&[b], &vec![0i32; b])?,
                ];
                let dyn_b = upload(self.rt, &dyn_in)?;
                let args = arg_refs(&self.tparams, &self.dparams, &dyn_b);
                let outs = extend.run_bufs(&args)?;
                let q_all = extend.output_host(&outs, 0)?; // [B,Sp,Vd]
                let h_all = extend.output_host(&outs, 1)?; // [B,Sp,d]
                let vd = self.dspec.draft_vocab;
                let mut hprev = vec![0f32; b * d];
                for (row, seq) in group.seqs.iter_mut().enumerate() {
                    let c = seq.len;
                    seq.q1 = tensor_row(&q_all, row, &[b, sp, vd], c - 1);
                    hprev[row * d..(row + 1) * d]
                        .copy_from_slice(&tensor_row(&h_all, row, &[b, sp, d], c - 1));
                }
                group.dkv = Some(outs.into_iter().nth(2).unwrap());
                group.h_prev = Some(lit_f32(&[b, d], &hprev)?);
            }
            Kind::Medusa | Kind::Mlp => {
                let f3 = self.tspec.feat_dim;
                let feats_full = feats.as_f32();
                for (row, seq) in group.seqs.iter_mut().enumerate() {
                    let c = seq.len;
                    let off = (row * sp + c - 1) * f3 + (f3 - d);
                    seq.hidden = feats_full[off..off + d].to_vec();
                }
            }
        }
        Ok(group)
    }

    // ------------------------------------------------------------------
    // one draft-verify round for the whole group
    // ------------------------------------------------------------------

    fn round(&mut self, g: &mut Group) -> Result<()> {
        let b = g.b;
        let k = self.k;
        let vt = self.rt.manifest.verify_t;
        let vocab = self.tspec.vocab;
        let d = self.tspec.d_model;

        // --- 1. draft K tokens per row ---------------------------------
        let mut drafts = vec![vec![0i32; k]; b];
        let mut q_full: Vec<Vec<Vec<f32>>> = vec![Vec::with_capacity(k); b];
        match self.kind {
            Kind::Recurrent => {
                let step = self
                    .rt
                    .draft_entry(&self.dspec.name, &format!("step_b{b}"))?;
                let vd = self.dspec.draft_vocab;
                let mut q_logits: Vec<Vec<f32>> =
                    g.seqs.iter().map(|s| s.q1.clone()).collect();
                for i in 0..k {
                    let mut toks = vec![0i32; b];
                    for row in 0..b {
                        let (qf, qc) = self.draft_dist(&q_logits[row]);
                        let xi = self.sample_draft(&mut g.seqs[row].rng, &qc);
                        drafts[row][i] = self.draft_token_id(xi);
                        q_full[row].push(qf);
                        toks[row] = drafts[row][i];
                    }
                    if i + 1 == k {
                        break; // q_{k+1} never needed
                    }
                    let pos: Vec<i32> = g.seqs.iter().map(|s| (s.len + i) as i32).collect();
                    let dyn_in = [
                        g.dkv.take().context("dkv")?,
                        g.h_prev.take().context("h_prev")?,
                        lit_i32(&[b], &toks)?,
                        lit_i32(&[b], &pos)?,
                    ];
                    let dyn_b = upload(self.rt, &dyn_in)?;
                    let args = arg_refs(&self.tparams, &self.dparams, &dyn_b);
                    let outs = step.run_bufs(&args)?;
                    let ql = step.output_host(&outs, 0)?;
                    for row in 0..b {
                        q_logits[row] = tensor_row(&ql, row, &[b, vd], 0);
                    }
                    let mut it = outs.into_iter();
                    let _ = it.next(); // logits
                    g.h_prev = Some(it.next().unwrap());
                    g.dkv = Some(it.next().unwrap());
                }
            }
            Kind::Medusa => {
                let propose = self
                    .rt
                    .draft_entry(&self.dspec.name, &format!("propose_b{b}"))?;
                let mut hidden = vec![0f32; b * d];
                for (row, seq) in g.seqs.iter().enumerate() {
                    hidden[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
                }
                let dyn_in = [lit_f32(&[b, d], &hidden)?];
                let dyn_b = upload(self.rt, &dyn_in)?;
                let args = arg_refs(&self.dparams, &[], &dyn_b);
                let outs = propose.run_bufs(&args)?;
                let logits = propose.output_host(&outs, 0)?.as_f32(); // [K,B,V]
                for row in 0..b {
                    for i in 0..k {
                        let off = (i * b + row) * vocab;
                        let (qf, qc) = self.draft_dist(&logits[off..off + vocab]);
                        let xi = self.sample_draft(&mut g.seqs[row].rng, &qc);
                        drafts[row][i] = self.draft_token_id(xi);
                        q_full[row].push(qf);
                    }
                }
            }
            Kind::Mlp => {
                let step = self
                    .rt
                    .draft_entry(&self.dspec.name, &format!("step_b{b}"))?;
                let mut state = vec![0f32; b * d];
                for (row, seq) in g.seqs.iter().enumerate() {
                    state[row * d..(row + 1) * d].copy_from_slice(&seq.hidden);
                }
                let mut state_t = lit_f32(&[b, d], &state)?;
                let mut toks: Vec<i32> = g.seqs.iter().map(|s| s.last_token).collect();
                for i in 0..k {
                    let dyn_in = [
                        state_t,
                        lit_i32(&[b], &toks)?,
                        lit_scalar_i32(i as i32)?,
                    ];
                    let dyn_b = upload(self.rt, &dyn_in)?;
                    let args = arg_refs(&self.tparams, &self.dparams, &dyn_b);
                    let outs = step.run_bufs(&args)?;
                    let lg = step.output_host(&outs, 0)?;
                    for row in 0..b {
                        let lrow = tensor_row(&lg, row, &[b, vocab], 0);
                        let (qf, qc) = self.draft_dist(&lrow);
                        let xi = self.sample_draft(&mut g.seqs[row].rng, &qc);
                        drafts[row][i] = self.draft_token_id(xi);
                        q_full[row].push(qf);
                        toks[row] = drafts[row][i];
                    }
                    state_t = outs.into_iter().nth(1).unwrap();
                }
            }
        }

        // --- 2. verify ---------------------------------------------------
        let verify = self
            .rt
            .target_entry(&self.tspec.name, &format!("verify_b{b}"))?;
        let mut vtok = vec![0i32; b * vt];
        for (row, seq) in g.seqs.iter().enumerate() {
            vtok[row * vt] = seq.last_token;
            for i in 0..k {
                vtok[row * vt + 1 + i] = drafts[row][i];
            }
        }
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?); // placeholder
        let dyn_in = [tkv, lit_i32(&[b, vt], &vtok)?, lit_i32(&[b], &pos)?];
        let dyn_b = upload(self.rt, &dyn_in)?;
        let args = arg_refs(&self.tparams, &[], &dyn_b);
        let outs = verify.run_bufs(&args)?;
        let logits = verify.output_host(&outs, 0)?; // [B, vt, V]
        let feats = verify.output_host(&outs, 2)?; // [B, vt, 3d]
        g.tkv = outs.into_iter().nth(1).unwrap();

        // --- 3. acceptance per row ---------------------------------------
        let temp = self.opts.temperature.max(1e-3);
        let mut n_acc = vec![0usize; b];
        for row in 0..b {
            let seq = &mut g.seqs[row];
            if seq.done {
                continue;
            }
            let mut j = 0usize;
            let mut replacement: Option<i32> = None;
            while j < k {
                let l = tensor_row(&logits, row, &[b, vt, vocab], j);
                let p = sampling::softmax_t(&l, temp);
                let x = drafts[row][j] as usize;
                match sampling::verify_token(&mut seq.rng, &p, &q_full[row][j], x, self.opts.mode)
                {
                    Verdict::Accept => j += 1,
                    Verdict::Reject { replacement: r } => {
                        replacement = Some(r);
                        break;
                    }
                }
            }
            seq.stats.record_round(k, j);
            for item in drafts[row].iter().take(j) {
                seq.generated.push(*item);
            }
            let y = match replacement {
                Some(r) => r,
                None => {
                    let l = tensor_row(&logits, row, &[b, vt, vocab], j);
                    let p = sampling::softmax_t(&l, temp);
                    self.sample_target(&mut seq.rng, &p)
                }
            };
            seq.generated.push(y);
            seq.len += 1 + j; // last_token + accepted drafts now processed
            seq.last_token = y;
            n_acc[row] = j;
            if seq.generated.len() >= seq.max_new {
                seq.done = true;
            }
        }

        // --- 4. advance draft state --------------------------------------
        match self.kind {
            Kind::Recurrent => {
                let fdim = self.dspec.fuse_dim;
                let f3 = self.tspec.feat_dim;
                let feats_full = feats.as_f32();
                let mut feats_in = vec![0f32; b * vt * fdim];
                let mut tnext = vec![0i32; b * vt];
                let mut pos = vec![0i32; b];
                for row in 0..b {
                    let seq = &g.seqs[row];
                    let j = n_acc[row];
                    for t in 0..vt {
                        let base = (row * vt + t) * f3;
                        feats_in[(row * vt + t) * fdim..(row * vt + t + 1) * fdim]
                            .copy_from_slice(&feats_full[base + (f3 - fdim)..base + f3]);
                    }
                    for (t, item) in drafts[row].iter().enumerate().take(j) {
                        tnext[row * vt + t] = *item;
                    }
                    tnext[row * vt + j] = seq.last_token;
                    // extend starts where this round's verify block started
                    pos[row] = if seq.done {
                        (seq.len.saturating_sub(1 + j)) as i32
                    } else {
                        (seq.len - 1 - j) as i32
                    };
                }
                let extend = self
                    .rt
                    .draft_entry(&self.dspec.name, &format!("extend_k_b{b}"))?;
                let dyn_in = [
                    g.dkv.take().context("dkv")?,
                    lit_f32(&[b, vt, fdim], &feats_in)?,
                    lit_i32(&[b, vt], &tnext)?,
                    lit_i32(&[b], &pos)?,
                ];
                let dyn_b = upload(self.rt, &dyn_in)?;
                let args = arg_refs(&self.tparams, &self.dparams, &dyn_b);
                let outs = extend.run_bufs(&args)?;
                let q_all = extend.output_host(&outs, 0)?;
                let h_all = extend.output_host(&outs, 1)?;
                let vd = self.dspec.draft_vocab;
                let mut hprev = vec![0f32; b * d];
                for row in 0..b {
                    let j = n_acc[row];
                    let seq = &mut g.seqs[row];
                    seq.q1 = tensor_row(&q_all, row, &[b, vt, vd], j);
                    hprev[row * d..(row + 1) * d]
                        .copy_from_slice(&tensor_row(&h_all, row, &[b, vt, d], j));
                }
                g.dkv = Some(outs.into_iter().nth(2).unwrap());
                g.h_prev = Some(lit_f32(&[b, d], &hprev)?);
            }
            Kind::Medusa | Kind::Mlp => {
                let f3 = self.tspec.feat_dim;
                let feats_full = feats.as_f32();
                for row in 0..b {
                    let j = n_acc[row];
                    let off = (row * vt + j) * f3 + (f3 - d);
                    g.seqs[row].hidden = feats_full[off..off + d].to_vec();
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // public entry points
    // ------------------------------------------------------------------

    /// Run a batch of prompts to completion in lockstep. Returns results
    /// in prompt order.
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<RequestResult>> {
        anyhow::ensure!(!prompts.is_empty());
        let t0 = std::time::Instant::now();
        let mut g = self.make_group(prompts, max_new)?;
        let mut rounds = 0u64;
        while g.seqs.iter().any(|s| !s.done) {
            self.round(&mut g)?;
            rounds += 1;
            if rounds > (max_new * 4 + 16) as u64 {
                bail!("round budget exceeded — engine stuck?");
            }
        }
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        let results: Vec<RequestResult> = g
            .seqs
            .iter()
            .take(prompts.len())
            .map(|s| RequestResult {
                tokens: s.generated.clone(),
                stats: s.stats.clone(),
                latency_ms: total_ms,
                rounds,
            })
            .collect();
        for r in &results {
            self.metrics.observe_request(r);
        }
        Ok(results)
    }

    /// Vanilla autoregressive baseline (no speculation): one target
    /// decode call per token. Used for Table 4 speedups.
    pub fn generate_vanilla(&mut self, prompt: &[i32], max_new: usize) -> Result<RequestResult> {
        let t0 = std::time::Instant::now();
        let b = 1usize;
        let sp = self.rt.manifest.prompt_len;
        let vocab = self.tspec.vocab;
        anyhow::ensure!(prompt.len() >= 2 && prompt.len() <= sp);
        let mut tok_flat = vec![0i32; sp];
        tok_flat[..prompt.len()].copy_from_slice(prompt);
        let prefill = self.rt.target_entry(&self.tspec.name, "prefill_b1")?;
        let dyn_in = [
            lit_i32(&[b, sp], &tok_flat)?,
            lit_scalar_i32(prompt.len() as i32)?,
        ];
        let dyn_b = upload(self.rt, &dyn_in)?;
        let args = arg_refs(&self.tparams, &[], &dyn_b);
        let outs = prefill.run_bufs(&args)?;
        let logits = prefill.output_host(&outs, 0)?;
        let mut tkv = outs.into_iter().nth(1).unwrap();

        let mut rng = Pcg64::new(self.opts.seed, 0x7a71);
        let temp = self.opts.temperature.max(1e-3);
        let lrow = tensor_row(&logits, 0, &[b, sp, vocab], prompt.len() - 1);
        let p = sampling::softmax_t(&lrow, temp);
        let mut last = self.sample_target(&mut rng, &p);
        let mut generated = vec![last];
        let mut len = prompt.len();
        let decode = self.rt.target_entry(&self.tspec.name, "decode_b1")?;
        while generated.len() < max_new {
            let dyn_in = [tkv, lit_i32(&[b, 1], &[last])?, lit_i32(&[b], &[len as i32])?];
            let dyn_b = upload(self.rt, &dyn_in)?;
            let args = arg_refs(&self.tparams, &[], &dyn_b);
            let outs = decode.run_bufs(&args)?;
            let lg = decode.output_host(&outs, 0)?;
            let lrow = tensor_row(&lg, 0, &[b, 1, vocab], 0);
            let p = sampling::softmax_t(&lrow, temp);
            last = self.sample_target(&mut rng, &p);
            generated.push(last);
            len += 1;
            tkv = outs.into_iter().nth(1).unwrap();
        }
        Ok(RequestResult {
            tokens: generated,
            stats: AcceptanceStats::new(self.k),
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            rounds: max_new as u64,
        })
    }
}

// ---------------------------------------------------------------------------
// small helpers
// ---------------------------------------------------------------------------

/// Upload dynamic inputs. SAFETY CONTRACT: the source literals must stay
/// alive until the call consuming these buffers has been synced (the h2d
/// copy is async and borrows the literal) — every call site keeps the
/// `dyn_in` array in scope across `run_bufs`, which force-syncs outputs.
fn upload(rt: &Runtime, lits: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
    lits.iter().map(|l| rt.to_buffer(l)).collect()
}

/// Upload parameters, returning the buffers AND the literals backing
/// them — the engine stores both so the async copies can never outlive
/// their source (the crash mode this fixed is documented in
/// EXPERIMENTS.md §Perf).
fn upload_params(
    rt: &Runtime,
    params: &[HostTensor],
) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
    let lits: Vec<xla::Literal> = params.iter().map(pack::to_literal).collect::<Result<_>>()?;
    let bufs: Vec<xla::PjRtBuffer> =
        lits.iter().map(|l| rt.to_buffer(l)).collect::<Result<_>>()?;
    Ok((bufs, lits))
}

fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_f32(shape, data))
}

fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::from_i32(shape, data))
}

fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::scalar_i32(v))
}

fn lit_zeros_f32(shape: &[usize]) -> Result<xla::Literal> {
    pack::to_literal(&HostTensor::zeros(crate::tensor::DType::F32, shape))
}

/// params1 ++ params2 ++ dynamic — as the &buffer slice run_bufs wants.
fn arg_refs<'a>(
    p1: &'a [xla::PjRtBuffer],
    p2: &'a [xla::PjRtBuffer],
    dynamic: &'a [xla::PjRtBuffer],
) -> Vec<&'a xla::PjRtBuffer> {
    p1.iter().chain(p2.iter()).chain(dynamic.iter()).collect()
}

/// Extract `tensor[row, idx, :]` from a [B, N, D]-shaped host tensor (or
/// `tensor[row, :]` from [B, D] with idx = 0).
fn tensor_row(t: &HostTensor, row: usize, shape: &[usize], idx: usize) -> Vec<f32> {
    debug_assert_eq!(t.shape, shape);
    let dlast = *shape.last().unwrap();
    let n_mid = if shape.len() == 3 { shape[1] } else { 1 };
    let off = (row * n_mid + idx) * dlast;
    t.data[off * 4..(off + dlast) * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}
