//! The speculative-decoding engine: draft-then-verify decode loop.
//!
//! One `SpecEngine` serves one (target, draft) pair. Sequences decode in
//! *groups* whose KV caches live packed in batched XLA literals that flow
//! executable-to-executable. Per round, for a group:
//!
//!   1. drafts: K tokens per sequence via the architecture's
//!      `DraftBackend` (`server::backend`);
//!   2. verify: one target call over [last_token, draft_1..draft_K];
//!   3. acceptance: the exact Leviathan rule per position (or the greedy
//!      / greedy-draft variants), residual resampling, bonus token;
//!   4. state advance: backend-specific draft-state roll past the
//!      accepted prefix.
//!
//! Two verify implementations share that loop. On the DEVICE path
//! (preferred whenever the artifacts carry the fused entries) the target
//! forward, temperature softmax, rejection rule and residual/bonus
//! sampling run in one `verify_fused` graph: the engine feeds host-drawn
//! per-position uniforms (O(B·K) f32) plus the drafts' device-resident q
//! tensors, and a steady-state round returns only `n_accepted` and the
//! emitted token ids — O(B·K) i32 — to the host. On the HOST fallback
//! (older artifact sets, `SimCore`-style testing, forced parity runs)
//! the round pulls the full `[B, K+1, V]` logits and runs the identical
//! arithmetic in `spec::sampling::verify_round` over flat reusable
//! scratch. Both paths draw the SAME uniforms in the SAME stream order,
//! so they are sample-path-equivalent and pinned against each other by
//! golden-uniform parity tests.
//!
//! The engine knows nothing about draft architectures — dispatch lives
//! entirely behind the `DraftBackend` trait, so new architectures plug in
//! without touching this loop. Group membership is managed above this
//! layer: `server::scheduler` runs groups as slot-mapped sessions with
//! mid-flight join/leave, while `generate_batch` below drives the classic
//! run-to-completion lockstep path (the evaluation protocol).
//!
//! Per-request RNG streams are keyed by a stable request id (not by
//! bootstrap order), so with a FIXED draft budget a sequence's sample
//! path is independent of batch composition, padding, admission order —
//! and of the verify path.
//!
//! The ONLINE SPECULATION CONTROLLER (`spec::adaptive`, on by default)
//! closes the measure→act loop per round: per-position EWMA acceptance
//! estimates drive the round's chain length `k_active` (the fused
//! entries take it as a runtime scalar — no re-lowering) or, for tree
//! backends without a fixed `--tree`, a freshly planned topology
//! (runtime parent tensors). Greedy modes emit bit-identical tokens
//! under any budget schedule, so the composition-independence above is
//! unconditional there. In STOCHASTIC mode the realized budget schedule
//! is shared group state: sample paths become a function of
//! (seed, id, schedule) — still exactly lossless in distribution and
//! replay-deterministic, and a constant schedule is bit-identical to
//! the corresponding fixed configuration; strict composition
//! independence of stochastic sample paths requires the fixed overrides
//! (`--spec-k` / `--tree FxF`, or `AdaptiveOpts::fixed()` — what the
//! eval protocol uses). See DESIGN.md §4a for the precise contract and
//! its impossibility boundary.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::{pack, Runtime};
use crate::spec::accept::AcceptanceStats;
use crate::spec::adaptive::{
    ControllerCfg, CostModel, PrefillArbiter, PrefillArbiterCfg, SpecController,
};
use crate::spec::sampling::{self, RoundUniforms, SamplingMode, TreeSpec};
use crate::tensor::{Checkpoint, HostTensor};
use crate::train::checkpoint_to_params;
use crate::util::Pcg64;

use super::backend::{
    arg_refs, copy_kv_row_device, copy_literal_row, gather_kv_rows_device, lit_f32, lit_i32,
    lit_scalar_f32, lit_scalar_i32, lit_zeros_f32, make_backend, tensor_row, tensor_row_into,
    upload, upload_params, DraftBackend, EngineCx, GroupState, KvSide, QFlat, SeqState,
    DUMMY_UNIFORM, TKV_BATCH_AXIS,
};
use super::fault::EngineError;
use super::metrics::EngineMetrics;
use super::scheduler::{AdmitReq, SchedulerCore};

/// RNG stream ids for padding rows (clones of a real row that keep the
/// executables' batch shape full); far above any realistic request id.
const PAD_STREAM_BASE: u64 = 0x7add_0000_0000_0000;

/// Per-request RNG: one independent PCG stream per stable request id.
pub fn request_rng(seed: u64, request_id: u64) -> Pcg64 {
    Pcg64::new(seed, 1 + request_id)
}

/// In-place retries for one device execute before the caller's fault
/// policy (degrade, or give up) kicks in.
const EXEC_RETRIES: u32 = 2;
/// Linear backoff unit between execute retries (attempt n sleeps n×this).
const EXEC_BACKOFF: Duration = Duration::from_millis(2);

/// Run one device execute with bounded in-place retries. Safe wherever
/// the closure consumes nothing (uploads + `run_bufs` over borrowed
/// args): a failed attempt leaves no partial state, so replaying it is
/// exact. Every FAILED attempt counts into `metrics.transient_faults`;
/// after `EXEC_RETRIES` retries the last error is returned and the
/// caller decides the blast radius (degrade to host verify, or
/// engine-fatal).
fn exec_with_retry<T>(
    metrics: &mut EngineMetrics,
    mut run: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0u32;
    loop {
        match run() {
            Ok(v) => return Ok(v),
            Err(e) => {
                metrics.transient_faults += 1;
                if attempt >= EXEC_RETRIES {
                    return Err(e);
                }
                attempt += 1;
                std::thread::sleep(EXEC_BACKOFF * attempt);
            }
        }
    }
}

/// Verify-path preference. `Auto` resolves to the device path when the
/// loaded artifacts carry the fused entries for this (target, draft)
/// pair, host otherwise; the forced variants exist for parity tests and
/// perf comparisons.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VerifyPath {
    #[default]
    Auto,
    Host,
    Device,
}

/// Online speculation-controller configuration (see `spec::adaptive`).
/// On by default: `--spec-k` / `--tree FxF` act as fixed overrides that
/// disable the corresponding adaptation.
#[derive(Clone, Debug)]
pub struct AdaptiveOpts {
    /// Run the controller: adapt the chain length per round (`k_active`
    /// in `k_min..=k_draft`) and, with `tree`, replan topologies per
    /// round. With `enabled = false` nothing adapts (a `tree` engine
    /// keeps its construction-time plan).
    pub enabled: bool,
    pub k_min: usize,
    /// Per-token draft cost override in verify-call units; None = the
    /// backend's own cost model (chained archs 0.25, parallel heads 0).
    pub draft_cost: Option<f64>,
    /// Profiled tree topologies: decode with the arch's `-tree` backend
    /// and replan the fanouts each round from measured per-level alpha
    /// (the adaptive replacement for a fixed `--tree FxF`).
    pub tree: bool,
    /// Per-level fanout cap for planned topologies.
    pub fanout_max: usize,
}

impl Default for AdaptiveOpts {
    fn default() -> Self {
        AdaptiveOpts {
            enabled: true,
            k_min: 1,
            draft_cost: None,
            tree: false,
            fanout_max: 4,
        }
    }
}

impl AdaptiveOpts {
    /// Fixed-override configuration (controller off) — what `--spec-k`
    /// and `--tree FxF` select, and what the paper-eval protocol uses.
    pub fn fixed() -> AdaptiveOpts {
        AdaptiveOpts {
            enabled: false,
            ..Default::default()
        }
    }
}

#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// MAXIMUM draft tokens per round (chain length). Recurrent archs
    /// may exceed the K=6 trained heads up to verify_t - 1 = 7;
    /// parallel-head archs are capped at their head count. With a tree
    /// configured this is overridden to the tree's depth (it sizes the
    /// acceptance stats). The speculation controller (`adaptive`, on by
    /// default) picks each round's actual chain length in
    /// `adaptive.k_min..=k_draft`; with `adaptive.enabled = false` every
    /// round drafts exactly this many.
    pub k_draft: usize,
    pub temperature: f32,
    pub mode: SamplingMode,
    pub seed: u64,
    pub verify_path: VerifyPath,
    /// Multi-candidate drafting: verify this FIXED candidate tree per
    /// round instead of a single K-chain (None = chain decoding, unless
    /// `adaptive.tree` plans topologies per round). Selects the
    /// architecture's `-tree` backend variant; the tree must fit the
    /// lowered block (`len() <= verify_t - 1`) and the backend's head
    /// count (`depth() <= max_k`).
    pub tree: Option<TreeSpec>,
    /// Online speculation controller (per-round K / profiled trees).
    pub adaptive: AdaptiveOpts,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            k_draft: 7,
            temperature: 1.0,
            mode: SamplingMode::Stochastic,
            seed: 1234,
            verify_path: VerifyPath::Auto,
            tree: None,
            adaptive: AdaptiveOpts::default(),
        }
    }
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub tokens: Vec<i32>,
    pub stats: AcceptanceStats,
    /// Submission → completion for THIS request (per-session, not the
    /// group total: sequences finishing early report their own latency).
    pub latency_ms: f64,
    /// Submission → first emitted token (prefill bootstrap included).
    pub ttft_ms: f64,
    /// Submission → admission into a decode group (queue wait).
    pub queue_ms: f64,
    /// Draft-verify rounds this request participated in.
    pub rounds: u64,
}

/// Flat per-round scratch reused across rounds (no per-round nested-Vec
/// churn on the host path).
#[derive(Default)]
struct VerifyScratch {
    /// `[B, N, V]` full-vocab draft distributions (N = chain slots or
    /// tree nodes).
    q: QFlat,
    /// `[(N+1)·V]` temperature softmaxes for the row under verdict.
    p: Vec<f32>,
    /// One logits row.
    lrow: Vec<f32>,
    /// `[V]` residual scratch for the tree walk.
    r: Vec<f32>,
    /// The row's fixed-count verify uniforms.
    u: RoundUniforms,
}

pub struct SpecEngine<'rt> {
    cx: EngineCx<'rt>,
    backend: Box<dyn DraftBackend>,
    pub metrics: EngineMetrics,
    next_req_id: u64,
    scratch: VerifyScratch,
    /// Cached all-zero [B, V] q literal per bucket: fills the fused
    /// entry's masked q slots when k < verify_t-1 without a per-round
    /// rebuild (device path only).
    zero_q: std::collections::BTreeMap<usize, xla::Literal>,
    /// The online speculation controller. Always fed (its alpha gauges
    /// are free telemetry); consulted for the round budget only when
    /// `opts.adaptive` enables it. Engine-lifetime state: estimates stay
    /// warm across groups.
    controller: SpecController,
    /// Chain-length adaptation active (controller picks `k_active`).
    adaptive_chain: bool,
    /// Topology adaptation active (controller replans the tree).
    adaptive_tree: bool,
    /// The current candidate-tree topology (fixed `--tree`, or the
    /// controller's latest plan). None = chain decoding.
    tree_plan: Option<TreeSpec>,
    /// Chunked prefill (DESIGN.md §11): the lowered `prefill_chunk_b1`
    /// entry's (chunk length, carried-KV shape); None on artifact sets
    /// that predate the entry — the scheduler then joins whole prompts.
    prefill_chunk: Option<(usize, Vec<usize>)>,
    /// In-flight chunked prefills, keyed by the target group row.
    pending_prefill: std::collections::HashMap<usize, PendingPrefill>,
    /// Chunk-boundary carry snapshots for the cached-prefix skip.
    chunk_cache: ChunkCache,
    /// Online-adaptation harvest ring (DESIGN.md §12); None = no
    /// adaptation loop attached. Every decode path pushes per-slot
    /// verdict records through `adapt::harvest_row` — the host chain
    /// round also carries the drafted token's (q, p), which it
    /// materializes anyway; the fused device rounds return only verdict
    /// ints, so their records omit the probabilities.
    replay: Option<super::adapt::ReplaySink>,
}

/// One session's in-flight chunked prefill (`prefill_begin` →
/// `prefill_step`… → row splice). The carry is exactly the whole-prompt
/// prefill state after `done` positions: the chunk entry is the verify
/// forward, so composing chunks at pos = 0, C, 2C, … over a zeroed KV
/// reproduces `prefill_b{B}` bit-for-bit on every computed position
/// (pinned by python/tests/test_chunked_prefill.py).
struct PendingPrefill {
    req: AdmitReq,
    /// Prompt positions already in the carry (cache-skipped + computed).
    done: usize,
    /// Carried target KV `[L, 2, 1, H, Smax, Dh]` after `done` positions.
    kv: xla::Literal,
    /// Features for positions `0..done`, flat `[done * feat_dim]` — the
    /// draft bootstrap's conditioning input. Cache-seeded prefixes are
    /// included: every snapshot's feats cover its whole boundary.
    feats: Vec<f32>,
    /// Queue wait measured at admission (`prefill_begin`).
    queue_ms: f64,
}

/// Bounded (FIFO-evicted) cache of chunk-boundary prefill carries keyed
/// by the exact token prefix: a joining session whose prompt shares a
/// cached boundary seeds its carry from the snapshot and SKIPS those
/// chunks' compute entirely — the radix prefix cache's block sharing
/// upgraded to compute sharing. Snapshots live host-side (a few hundred
/// KB each at the lowered shapes), uploaded once on a hit.
struct ChunkCache {
    cap: usize,
    map: std::collections::HashMap<Vec<i32>, (HostTensor, Vec<f32>)>,
    order: std::collections::VecDeque<Vec<i32>>,
}

impl ChunkCache {
    fn new(cap: usize) -> ChunkCache {
        ChunkCache {
            cap: cap.max(1),
            map: std::collections::HashMap::new(),
            order: std::collections::VecDeque::new(),
        }
    }

    fn get(&self, key: &[i32]) -> Option<&(HostTensor, Vec<f32>)> {
        self.map.get(key)
    }

    fn put(&mut self, key: Vec<i32>, kv: HostTensor, feats: Vec<f32>) {
        if self.map.insert(key.clone(), (kv, feats)).is_none() {
            self.order.push_back(key);
            if self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }
}

impl<'rt> SpecEngine<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        draft_name: &str,
        tckpt: &Checkpoint,
        dckpt: &Checkpoint,
        vocab_map: Option<Vec<i32>>,
        opts: EngineOpts,
    ) -> Result<SpecEngine<'rt>> {
        let dspec = rt.manifest.draft(draft_name)?.clone();
        let tspec = rt.manifest.target(&dspec.target)?.clone();
        // Tree decoding — a fixed `--tree` topology OR controller-planned
        // topologies — selects the architecture's multi-candidate backend
        // variant (registered under the `-tree` suffix).
        let use_tree = opts.tree.is_some() || opts.adaptive.tree;
        let backend = if use_tree {
            make_backend(&format!("{}-tree", dspec.arch))?
        } else {
            make_backend(&dspec.arch)?
        };
        if dspec.arch == "eagle3" && vocab_map.is_none() {
            bail!("eagle3 needs a vocab map");
        }
        let max_k = backend.max_k(rt, &dspec);
        let n_slots = rt.manifest.verify_t - 1;
        let mut opts = opts;
        opts.k_draft = opts.k_draft.min(max_k).max(1);
        opts.adaptive.k_min = opts.adaptive.k_min.clamp(1, opts.k_draft);
        if use_tree {
            // The host tree path is the baseline requirement; the fused
            // entries only upgrade it.
            let host_ok = rt.manifest.serve_batches.iter().all(|&b| {
                rt.has_target_entry(&tspec.name, &format!("verify_tree_b{b}"))
                    && rt.has_target_entry(&tspec.name, &format!("kv_path_gather_b{b}"))
            }) && backend.supports_tree(rt, &dspec);
            anyhow::ensure!(
                host_ok,
                "tree decoding needs the verify_tree/kv_path_gather entries for \
                 {draft_name} (re-lower the artifacts: python/compile/aot.py)"
            );
        }
        if let Some(tree) = &opts.tree {
            anyhow::ensure!(
                tree.len() <= n_slots,
                "tree has {} nodes but the lowered verify block fits {n_slots}",
                tree.len()
            );
            anyhow::ensure!(
                tree.depth() <= max_k,
                "tree depth {} exceeds {draft_name}'s max chain length {max_k}",
                tree.depth()
            );
            // Stats are per accepted-path position; depth is the tree's K.
            opts.k_draft = tree.depth();
            // A fixed topology is a fixed override: no replanning.
            opts.adaptive.tree = false;
        } else if use_tree {
            // Controller-planned topologies: stats sized at the deepest
            // plannable path (any plan fits depth <= max_k, <= n_slots).
            opts.k_draft = max_k.min(n_slots);
        }
        // Device verify needs the fused target entry at every bucket
        // plus the backend's device-sampling entries (the tree variants
        // of both when tree decoding is selected).
        let device_supported = if use_tree {
            rt.manifest
                .serve_batches
                .iter()
                .all(|&b| rt.has_target_entry(&tspec.name, &format!("verify_tree_fused_b{b}")))
                && backend.supports_tree_device(rt, &dspec)
        } else {
            rt.manifest
                .serve_batches
                .iter()
                .all(|&b| rt.has_target_entry(&tspec.name, &format!("verify_fused_b{b}")))
                && backend.supports_device(rt, &dspec)
        };
        let device_verify = match opts.verify_path {
            VerifyPath::Host => false,
            VerifyPath::Auto => device_supported,
            VerifyPath::Device => {
                anyhow::ensure!(
                    device_supported,
                    "device verify forced but the artifacts lack the fused entries \
                     for {draft_name} (re-lower the artifacts: python/compile/aot.py)"
                );
                true
            }
        };
        // Parameters are uploaded ONCE as device buffers and reused by
        // every call — the single biggest serving-path optimization on
        // this runtime (no per-call h2d of the full model).
        let (tparams, tlits) = upload_params(rt, &checkpoint_to_params(&tspec.params, tckpt)?)?;
        let (dparams, dlits) = upload_params(rt, &checkpoint_to_params(&dspec.params, dckpt)?)?;
        let mut _param_lits = tlits;
        _param_lits.extend(dlits);
        let metrics = EngineMetrics {
            verify_path: if device_verify { "device" } else { "host" },
            ..Default::default()
        };
        // The speculation controller: cost model from the backend (or
        // the operator's override), budget range from the clamped opts.
        let mut cost = opts
            .adaptive
            .draft_cost
            .map(CostModel::chained)
            .unwrap_or_else(|| backend.cost_model());
        if use_tree && device_verify {
            // The device tree proposal runs its level-parallel expansion
            // in ONE lowered graph with a FIXED number of level passes
            // (one graph serves every topology), so draft cost no longer
            // scales with planned depth there: fold the chained
            // per-level price into the fixed term and let the planner
            // allocate purely by expected accepted length. The host tree
            // path keeps the per-level price (one tree_step dispatch per
            // level). Parallel-head models (per_token = 0) are unchanged.
            cost = CostModel {
                fixed: cost.fixed + cost.per_token * n_slots.saturating_sub(1) as f64,
                per_token: 0.0,
            };
        }
        let controller = SpecController::new(ControllerCfg {
            k_min: opts.adaptive.k_min,
            k_max: opts.k_draft,
            cost,
            ..Default::default()
        });
        let adaptive_chain = opts.adaptive.enabled && !use_tree;
        // Topology replanning is controller work too: with the
        // controller disabled, `adaptive.tree` still selects the tree
        // backend but the construction-time plan stays fixed.
        let adaptive_tree = use_tree && opts.tree.is_none() && opts.adaptive.enabled;
        let tree_plan = if let Some(t) = &opts.tree {
            Some(t.clone())
        } else if use_tree {
            // Planned topology (replanned per round only when the
            // controller is enabled; the prior-driven plan otherwise).
            Some(controller.plan_tree(n_slots, opts.k_draft, opts.adaptive.fanout_max))
        } else {
            None
        };
        // Chunked-prefill support: chunk length from the lowered
        // `prefill_chunk_b1` entry's tokens input `[1, C]`, carried-KV
        // shape from its kv input. Absent on artifact sets lowered
        // before the entry existed — the scheduler then falls back to
        // whole-prompt joins.
        let prefill_chunk = tspec.entries.get("prefill_chunk_b1").and_then(|e| {
            let c = e.inputs.iter().find(|a| a.group == "tokens")?.shape.last().copied()?;
            let kv = e.inputs.iter().find(|a| a.group == "kv")?.shape.clone();
            (c > 0).then_some((c, kv))
        });
        Ok(SpecEngine {
            cx: EngineCx {
                rt,
                tspec,
                dspec,
                tparams,
                dparams,
                _param_lits,
                vocab_map,
                k: opts.k_draft,
                opts,
                device_verify,
            },
            backend,
            metrics,
            next_req_id: 0,
            scratch: VerifyScratch::default(),
            zero_q: std::collections::BTreeMap::new(),
            controller,
            adaptive_chain,
            adaptive_tree,
            tree_plan,
            prefill_chunk,
            pending_prefill: std::collections::HashMap::new(),
            chunk_cache: ChunkCache::new(32),
            replay: None,
        })
    }

    /// Hot-swap the DRAFT model's weights from a fine-tuned `.lkt`
    /// checkpoint (DESIGN.md §12). Validate-then-commit: the checkpoint
    /// is read, shape-checked against the draft manifest's `TensorSpec`s
    /// (`checkpoint_to_params` — extra tensors like the fine-tuner's
    /// `adapt/*` state are ignored), and uploaded to fresh device
    /// buffers BEFORE the live `dparams` are replaced; any failure
    /// returns with the old weights still serving (rollback = not
    /// swapping). The old parameter literals are deliberately retained
    /// in `_param_lits`: uploads are async (literals must outlive their
    /// buffers, see `upload_params`), and in-flight work may still
    /// reference the old buffers this round — a few MB of host memory
    /// per swap buys memory-safety without a device fence.
    ///
    /// Exactness is untouched by construction: draft weights change
    /// what is PROPOSED; the accept/resample rule and the target model
    /// never change.
    pub fn swap_draft_checkpoint(&mut self, ckpt: &std::path::Path) -> Result<()> {
        let wrap = |e: anyhow::Error| super::adapt::swap_error(ckpt, e);
        let c = crate::tensor::read_checkpoint(ckpt).map_err(wrap)?;
        let params = checkpoint_to_params(&self.cx.dspec.params, &c).map_err(wrap)?;
        let (dparams, dlits) = upload_params(self.cx.rt, &params).map_err(wrap)?;
        // Commit point: everything validated and resident.
        self.cx.dparams = dparams;
        self.cx._param_lits.extend(dlits);
        Ok(())
    }

    pub fn target_name(&self) -> &str {
        &self.cx.tspec.name
    }

    pub fn k_draft(&self) -> usize {
        self.cx.k
    }

    pub fn opts(&self) -> &EngineOpts {
        &self.cx.opts
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Which verify path this engine resolved to.
    pub fn verify_path(&self) -> &'static str {
        if self.cx.device_verify {
            "device"
        } else {
            "host"
        }
    }

    /// The online speculation controller (estimates + current choice).
    pub fn controller(&self) -> &SpecController {
        &self.controller
    }

    /// The candidate-tree topology the next round will verify (fixed
    /// `--tree`, or the controller's latest plan); None = chain rounds.
    pub fn tree_plan(&self) -> Option<&TreeSpec> {
        self.tree_plan.as_ref()
    }

    /// Whether any per-round adaptation (chain K or topology) is live.
    pub fn adaptive(&self) -> bool {
        self.adaptive_chain || self.adaptive_tree
    }

    // ------------------------------------------------------------------
    // group construction (prefill path)
    // ------------------------------------------------------------------

    /// Target prefill + per-sequence bootstrap + backend draft bootstrap
    /// for `reqs`, padded up to the serve bucket. Row i hosts `reqs[i]`;
    /// padding rows clone the last request but start `done`.
    fn bootstrap_group(&mut self, reqs: &[AdmitReq]) -> Result<GroupState> {
        let n = reqs.len();
        anyhow::ensure!(n > 0, "empty group");
        let t_admit = Instant::now();
        let b = self.cx.bucket(n);
        anyhow::ensure!(n <= b, "group of {n} exceeds the largest serve bucket {b}");
        let sp = self.cx.rt.manifest.prompt_len;
        let vocab = self.cx.tspec.vocab;

        // --- target prefill ------------------------------------------
        let mut tok_flat = vec![0i32; b * sp];
        let mut lens = vec![0usize; b];
        for row in 0..b {
            let p = &reqs[row.min(n - 1)].prompt; // clone last prompt into padding
            anyhow::ensure!(
                p.len() >= 2 && p.len() <= sp,
                "prompt length {} not in 2..={sp}",
                p.len()
            );
            lens[row] = p.len();
            tok_flat[row * sp..row * sp + p.len()].copy_from_slice(p);
        }
        let prefill = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("prefill_b{b}"))?;
        let dyn_in = [
            lit_i32(&[b, sp], &tok_flat)?,
            lit_scalar_i32(lens[0] as i32)?,
        ];
        // No group state exists yet: a prefill blip retries in place,
        // and past the budget the scheduler's bootstrap containment
        // decides the blast radius.
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        let outs = exec_with_retry(&mut self.metrics, || {
            let dyn_b = upload(rt, &dyn_in)?;
            let args = arg_refs(tparams, &[], &dyn_b);
            prefill.run_bufs(&args)
        })?;
        let logits = prefill.output_host(&outs, 0)?;
        let feats = prefill.output_host(&outs, 2)?;
        let tkv_spec = prefill.spec.outputs[1].clone();
        let tkv = outs.into_iter().nth(1).unwrap();

        // --- per-sequence bootstrap -----------------------------------
        let mut seqs = Vec::with_capacity(b);
        for row in 0..b {
            let is_pad = row >= n;
            let req = &reqs[row.min(n - 1)];
            let c = lens[row];
            let stream_id = if is_pad {
                PAD_STREAM_BASE + row as u64
            } else {
                req.id
            };
            let mut rng = request_rng(self.cx.opts.seed, stream_id);
            let lrow = tensor_row(&logits, row, &[b, sp, vocab], c - 1);
            let p = sampling::softmax_t(&lrow, self.cx.opts.temperature.max(1e-3));
            let first = self.cx.sample_target(&mut rng, &p);
            seqs.push(SeqState {
                id: stream_id,
                len: c,
                last_token: first,
                generated: vec![first],
                max_new: req.max_new,
                rng,
                stats: AcceptanceStats::new(self.cx.k),
                done: is_pad, // padding rows start done
                hidden: Vec::new(),
                q1: Vec::new(),
                enqueued: req.enqueued,
                queue_ms: t_admit.saturating_duration_since(req.enqueued).as_secs_f64() * 1e3,
                ttft_ms: 0.0,
                total_ms: 0.0,
                rounds: 0,
            });
        }

        let mut group = GroupState {
            b,
            seqs,
            tkv,
            tkv_spec,
            dkv: None,
            dkv_spec: None,
            h_prev: None,
            tok0: vec![0; b],
            q0_dev: None,
        };

        // --- draft bootstrap ------------------------------------------
        self.backend
            .bootstrap(&self.cx, &mut group, &tok_flat, &feats)?;

        // The first token exists as soon as the bootstrap sampled it.
        for seq in group.seqs.iter_mut().take(n) {
            seq.ttft_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
        }
        Ok(group)
    }

    // ------------------------------------------------------------------
    // one draft-verify round for the whole group
    // ------------------------------------------------------------------

    fn decode_round(&mut self, g: &mut GroupState) -> Result<()> {
        let before = self.cx.rt.d2h_bytes_total();
        if self.tree_plan.is_some() {
            // Profiled topologies: replan from the measured per-level
            // alpha before the round (a fixed --tree never replans).
            if self.adaptive_tree {
                let n_slots = self.cx.rt.manifest.verify_t - 1;
                self.tree_plan = Some(self.controller.plan_tree(
                    n_slots,
                    self.cx.k,
                    self.cx.opts.adaptive.fanout_max,
                ));
            }
            let (depth, n) = {
                let t = self.tree_plan.as_ref().unwrap();
                (t.depth(), t.len())
            };
            self.observe_budget(depth, n);
            if self.cx.device_verify {
                self.decode_round_tree_device(g)?;
            } else {
                self.decode_round_tree_host(g)?;
            }
        } else {
            // Per-round chain length: the fused entries take k_active as
            // a runtime scalar, so adaptation needs no re-lowering.
            let k = if self.adaptive_chain {
                self.controller.choose_k()
            } else {
                self.cx.k
            };
            self.observe_budget(k, k);
            if self.cx.device_verify {
                self.decode_round_device(g, k)?;
            } else {
                self.decode_round_host(g, k)?;
            }
        }
        self.metrics.decode_rounds += 1;
        self.metrics.bytes_to_host += self.cx.rt.d2h_bytes_total() - before;
        Ok(())
    }

    /// Stamp the round's chosen budget + the controller's current alpha
    /// estimates into the metrics registry.
    fn observe_budget(&mut self, depth: usize, slots: usize) {
        let est = self.controller.estimator();
        let alpha: Vec<f64> = (0..est.k_max()).map(|i| est.alpha(i)).collect();
        self.metrics.observe_controller(depth, slots, &alpha);
    }

    /// Apply one row's verdict to its sequence state (both paths).
    fn apply_verdict(seq: &mut SeqState, drafts_row: &[i32], k: usize, n_acc: usize, token: i32) {
        seq.stats.record_round(k, n_acc);
        for item in drafts_row.iter().take(n_acc) {
            seq.generated.push(*item);
        }
        seq.generated.push(token);
        seq.len += 1 + n_acc; // last_token + accepted drafts now processed
        seq.last_token = token;
        seq.rounds += 1;
        if seq.generated.len() >= seq.max_new {
            seq.done = true;
            seq.total_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
        }
    }

    /// Host fallback: pull the full [B, Vt, V] logits and run the shared
    /// verify arithmetic in Rust over flat reusable scratch. `k` is this
    /// round's chain length (controller-chosen, or the fixed maximum).
    fn decode_round_host(&mut self, g: &mut GroupState, k: usize) -> Result<()> {
        let b = g.b;
        let vt = self.cx.rt.manifest.verify_t;
        let vocab = self.cx.tspec.vocab;

        // --- 1. draft k tokens per row (backend-specific) --------------
        let mut drafts = vec![vec![0i32; k]; b];
        self.scratch.q.reset(b, k, vocab);
        self.backend
            .propose(&self.cx, g, k, &mut drafts, &mut self.scratch.q)?;

        // --- 2. verify --------------------------------------------------
        let verify = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("verify_b{b}"))?;
        let mut vtok = vec![0i32; b * vt];
        for (row, seq) in g.seqs.iter().enumerate() {
            vtok[row * vt] = seq.last_token;
            for i in 0..k {
                vtok[row * vt + 1 + i] = drafts[row][i];
            }
        }
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?); // placeholder
        let dyn_in = [tkv, lit_i32(&[b, vt], &vtok)?, lit_i32(&[b], &pos)?];
        // Host verify is the degradation FLOOR: retry the execute in
        // place (no observable state has mutated yet — the uniforms are
        // drawn after it), and past the budget give up untyped
        // (= engine-fatal); there is no slower path left to degrade to.
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        let outs = exec_with_retry(&mut self.metrics, || {
            let dyn_b = upload(rt, &dyn_in)?;
            let args = arg_refs(tparams, &[], &dyn_b);
            verify.run_bufs(&args)
        })?;
        let logits = verify.output_host(&outs, 0)?; // [B, vt, V]
        let feats = verify.output_host(&outs, 2)?; // [B, vt, 3d]
        g.tkv = outs.into_iter().nth(1).unwrap();

        // --- 3. acceptance per row --------------------------------------
        let temp = self.cx.opts.temperature.max(1e-3);
        let mode = self.cx.opts.mode;
        let mut n_acc = vec![0usize; b];
        let VerifyScratch { q, p, lrow, u, .. } = &mut self.scratch;
        p.resize((k + 1) * vocab, 0.0);
        for row in 0..b {
            let seq = &mut g.seqs[row];
            if seq.done {
                continue;
            }
            u.draw_into(&mut seq.rng, k, mode);
            // Rows are softmaxed lazily — only up to the first rejection.
            let rv = sampling::verify_round_lazy(
                k,
                vocab,
                p,
                |j, out| {
                    tensor_row_into(&logits, row, &[b, vt, vocab], j, lrow);
                    sampling::softmax_t_into(lrow, temp, out);
                },
                q.row_block(row),
                &drafts[row],
                mode,
                u,
            );
            // Adaptation harvest (host verify is the one path where the
            // drafted token's q and p are already materialized: q from
            // the proposal block, p from the lazily softmaxed rows —
            // both filled through the first rejection, exactly the
            // judged slots).
            if let Some(sink) = &self.replay {
                let judged = (rv.n_accepted + 1).min(k);
                let qb = q.row_block(row);
                let probs: Vec<(f32, f32)> = (0..judged)
                    .map(|i| {
                        let d = drafts[row][i].max(0) as usize;
                        (qb[i * vocab + d], p[i * vocab + d])
                    })
                    .collect();
                super::adapt::harvest_row(
                    sink,
                    seq.id,
                    self.metrics.decode_rounds,
                    seq.len,
                    &seq.generated,
                    &drafts[row],
                    rv.n_accepted,
                    &probs,
                );
            }
            Self::apply_verdict(seq, &drafts[row], k, rv.n_accepted, rv.token);
            self.metrics.observe_round_row(k, rv.n_accepted);
            self.controller.observe_chain(k, rv.n_accepted);
            n_acc[row] = rv.n_accepted;
        }

        // --- 4. advance draft state (backend-specific) ------------------
        self.backend
            .advance(&self.cx, g, &drafts, &n_acc, &feats)?;
        Ok(())
    }

    /// Device-resident round: softmax + rejection + residual sampling run
    /// inside the `verify_fused` graph; the host feeds O(B·K) uniforms
    /// and reads back O(B·K) verdict integers. Draft q's, target KV,
    /// features and the conditioning hidden stay device-side.
    fn decode_round_device(&mut self, g: &mut GroupState, k: usize) -> Result<()> {
        let b = g.b;
        let vt = self.cx.rt.manifest.verify_t;
        let kq = vt - 1; // q inputs the fused entry was lowered with
        let vocab = self.cx.tspec.vocab;
        let mode = self.cx.opts.mode;

        // --- 1. draft (device sampling; tokens come back as ints) -------
        let mut drafts = vec![vec![0i32; k]; b];
        let mut q_dev: Vec<xla::Literal> = Vec::with_capacity(kq);
        self.backend
            .propose_device(&self.cx, g, k, &mut drafts, &mut q_dev)?;
        anyhow::ensure!(q_dev.len() == k, "backend produced {} q tensors", q_dev.len());

        // --- 2. fused verify --------------------------------------------
        let mut vtok = vec![0i32; b * vt];
        for (row, seq) in g.seqs.iter().enumerate() {
            vtok[row * vt] = seq.last_token;
            for i in 0..k {
                vtok[row * vt + 1 + i] = drafts[row][i];
            }
        }
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        // Snapshot the RNG streams before the uniform draws: if the
        // fused execute fails past its retry budget the restore below
        // un-happens the round, so the degraded retry replays the
        // identical sample path on the host.
        let rng_snap: Vec<Pcg64> = g.seqs.iter().map(|s| s.rng.clone()).collect();
        // The SAME fixed-count uniforms the host path would draw; done
        // rows draw nothing and get inert constants.
        let mut u_acc = vec![DUMMY_UNIFORM; b * kq];
        let mut u_samp = vec![DUMMY_UNIFORM; b];
        if mode.is_stochastic() {
            for (row, seq) in g.seqs.iter_mut().enumerate() {
                if seq.done {
                    continue;
                }
                for slot in u_acc[row * kq..row * kq + k].iter_mut() {
                    *slot = seq.rng.uniform() as f32;
                }
                u_samp[row] = seq.rng.uniform() as f32;
            }
        }
        let verify = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("verify_fused_b{b}"))?;
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?); // placeholder
        let mut head = vec![tkv, lit_i32(&[b, vt], &vtok)?, lit_i32(&[b], &pos)?];
        head.extend(q_dev);
        let tail = [
            lit_f32(&[b, kq], &u_acc)?,
            lit_f32(&[b], &u_samp)?,
            lit_scalar_f32(self.cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(mode.device_code())?,
            lit_scalar_i32(k as i32)?,
        ];
        if k < kq && !self.zero_q.contains_key(&b) {
            self.zero_q.insert(b, lit_zeros_f32(&[b, vocab])?);
        }
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        let zero_q = &self.zero_q;
        let exec = exec_with_retry(&mut self.metrics, || {
            let mut dyn_b = upload(rt, &head)?;
            // Positions beyond this round's chain are masked in-graph by
            // k_active; the cached zero literal just fills the lowered
            // arity.
            for _ in k..kq {
                dyn_b.push(rt.to_buffer(&zero_q[&b])?);
            }
            dyn_b.extend(upload(rt, &tail)?);
            let args = arg_refs(tparams, &[], &dyn_b);
            verify.run_bufs(&args)
        });
        let outs = match exec {
            Ok(outs) => outs,
            Err(e) => {
                // The fused path exhausted its in-place retries:
                // un-happen the round (the target KV never left `head`,
                // the RNG streams restore from the snapshot) and degrade
                // this engine to host verify. The typed transient fault
                // makes the scheduler re-run the round, which now
                // dispatches to the host path and replays the same
                // sample path.
                g.tkv = head.swap_remove(0);
                for (seq, rng) in g.seqs.iter_mut().zip(rng_snap) {
                    seq.rng = rng;
                }
                self.cx.device_verify = false;
                self.metrics.verify_degrades += 1;
                self.metrics.verify_path = "host";
                return Err(EngineError::transient(format!(
                    "device verify failed; group degraded to host verify: {e:#}"
                )));
            }
        };
        // Only the verdict integers are materialized host-side.
        let n_acc_host = verify.output_host(&outs, 0)?.as_i32(); // [B]
        let toks_host = verify.output_host(&outs, 1)?.as_i32(); // [B, vt]
        let mut it = outs.into_iter();
        let n_acc_lit = it.next().unwrap();
        let _toks_lit = it.next();
        g.tkv = it.next().unwrap();
        let feats = it.next().unwrap();
        let h_sel = it.next().unwrap();

        // --- 3. bookkeeping per row -------------------------------------
        let mut n_acc = vec![0usize; b];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            if seq.done {
                continue; // in-graph verdicts for done rows are garbage
            }
            let j = (n_acc_host[row].max(0) as usize).min(k);
            let token = toks_host[row * vt + j];
            // Adaptation harvest: the fused kernel returns only verdict
            // ints, so these records carry no q/p (same core fields as
            // the host path's — pinned by the harvest-parity test).
            if let Some(sink) = &self.replay {
                super::adapt::harvest_row(
                    sink,
                    seq.id,
                    self.metrics.decode_rounds,
                    seq.len,
                    &seq.generated,
                    &drafts[row],
                    j,
                    &[],
                );
            }
            Self::apply_verdict(seq, &drafts[row], k, j, token);
            self.metrics.observe_round_row(k, j);
            self.controller.observe_chain(k, j);
            n_acc[row] = j;
        }

        // --- 4. advance draft state (backend-specific) ------------------
        self.backend
            .advance_device(&self.cx, g, &drafts, &n_acc, n_acc_lit, feats, h_sel)?;
        Ok(())
    }

    /// Host tree round: ONE tree-attention target pass judges every
    /// candidate of the per-round tree, the exact multi-draft rejection
    /// walk runs in `spec::sampling::verify_tree_lazy` over the pulled
    /// logits, and the accepted path's KV is spliced back to consecutive
    /// positions with the device-side `kv_path_gather` entry (the packed
    /// cache never round-trips through the host).
    fn decode_round_tree_host(&mut self, g: &mut GroupState) -> Result<()> {
        // Topology is engine state (fixed, or the controller's current
        // plan); borrow it (no per-round clone of the spec's vectors).
        let tree = self.tree_plan.as_ref().expect("tree round without a tree");
        let b = g.b;
        let n = tree.len();
        let depth = tree.depth();
        let vt = self.cx.rt.manifest.verify_t;
        let kq = vt - 1;
        let vocab = self.cx.tspec.vocab;

        // --- 1. propose one candidate per tree node --------------------
        let mut drafts = vec![vec![0i32; n]; b];
        self.scratch.q.reset(b, n, vocab);
        self.backend
            .propose_tree(&self.cx, g, tree, &mut drafts, &mut self.scratch.q)?;

        // --- 2. tree-attention verify pass ------------------------------
        let verify = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("verify_tree_b{b}"))?;
        let mut vtok = vec![0i32; b * vt];
        for (row, seq) in g.seqs.iter().enumerate() {
            vtok[row * vt] = seq.last_token;
            for i in 0..n {
                vtok[row * vt + 1 + i] = drafts[row][i];
            }
        }
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?); // placeholder
        let dyn_in = [
            tkv,
            lit_i32(&[b, vt], &vtok)?,
            lit_i32(&[b], &pos)?,
            lit_i32(&[vt], &tree.block_parents(vt))?,
        ];
        // Degradation floor, as in the chain host round: retry in place
        // (the rejection walk and its draws come after), then give up
        // untyped (= engine-fatal).
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        let outs = exec_with_retry(&mut self.metrics, || {
            let dyn_b = upload(rt, &dyn_in)?;
            let args = arg_refs(tparams, &[], &dyn_b);
            verify.run_bufs(&args)
        })?;
        let logits = verify.output_host(&outs, 0)?; // [B, vt, V]
        let feats = verify.output_host(&outs, 2)?; // [B, vt, 3d]
        g.tkv = outs.into_iter().nth(1).unwrap();

        // --- 3. the multi-draft rejection walk per row ------------------
        let temp = self.cx.opts.temperature.max(1e-3);
        let mode = self.cx.opts.mode;
        let mut stop_blk = vec![0usize; b];
        let mut paths: Vec<Vec<usize>> = vec![Vec::new(); b];
        let mut sel = vec![0i32; b * kq];
        let mut acc_toks: Vec<i32> = Vec::with_capacity(depth);
        let VerifyScratch { q, p, lrow, u, r } = &mut self.scratch;
        p.resize((n + 1) * vocab, 0.0);
        r.resize(vocab, 0.0);
        for row in 0..b {
            // A row's path splice defaults to replaying its own block
            // (done rows included: in-bounds garbage positions).
            for (t, s) in sel[row * kq..(row + 1) * kq].iter_mut().enumerate() {
                *s = pos[row] + 1 + t as i32;
            }
            let seq = &mut g.seqs[row];
            if seq.done {
                continue;
            }
            u.draw_into(&mut seq.rng, n, mode);
            // Pristine rows materialize lazily — root + accepted nodes.
            let tv = sampling::verify_tree_lazy(
                tree,
                vocab,
                p,
                |j, out| {
                    tensor_row_into(&logits, row, &[b, vt, vocab], j, lrow);
                    sampling::softmax_t_into(lrow, temp, out);
                },
                r,
                q.row_block(row),
                &drafts[row],
                mode,
                u,
            );
            acc_toks.clear();
            acc_toks.extend(tv.path.iter().map(|&node| drafts[row][node]));
            // Adaptation harvest: the judged node set (accepted path +
            // the sibling rejections the sequential walk made) is
            // reconstructed from topology + path; per-node q/p live in
            // tree coordinates and are not carried.
            if let Some(sink) = &self.replay {
                super::adapt::harvest_tree_row(
                    sink,
                    seq.id,
                    self.metrics.decode_rounds,
                    seq.len,
                    &seq.generated,
                    &drafts[row],
                    |i| tree.parent(i),
                    &tv.path,
                );
            }
            Self::apply_verdict(seq, &acc_toks, depth, acc_toks.len(), tv.token);
            self.metrics.observe_round_row(n, tv.path.len());
            self.controller.observe_tree(tree, tv.path.len());
            stop_blk[row] = tv.path.last().map(|&node| node + 1).unwrap_or(0);
            for (t, &node) in tv.path.iter().enumerate() {
                sel[row * kq + t] = pos[row] + 1 + node as i32;
            }
            paths[row] = tv.path;
        }

        // --- 4. splice the accepted paths to linear KV ------------------
        let gather = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("kv_path_gather_b{b}"))?;
        let dst0: Vec<i32> = pos.iter().map(|&p| p + 1).collect();
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?);
        let splice_in = [
            tkv,
            lit_i32(&[b, kq], &sel)?,
            lit_i32(&[b], &dst0)?,
        ];
        // The verdicts above already advanced every sequence, so this
        // splice CANNOT be un-happened: retry it in place, and past the
        // budget the failure stays untyped (= engine-fatal) — never a
        // transient, which would replay the round on top of mutated
        // state.
        let rt = self.cx.rt;
        let outs = exec_with_retry(&mut self.metrics, || {
            let splice_b = upload(rt, &splice_in)?;
            let splice_refs: Vec<&xla::PjRtBuffer> = splice_b.iter().collect();
            gather.run_bufs(&splice_refs)
        })?;
        g.tkv = outs.into_iter().next().unwrap();

        // --- 5. advance draft state (backend-specific; stateful tree
        // backends splice their draft KV here, in the same round as the
        // target splice above) -------------------------------------------
        self.backend
            .advance_tree(&self.cx, g, &drafts, &paths, &stop_blk, &feats)?;
        Ok(())
    }

    /// Device tree round: candidate sampling, the tree-attention target
    /// forward, the multi-draft rejection walk, the KV path splice and
    /// the conditioning-hidden pickup all run inside
    /// `verify_tree_fused_b{B}`; the host feeds O(B·N) uniforms plus the
    /// topology ints and reads back O(B·N) verdict integers.
    fn decode_round_tree_device(&mut self, g: &mut GroupState) -> Result<()> {
        let tree = self.tree_plan.as_ref().expect("tree round without a tree");
        let b = g.b;
        let n = tree.len();
        let depth = tree.depth();
        let vt = self.cx.rt.manifest.verify_t;
        let kq = vt - 1;
        let mode = self.cx.opts.mode;

        // --- 1. draft (in-graph sampling; candidates come back as ints) -
        let mut drafts = vec![vec![0i32; n]; b];
        let mut q_dev: Vec<xla::Literal> = Vec::with_capacity(kq);
        self.backend
            .propose_tree_device(&self.cx, g, tree, &mut drafts, &mut q_dev)?;
        anyhow::ensure!(q_dev.len() == kq, "backend produced {} q tensors", q_dev.len());

        // --- 2. fused tree verify ---------------------------------------
        let mut vtok = vec![0i32; b * vt];
        for (row, seq) in g.seqs.iter().enumerate() {
            vtok[row * vt] = seq.last_token;
            for i in 0..n {
                vtok[row * vt + 1 + i] = drafts[row][i];
            }
        }
        let pos: Vec<i32> = g.seqs.iter().map(|s| s.len as i32).collect();
        // RNG snapshot before the draws — the degrade path below
        // un-happens the round; see `decode_round_device`.
        let rng_snap: Vec<Pcg64> = g.seqs.iter().map(|s| s.rng.clone()).collect();
        // The SAME fixed-count uniforms the host walk would draw (one
        // accept per node + one sample); done rows get inert constants.
        let mut u_acc = vec![DUMMY_UNIFORM; b * kq];
        let mut u_samp = vec![DUMMY_UNIFORM; b];
        if mode.is_stochastic() {
            for (row, seq) in g.seqs.iter_mut().enumerate() {
                if seq.done {
                    continue;
                }
                for slot in u_acc[row * kq..row * kq + n].iter_mut() {
                    *slot = seq.rng.uniform() as f32;
                }
                u_samp[row] = seq.rng.uniform() as f32;
            }
        }
        let verify = self
            .cx
            .rt
            .target_entry(&self.cx.tspec.name, &format!("verify_tree_fused_b{b}"))?;
        let tkv = std::mem::replace(&mut g.tkv, lit_scalar_i32(0)?); // placeholder
        let mut head = vec![
            tkv,
            lit_i32(&[b, vt], &vtok)?,
            lit_i32(&[b], &pos)?,
            lit_i32(&[kq], &tree.parents_padded(kq))?,
        ];
        head.extend(q_dev);
        let tail = [
            lit_f32(&[b, kq], &u_acc)?,
            lit_f32(&[b], &u_samp)?,
            lit_scalar_f32(self.cx.opts.temperature.max(1e-3))?,
            lit_scalar_i32(mode.device_code())?,
            lit_scalar_i32(n as i32)?,
        ];
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        let exec = exec_with_retry(&mut self.metrics, || {
            let mut dyn_b = upload(rt, &head)?;
            dyn_b.extend(upload(rt, &tail)?);
            let args = arg_refs(tparams, &[], &dyn_b);
            verify.run_bufs(&args)
        });
        let outs = match exec {
            Ok(outs) => outs,
            Err(e) => {
                // Un-happen the round and degrade to the host tree
                // round, exactly as in `decode_round_device`: the
                // transient verdict makes the scheduler replay the
                // round on the host path with the restored streams.
                g.tkv = head.swap_remove(0);
                for (seq, rng) in g.seqs.iter_mut().zip(rng_snap) {
                    seq.rng = rng;
                }
                self.cx.device_verify = false;
                self.metrics.verify_degrades += 1;
                self.metrics.verify_path = "host";
                return Err(EngineError::transient(format!(
                    "device tree verify failed; group degraded to host verify: {e:#}"
                )));
            }
        };
        // Only the verdict integers are materialized host-side. The
        // accepted-path node indices (`[B, Vt-1]`, first `n` slots
        // live) ride along ONLY for stateful backends, which build
        // their draft-splice maps from them — still O(B·N) ints.
        let n_path_host = verify.output_host(&outs, 0)?.as_i32(); // [B]
        // The accepted-path node indices are pulled for stateful
        // backends (draft-splice maps) and whenever the adaptation loop
        // is harvesting — reconstructing the judged node set needs node
        // coordinates, not just tokens. Still O(B·N) ints.
        let path_host = if self.backend.tree_paths_needed() || self.replay.is_some() {
            Some(verify.output_host(&outs, 1)?.as_i32())
        } else {
            None
        };
        let toks_host = verify.output_host(&outs, 2)?.as_i32(); // [B, vt]
        let mut it = outs.into_iter();
        let n_path_lit = it.next().unwrap();
        let _path_lit = it.next();
        let _toks_lit = it.next();
        g.tkv = it.next().unwrap(); // already path-spliced in-graph
        let feats = it.next().unwrap();
        let h_sel = it.next().unwrap();

        // --- 3. bookkeeping per row -------------------------------------
        let mut paths: Vec<Vec<usize>> = vec![Vec::new(); b];
        for (row, seq) in g.seqs.iter_mut().enumerate() {
            if seq.done {
                continue; // in-graph verdicts for done rows are garbage
            }
            let j = (n_path_host[row].max(0) as usize).min(depth);
            if let Some(ph) = &path_host {
                paths[row] = ph[row * kq..row * kq + j]
                    .iter()
                    .map(|&x| (x.max(0) as usize).min(n - 1))
                    .collect();
            }
            // tokens_out shares the chain layout: accepted candidates
            // then the replacement/bonus emission.
            let token = toks_host[row * vt + j];
            // Adaptation harvest: judged node set from topology + the
            // in-graph accepted path, as on the host tree round.
            if let Some(sink) = &self.replay {
                super::adapt::harvest_tree_row(
                    sink,
                    seq.id,
                    self.metrics.decode_rounds,
                    seq.len,
                    &seq.generated,
                    &drafts[row],
                    |i| tree.parent(i),
                    &paths[row],
                );
            }
            Self::apply_verdict(seq, &toks_host[row * vt..row * vt + j], depth, j, token);
            self.metrics.observe_round_row(n, j);
            self.controller.observe_tree(tree, j);
        }

        // --- 4. advance draft state (backend-specific; stateful tree
        // backends splice their draft KV against the in-graph-spliced
        // target cache and re-extend from the resident features) --------
        self.backend
            .advance_tree_device(&self.cx, g, &drafts, &paths, n_path_lit, feats, h_sel)?;
        Ok(())
    }

    fn result_of(seq: &SeqState) -> RequestResult {
        RequestResult {
            tokens: seq.generated.clone(),
            stats: seq.stats.clone(),
            latency_ms: seq.total_ms,
            ttft_ms: seq.ttft_ms,
            queue_ms: seq.queue_ms,
            rounds: seq.rounds,
        }
    }

    // ------------------------------------------------------------------
    // public entry points
    // ------------------------------------------------------------------

    /// Run a batch of prompts to completion in lockstep (the evaluation
    /// protocol: the group runs until every row finishes). Returns
    /// results in prompt order with true per-session latencies.
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<RequestResult>> {
        let reqs: Vec<(Vec<i32>, usize)> =
            prompts.iter().map(|p| (p.clone(), max_new)).collect();
        self.generate_batch_with(&reqs)
    }

    /// Lockstep decode with a per-request generation cap.
    pub fn generate_batch_with(
        &mut self,
        requests: &[(Vec<i32>, usize)],
    ) -> Result<Vec<RequestResult>> {
        anyhow::ensure!(!requests.is_empty());
        let now = Instant::now();
        let reqs: Vec<AdmitReq> = requests
            .iter()
            .enumerate()
            .map(|(i, (p, max_new))| AdmitReq {
                id: self.next_req_id + i as u64,
                prompt: p.clone(),
                max_new: *max_new,
                enqueued: now,
                deadline: None,
            })
            .collect();
        self.next_req_id += requests.len() as u64;
        let max_new_cap = requests.iter().map(|(_, m)| *m).max().unwrap_or(16);
        let mut g = self.bootstrap_group(&reqs)?;
        let mut rounds = 0u64;
        while g.seqs.iter().any(|s| !s.done) {
            self.decode_round(&mut g)?;
            rounds += 1;
            if rounds > (max_new_cap * 4 + 16) as u64 {
                bail!("round budget exceeded — engine stuck?");
            }
        }
        let results: Vec<RequestResult> = g
            .seqs
            .iter()
            .take(requests.len())
            .map(Self::result_of)
            .collect();
        for r in &results {
            self.metrics.observe_request(r);
        }
        Ok(results)
    }

    /// Vanilla autoregressive baseline (no speculation): one target
    /// decode call per token. Used for Table 4 speedups.
    pub fn generate_vanilla(&mut self, prompt: &[i32], max_new: usize) -> Result<RequestResult> {
        let t0 = Instant::now();
        let b = 1usize;
        let sp = self.cx.rt.manifest.prompt_len;
        let vocab = self.cx.tspec.vocab;
        anyhow::ensure!(prompt.len() >= 2 && prompt.len() <= sp);
        let mut tok_flat = vec![0i32; sp];
        tok_flat[..prompt.len()].copy_from_slice(prompt);
        let prefill = self.cx.rt.target_entry(&self.cx.tspec.name, "prefill_b1")?;
        let dyn_in = [
            lit_i32(&[b, sp], &tok_flat)?,
            lit_scalar_i32(prompt.len() as i32)?,
        ];
        let dyn_b = upload(self.cx.rt, &dyn_in)?;
        let args = arg_refs(&self.cx.tparams, &[], &dyn_b);
        let outs = prefill.run_bufs(&args)?;
        let logits = prefill.output_host(&outs, 0)?;
        let mut tkv = outs.into_iter().nth(1).unwrap();

        let mut rng = Pcg64::new(self.cx.opts.seed, 0x7a71);
        let temp = self.cx.opts.temperature.max(1e-3);
        let lrow = tensor_row(&logits, 0, &[b, sp, vocab], prompt.len() - 1);
        let p = sampling::softmax_t(&lrow, temp);
        let mut last = self.cx.sample_target(&mut rng, &p);
        let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut generated = vec![last];
        let mut len = prompt.len();
        let decode = self.cx.rt.target_entry(&self.cx.tspec.name, "decode_b1")?;
        while generated.len() < max_new {
            let dyn_in = [tkv, lit_i32(&[b, 1], &[last])?, lit_i32(&[b], &[len as i32])?];
            let dyn_b = upload(self.cx.rt, &dyn_in)?;
            let args = arg_refs(&self.cx.tparams, &[], &dyn_b);
            let outs = decode.run_bufs(&args)?;
            let lg = decode.output_host(&outs, 0)?;
            let lrow = tensor_row(&lg, 0, &[b, 1, vocab], 0);
            let p = sampling::softmax_t(&lrow, temp);
            last = self.cx.sample_target(&mut rng, &p);
            generated.push(last);
            len += 1;
            tkv = outs.into_iter().nth(1).unwrap();
        }
        Ok(RequestResult {
            tokens: generated,
            stats: AcceptanceStats::new(self.cx.k),
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            ttft_ms,
            queue_ms: 0.0,
            rounds: max_new as u64,
        })
    }
}

// ---------------------------------------------------------------------------
// continuous-batching driver interface
// ---------------------------------------------------------------------------

/// Placeholder left behind when a migrating session's `SeqState` is
/// moved out of the old group (which the scheduler drops immediately).
fn drained_seq(seed: u64) -> SeqState {
    SeqState {
        id: PAD_STREAM_BASE,
        len: 2,
        last_token: 0,
        generated: Vec::new(),
        max_new: 0,
        rng: request_rng(seed, PAD_STREAM_BASE),
        stats: AcceptanceStats::new(1),
        done: true,
        hidden: Vec::new(),
        q1: Vec::new(),
        enqueued: Instant::now(),
        queue_ms: 0.0,
        ttft_ms: 0.0,
        total_ms: 0.0,
        rounds: 0,
    }
}

/// Padding row for a migrated group: clones a live row's decode state
/// (the batched propose calls need a valid hidden/q1 in every row) but
/// is inert — done, its own pad RNG stream, no generation budget.
fn pad_clone(src: &SeqState, row: usize, seed: u64) -> SeqState {
    let id = PAD_STREAM_BASE + row as u64;
    SeqState {
        id,
        len: src.len,
        last_token: src.last_token,
        generated: Vec::new(),
        max_new: 0,
        rng: request_rng(seed, id),
        stats: AcceptanceStats::new(src.stats.k),
        done: true,
        hidden: src.hidden.clone(),
        q1: src.q1.clone(),
        enqueued: src.enqueued,
        queue_ms: 0.0,
        ttft_ms: 0.0,
        total_ms: 0.0,
        rounds: 0,
    }
}

impl<'rt> SchedulerCore for SpecEngine<'rt> {
    type Group = GroupState;

    fn attach_replay(&mut self, sink: super::adapt::ReplaySink) {
        self.replay = Some(sink);
    }

    fn swap_draft(&mut self, ckpt: &std::path::Path) -> Result<()> {
        self.swap_draft_checkpoint(ckpt)
    }

    fn bucket(&self, n: usize) -> usize {
        self.cx.bucket(n)
    }

    /// Reject malformed prompts at SUBMIT time, with the same bounds
    /// `bootstrap_group` enforces — a bad request must bounce off the
    /// front door instead of engine-fataling the group it lands in.
    fn validate(&self, prompt: &[i32], _max_new: usize) -> std::result::Result<(), String> {
        let sp = self.cx.rt.manifest.prompt_len;
        if prompt.len() < 2 || prompt.len() > sp {
            return Err(format!("prompt length {} not in 2..={sp}", prompt.len()));
        }
        Ok(())
    }

    fn bootstrap(&mut self, reqs: &[AdmitReq]) -> Result<GroupState> {
        // Scheduler-assigned ids are authoritative; keep the engine's own
        // counter ahead of them so lockstep calls never reuse a stream.
        if let Some(max_id) = reqs.iter().map(|r| r.id).max() {
            self.next_req_id = self.next_req_id.max(max_id + 1);
        }
        // A fresh group replaces whatever ran before it; any carries
        // parked against the old group's rows are dead.
        self.pending_prefill.clear();
        self.bootstrap_group(reqs)
    }

    /// Admit one request into free row `row` of a running group: per-row
    /// prefill at the smallest bucket, then a one-row KV copy into the
    /// group's packed caches (device-side when the copy entry is lowered,
    /// host fallback otherwise) plus backend draft state adoption.
    fn join(&mut self, g: &mut GroupState, row: usize, req: &AdmitReq) -> Result<()> {
        anyhow::ensure!(row < g.b, "join row {row} out of range (b={})", g.b);
        self.next_req_id = self.next_req_id.max(req.id + 1);
        let mut mini = self.bootstrap_group(std::slice::from_ref(req))?;
        g.tkv = match copy_kv_row_device(&self.cx, KvSide::Target, g.b, mini.b, &g.tkv, &mini.tkv, row)? {
            Some(tkv) => tkv,
            None => copy_literal_row(
                &g.tkv,
                &g.tkv_spec,
                row,
                &mini.tkv,
                &mini.tkv_spec,
                0,
                TKV_BATCH_AXIS,
            )?,
        };
        self.backend.adopt_row(&self.cx, g, row, &mini, 0)?;
        if self.cx.device_verify {
            g.tok0[row] = mini.tok0[0];
        }
        g.seqs[row] = mini.seqs.swap_remove(0);
        Ok(())
    }

    fn prefill_chunk_len(&self) -> Option<usize> {
        self.prefill_chunk.as_ref().map(|(c, _)| *c)
    }

    /// The verify-vs-prefill exchange rate comes from the SAME cost
    /// model the speculation controller prices K with, so the arbiter's
    /// "one chunk ≈ chunk/verify_t rounds" stays honest per backend.
    fn prefill_arbiter(&self, max_chunks_per_round: usize) -> Option<PrefillArbiter> {
        let (c, _) = self.prefill_chunk.as_ref()?;
        Some(PrefillArbiter::new(PrefillArbiterCfg {
            max_chunks_per_round,
            ..PrefillArbiterCfg::for_chunk(
                *c,
                self.cx.rt.manifest.verify_t,
                self.backend.cost_model(),
                self.cx.k,
            )
        }))
    }

    /// Park a chunked prefill on free row `row`: seed the carry from the
    /// longest cached chunk-boundary prefix of the prompt (a zeroed KV
    /// otherwise) and return how many positions were actually skipped —
    /// at most the scheduler's authorization `skip`, which caps the skip
    /// at whole chunks the radix cache proved shared AND below the final
    /// chunk (the first token's logits must be computed). The row stays
    /// inert padding until the final `prefill_step` splices the session.
    fn prefill_begin(
        &mut self,
        g: &mut GroupState,
        row: usize,
        req: &AdmitReq,
        skip: usize,
    ) -> Result<usize> {
        anyhow::ensure!(row < g.b, "prefill row {row} out of range (b={})", g.b);
        anyhow::ensure!(
            !self.pending_prefill.contains_key(&row),
            "row {row} already has a prefill in flight"
        );
        let (c, kv_shape) = self
            .prefill_chunk
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifact set lacks prefill_chunk_b1"))?;
        anyhow::ensure!(
            skip % c == 0 && skip < req.prompt.len(),
            "bad skip authorization {skip} (chunk {c}, prompt {})",
            req.prompt.len()
        );
        self.next_req_id = self.next_req_id.max(req.id + 1);
        // Longest cached boundary ≤ the authorization. The radix cache
        // authorizes by block sharing; the snapshot cache is smaller and
        // FIFO-bounded, so a miss here just recomputes — never corrupts.
        let mut start = skip;
        let mut carry = None;
        while start > 0 {
            if let Some((kv, feats)) = self.chunk_cache.get(&req.prompt[..start]) {
                carry = Some((pack::to_literal(kv)?, feats.clone()));
                break;
            }
            start -= c;
        }
        let (kv, feats) = match carry {
            Some(v) => v,
            None => (lit_zeros_f32(&kv_shape)?, Vec::new()),
        };
        // Clear whatever drained session the row still pads with.
        self.evict(g, row);
        self.pending_prefill.insert(
            row,
            PendingPrefill {
                req: req.clone(),
                done: start,
                kv,
                feats,
                queue_ms: Instant::now()
                    .saturating_duration_since(req.enqueued)
                    .as_secs_f64()
                    * 1e3,
            },
        );
        Ok(start)
    }

    /// Advance row `row`'s parked prefill by one chunk: one
    /// `prefill_chunk_b1` dispatch at pos = `done` over the carried KV.
    /// Intermediate boundaries publish their carry to the snapshot cache
    /// (future joins sharing the prefix skip the compute). The final
    /// chunk holds the last prompt position: sample the first token from
    /// its logits — bit-equal to whole-prompt prefill, pinned by
    /// python/tests/test_chunked_prefill.py — then bootstrap the draft
    /// and splice the row exactly like `join`. Returns true when live.
    fn prefill_step(&mut self, g: &mut GroupState, row: usize) -> Result<bool> {
        let (c, _) = self
            .prefill_chunk
            .clone()
            .ok_or_else(|| anyhow::anyhow!("artifact set lacks prefill_chunk_b1"))?;
        let mut pending = match self.pending_prefill.remove(&row) {
            Some(p) => p,
            None => anyhow::bail!("no prefill in flight on row {row}"),
        };
        let len = pending.req.prompt.len();
        anyhow::ensure!(pending.done < len, "prefill already complete on row {row}");
        let mut chunk_tok = vec![0i32; c];
        for (i, slot) in chunk_tok.iter_mut().enumerate() {
            if pending.done + i < len {
                *slot = pending.req.prompt[pending.done + i];
            }
        }
        let entry = self.cx.rt.target_entry(&self.cx.tspec.name, "prefill_chunk_b1")?;
        let dyn_in = [
            pending.kv,
            lit_i32(&[1, c], &chunk_tok)?,
            lit_i32(&[1], &[pending.done as i32])?,
        ];
        let rt = self.cx.rt;
        let tparams = &self.cx.tparams;
        // A chunk blip retries in place (the carry is untouched by a
        // failed attempt); past the budget the error surfaces and the
        // scheduler's lane containment evicts just this session.
        let outs = exec_with_retry(&mut self.metrics, || {
            let dyn_b = upload(rt, &dyn_in)?;
            let args = arg_refs(tparams, &[], &dyn_b);
            entry.run_bufs(&args)
        })?;
        let logits = entry.output_host(&outs, 0)?;
        let feats_t = entry.output_host(&outs, 2)?;
        pending.kv = outs.into_iter().nth(1).unwrap();
        pending.feats.extend(feats_t.as_f32());
        let prev_done = pending.done;
        pending.done += c;

        if pending.done < len {
            // Publish the boundary carry for future shared-prefix joins.
            // Feats cover 0..done by induction (cache seeds included),
            // so the snapshot is a complete resume point.
            let kv_host =
                pack::from_literal(&pending.kv, &entry.spec.outputs[1], "prefill_chunk carry")?;
            self.chunk_cache.put(
                pending.req.prompt[..pending.done].to_vec(),
                kv_host,
                pending.feats.clone(),
            );
            self.pending_prefill.insert(row, pending);
            return Ok(false);
        }

        // --- final chunk: sample the first token, splice the row -----
        let sp = self.cx.rt.manifest.prompt_len;
        let vocab = self.cx.tspec.vocab;
        let f3 = self.cx.tspec.feat_dim;
        let idx = (len - 1) - prev_done;
        let lrow = tensor_row(&logits, 0, &[1, c, vocab], idx);
        let p = sampling::softmax_t(&lrow, self.cx.opts.temperature.max(1e-3));
        let mut rng = request_rng(self.cx.opts.seed, pending.req.id);
        let first = self.cx.sample_target(&mut rng, &p);
        let seq = SeqState {
            id: pending.req.id,
            len,
            last_token: first,
            generated: vec![first],
            max_new: pending.req.max_new,
            rng,
            stats: AcceptanceStats::new(self.cx.k),
            done: false,
            hidden: Vec::new(),
            q1: Vec::new(),
            enqueued: pending.req.enqueued,
            queue_ms: pending.queue_ms,
            ttft_ms: 0.0,
            total_ms: 0.0,
            rounds: 0,
        };
        // Whole-prompt layouts for the draft bootstrap: tokens and feats
        // zero-padded past the prompt. Positions ≥ len are masked by the
        // causal/len mask on every verify path, and draft-side deviation
        // cannot change emitted tokens (greedy = target argmax path;
        // stochastic = Leviathan-lossless) — only acceptance rates.
        let mut tok_flat = vec![0i32; sp];
        tok_flat[..len].copy_from_slice(&pending.req.prompt);
        let mut feats_flat = vec![0f32; sp * f3];
        let nf = pending.feats.len().min(sp * f3);
        feats_flat[..nf].copy_from_slice(&pending.feats[..nf]);
        let feats = HostTensor::from_f32(&[1, sp, f3], &feats_flat);
        let tkv_spec = {
            let mut s = entry.spec.outputs[1].clone();
            s.name = String::new();
            s
        };
        let mut mini = GroupState {
            b: 1,
            seqs: vec![seq],
            tkv: pending.kv,
            tkv_spec,
            dkv: None,
            dkv_spec: None,
            h_prev: None,
            tok0: vec![0; 1],
            q0_dev: None,
        };
        self.backend.bootstrap(&self.cx, &mut mini, &tok_flat, &feats)?;
        mini.seqs[0].ttft_ms = mini.seqs[0].enqueued.elapsed().as_secs_f64() * 1e3;
        g.tkv = match copy_kv_row_device(&self.cx, KvSide::Target, g.b, 1, &g.tkv, &mini.tkv, row)? {
            Some(tkv) => tkv,
            None => copy_literal_row(
                &g.tkv,
                &g.tkv_spec,
                row,
                &mini.tkv,
                &mini.tkv_spec,
                0,
                TKV_BATCH_AXIS,
            )?,
        };
        self.backend.adopt_row(&self.cx, g, row, &mini, 0)?;
        if self.cx.device_verify {
            g.tok0[row] = mini.tok0[0];
        }
        g.seqs[row] = mini.seqs.swap_remove(0);
        Ok(true)
    }

    fn round(&mut self, g: &mut GroupState) -> Result<()> {
        self.decode_round(g)
    }

    /// Bucket migration (the scheduler's long-tail downshift, or the
    /// upshift that re-grows a shrunk group when arrivals queue behind
    /// it): repack the listed live rows into a fresh group at lowered
    /// bucket `b_new`. The target KV moves entirely ON DEVICE through
    /// the `kv_gather_rows_b{Bsrc}x{Bdst}` entry (zero KV bytes cross
    /// the host; artifact sets lowered before the entry existed are a
    /// hard error — re-lower); the per-sequence `SeqState`s move by
    /// value and the backend repacks its packed draft state via
    /// `DraftBackend::migrate_rows` (device gather for KV-bearing
    /// backends). Padding rows clone the last live row and start done —
    /// the bootstrap convention.
    fn migrate(&mut self, g: &mut GroupState, rows: &[usize], b_new: usize) -> Result<GroupState> {
        let n = rows.len();
        anyhow::ensure!(n > 0, "migrate of zero rows");
        // Carries are keyed by row index; the scheduler holds bucket
        // moves while any prefill is in flight — backstop it here.
        anyhow::ensure!(
            self.pending_prefill.is_empty(),
            "migrate with {} chunked prefill(s) in flight",
            self.pending_prefill.len()
        );
        anyhow::ensure!(
            n <= b_new && b_new != g.b,
            "bad migration target {b_new} for {n} rows (from b={})",
            g.b
        );
        anyhow::ensure!(
            self.cx.rt.manifest.serve_batches.contains(&b_new),
            "migration target {b_new} is not a lowered serve bucket"
        );
        let src_map: Vec<usize> = (0..b_new).map(|i| rows[i.min(n - 1)]).collect();
        let tkv = match gather_kv_rows_device(
            &self.cx,
            KvSide::Target,
            g.b,
            b_new,
            &g.tkv,
            &src_map,
        )? {
            Some(tkv) => tkv,
            None => anyhow::bail!(
                "migrate: artifact set lacks kv_gather_rows_b{}x{b_new} — \
                 re-lower the artifacts: python/compile/aot.py",
                g.b
            ),
        };
        let tkv_spec = {
            let mut s = g.tkv_spec.clone();
            s.name = String::new();
            s.shape[TKV_BATCH_AXIS] = b_new;
            s
        };
        self.metrics.observe_migration_host_kv_bytes(0);
        // Sessions move; padding rows clone the last live session's
        // decode state (valid hidden/q1 for the batched propose calls)
        // but are inert: done, pad-stream RNG, no generation budget.
        let mut seqs: Vec<SeqState> = Vec::with_capacity(b_new);
        for (dst_row, &src_row) in src_map.iter().enumerate() {
            if dst_row < n {
                seqs.push(std::mem::replace(
                    &mut g.seqs[src_row],
                    drained_seq(self.cx.opts.seed),
                ));
            } else {
                let pad = pad_clone(&seqs[n - 1], dst_row, self.cx.opts.seed);
                seqs.push(pad);
            }
        }
        let tok0 = if g.tok0.is_empty() {
            vec![0; b_new]
        } else {
            src_map.iter().map(|&r| g.tok0[r]).collect()
        };
        let mut migrated = GroupState {
            b: b_new,
            seqs,
            tkv,
            tkv_spec,
            dkv: None,
            dkv_spec: None,
            h_prev: None,
            tok0,
            q0_dev: None,
        };
        self.backend.migrate_rows(&self.cx, &mut migrated, g, &src_map)?;
        Ok(migrated)
    }

    fn row_done(&self, g: &GroupState, row: usize) -> bool {
        g.seqs[row].done
    }

    /// Per-token streaming hook: `generated` is append-only across
    /// rounds (accepted prefix + bonus token commit, rejections are
    /// never applied), and `result_of` returns exactly this sequence —
    /// so streamed deltas concat to the terminal reply bit-for-bit.
    fn row_tokens(&self, g: &GroupState, row: usize) -> Option<&[i32]> {
        Some(&g.seqs[row].generated)
    }

    /// Turn `row` into inert padding mid-flight (cancellation, deadline
    /// expiry, session-fatal containment): the row keeps decoding as a
    /// pad stream — the executables' batch shape must stay full — but
    /// no session state survives in it and a join can replace it.
    fn evict(&mut self, g: &mut GroupState, row: usize) {
        // Drop any carry parked on the row (prefill-lane containment).
        self.pending_prefill.remove(&row);
        let seq = &mut g.seqs[row];
        seq.id = PAD_STREAM_BASE + row as u64;
        seq.done = true;
        seq.max_new = 0;
        seq.generated.clear();
    }

    fn take_result(&mut self, g: &mut GroupState, row: usize) -> RequestResult {
        let res = Self::result_of(&g.seqs[row]);
        self.metrics.observe_request(&res);
        // The row keeps decoding as inert padding until a join replaces
        // it; mark it as such so no session state leaks.
        g.seqs[row].id = PAD_STREAM_BASE;
        res
    }
}
