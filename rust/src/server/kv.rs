//! KV-cache management.
//!
//! The engine keeps each decode group's caches PACKED in the batched
//! layout the executables expect (target kv [L,2,B,H,Smax,Dh], draft kv
//! [2,B,H,Smax,Dh]); a sequence's cache is a batch ROW. Steady-state
//! rounds therefore move zero cache bytes on the host — the tensors flow
//! executable-to-executable — and only group membership changes (a
//! request joining/leaving under continuous batching) pay one row copy.
//! When the artifacts carry the `kv_copy_row_b*` / `dkv_copy_row_b*`
//! entries even that copy is a device-side splice
//! (`backend::copy_tkv_row_device`); the host `copy_row` below is the
//! strided fallback for older artifact sets.
//!
//! `SlotMap` tracks row occupancy; `copy_row` is the strided row mover
//! and `gather_rows` its many-row generalization (the host reference for
//! the device `kv_gather_rows_b{Bsrc}x{Bdst}` migration entry).
//!
//! CAPACITY accounting is paged: `BlockPool` hands out fixed-size cache
//! blocks from a free-list, `RadixCache` shares identical token-prefix
//! blocks between sessions with reference counts, and `PagedKv` ties
//! both to per-session `BlockTable`s with reservation-based admission —
//! a session reserves blocks for its uncached prompt suffix AND its
//! full `max_new` budget up front, so a decode can never OOM mid-flight;
//! admission load-sheds instead (see DESIGN.md §8).

use std::collections::{BTreeMap, BTreeSet, HashMap};

use anyhow::Result;

use crate::tensor::HostTensor;

/// Copy batch row `src_b` of `src` into row `dst_b` of `dst`, where the
/// batch dimension is `axis` in both tensors (all other dims equal).
pub fn copy_row(
    dst: &mut HostTensor,
    dst_b: usize,
    src: &HostTensor,
    src_b: usize,
    axis: usize,
) -> Result<()> {
    anyhow::ensure!(dst.dtype == src.dtype, "dtype mismatch");
    anyhow::ensure!(
        dst.shape.len() == src.shape.len(),
        "rank mismatch {:?} vs {:?}",
        dst.shape,
        src.shape
    );
    for (i, (&d, &s)) in dst.shape.iter().zip(&src.shape).enumerate() {
        if i != axis {
            anyhow::ensure!(d == s, "dim {i} mismatch {:?} vs {:?}", dst.shape, src.shape);
        }
    }
    let db = dst.shape[axis];
    let sb = src.shape[axis];
    anyhow::ensure!(dst_b < db && src_b < sb, "row out of range");
    let outer: usize = dst.shape[..axis].iter().product();
    let inner: usize = dst.shape[axis + 1..].iter().product::<usize>() * dst.dtype.size();
    for o in 0..outer {
        let doff = (o * db + dst_b) * inner;
        let soff = (o * sb + src_b) * inner;
        dst.data[doff..doff + inner].copy_from_slice(&src.data[soff..soff + inner]);
    }
    Ok(())
}

/// Gather batch rows of `src` into a fresh tensor whose batch dim (at
/// `axis`) is `row_map.len()`: result row `i` is `src` row `row_map[i]`.
/// `row_map` may repeat rows (migration clones a live row into padding
/// slots). This is the HOST REFERENCE for the lowered
/// `kv_gather_rows_b{Bsrc}x{Bdst}` entry — the device gather must be
/// bit-identical to it (property-tested in `tests/properties.rs`).
pub fn gather_rows(src: &HostTensor, row_map: &[usize], axis: usize) -> Result<HostTensor> {
    anyhow::ensure!(axis < src.shape.len(), "axis out of range");
    anyhow::ensure!(!row_map.is_empty(), "empty row_map");
    let sb = src.shape[axis];
    let mut shape = src.shape.clone();
    shape[axis] = row_map.len();
    let mut dst = HostTensor::zeros(src.dtype, &shape);
    let outer: usize = src.shape[..axis].iter().product();
    let inner: usize = src.shape[axis + 1..].iter().product::<usize>() * src.dtype.size();
    let db = row_map.len();
    for (dst_b, &src_b) in row_map.iter().enumerate() {
        anyhow::ensure!(src_b < sb, "row {src_b} out of range (batch {sb})");
        for o in 0..outer {
            let doff = (o * db + dst_b) * inner;
            let soff = (o * sb + src_b) * inner;
            dst.data[doff..doff + inner].copy_from_slice(&src.data[soff..soff + inner]);
        }
    }
    Ok(dst)
}

/// Row-slot occupancy for one decode group (continuous batching).
#[derive(Debug, Clone)]
pub struct SlotMap {
    /// slot -> sequence id (None = free / padding row)
    slots: Vec<Option<u64>>,
    high_water: usize,
}

impl SlotMap {
    pub fn new(capacity: usize) -> SlotMap {
        SlotMap {
            slots: vec![None; capacity],
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_full(&self) -> bool {
        self.occupied() == self.slots.len()
    }

    pub fn alloc(&mut self, seq_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| s.is_none())?;
        self.slots[slot] = Some(seq_id);
        self.high_water = self.high_water.max(self.occupied());
        Some(slot)
    }

    pub fn free(&mut self, seq_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| *s == Some(seq_id))?;
        self.slots[slot] = None;
        Some(slot)
    }

    pub fn slot_of(&self, seq_id: u64) -> Option<usize> {
        self.slots.iter().position(|s| *s == Some(seq_id))
    }

    pub fn iter_occupied(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.map(|id| (i, id)))
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }
}

// ---------------------------------------------------------------------------
// paged KV: block pool + refcounted radix prefix cache
// ---------------------------------------------------------------------------

/// Handle to one fixed-size KV block.
pub type BlockId = usize;

/// Fixed-size block allocator with per-block reference counts and a
/// LIFO free-list. A block is live while its refcount is non-zero;
/// `release` returns it to the free-list at zero. Refcounts are how the
/// radix cache shares one device block between many sessions: each
/// holding session owns one reference, cache residency owns one more.
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    refcount: Vec<u32>,
    free: Vec<BlockId>,
}

impl BlockPool {
    pub fn new(block_size: usize, total_blocks: usize) -> BlockPool {
        assert!(block_size > 0, "block_size must be positive");
        assert!(total_blocks > 0, "pool must hold at least one block");
        BlockPool {
            block_size,
            refcount: vec![0; total_blocks],
            // Reversed so alloc() hands out ids 0, 1, 2, … (stable tests).
            free: (0..total_blocks).rev().collect(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn live_blocks(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    /// Blocks needed to hold `tokens` cache positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.saturating_add(self.block_size - 1) / self.block_size
    }

    /// Allocate one block (refcount 1), or None when the pool is dry.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcount[id], 0, "free-list held a live block");
        self.refcount[id] = 1;
        Some(id)
    }

    // INVARIANT (unwrap audit, DESIGN.md §9): block ids are assigned by
    // `alloc` and flow only through the pool's own tables — no request
    // field ever names a block — so the refcount asserts below guard
    // internal accounting bugs, not inputs. A malformed request cannot
    // reach them.
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refcount[id] > 0, "retain of a free block {id}");
        self.refcount[id] += 1;
    }

    pub fn release(&mut self, id: BlockId) {
        assert!(self.refcount[id] > 0, "double free of block {id}");
        self.refcount[id] -= 1;
        if self.refcount[id] == 0 {
            self.free.push(id);
        }
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcount[id]
    }
}

/// One radix-tree node: a full `block_size`-token chunk keyed under its
/// parent, owning one cache block. `holders` counts sessions currently
/// referencing the node — eviction is vetoed while it is non-zero.
#[derive(Debug)]
struct RadixNode {
    chunk: Vec<i32>,
    block: BlockId,
    parent: Option<usize>,
    /// Child node per next-chunk content: lookup is one hash probe per
    /// level instead of a linear scan over siblings.
    children: HashMap<Vec<i32>, usize>,
    holders: u32,
    last_used: u64,
}

impl RadixNode {
    fn is_evictable(&self) -> bool {
        self.holders == 0 && self.children.is_empty()
    }
}

/// Radix tree over token prefixes at block granularity. Edges are whole
/// `block_size`-token chunks (a node exists only for a COMPLETE block of
/// prompt tokens, so a shared block's contents are immutable — partial
/// tail chunks stay private to their session, which is what makes the
/// sharing copy-on-extend). LRU eviction frees the least-recently-used
/// holder-free leaf; interior nodes become evictable once their subtree
/// is gone. The `evictable` index keeps eviction O(log n) — admission
/// under pool pressure can evict many times per reservation, so a full
/// node scan per eviction would be a latency cliff at large pools.
///
/// INVARIANT (unwrap audit, DESIGN.md §9): node ids live only inside
/// this structure — `roots`/`children` edges, session `shared_nodes`
/// lists and the `evictable` index all point at slots this cache
/// populated, and a slot is vacated (`take`) only when every edge to it
/// is removed in the same call. The `self.nodes[id].as_ref().unwrap()`
/// dereferences below are therefore unreachable from any request input,
/// malformed or not.
#[derive(Debug, Default)]
pub struct RadixCache {
    nodes: Vec<Option<RadixNode>>,
    roots: HashMap<Vec<i32>, usize>,
    free_nodes: Vec<usize>,
    /// Exactly the holder-free leaves, ordered by (last_used, id) —
    /// the invariant every holder/children transition below maintains.
    evictable: BTreeSet<(u64, usize)>,
}

impl RadixCache {
    /// Walk the tree along `prompt`'s full chunks WITHOUT taking
    /// references; returns the matched node ids root-first.
    fn lookup_path(&self, prompt: &[i32], block_size: usize) -> Vec<usize> {
        let mut path = Vec::new();
        let mut level = &self.roots;
        for chunk in prompt.chunks_exact(block_size) {
            match level.get(chunk) {
                Some(&id) => {
                    path.push(id);
                    level = &self.nodes[id].as_ref().unwrap().children;
                }
                None => break,
            }
        }
        path
    }

    /// Take one holder reference on every node of `path` (and one pool
    /// reference per block — the session's share of the block).
    fn acquire(&mut self, pool: &mut BlockPool, path: &[usize], tick: u64) {
        for &id in path {
            let n = self.nodes[id].as_mut().unwrap();
            if n.is_evictable() {
                self.evictable.remove(&(n.last_used, id));
            }
            n.holders += 1;
            n.last_used = tick;
            pool.retain(n.block);
        }
    }

    /// Drop one holder reference (the paired pool release is the
    /// caller's, via the session's block table).
    fn release_holder(&mut self, id: usize) {
        let n = self.nodes[id].as_mut().unwrap();
        debug_assert!(n.holders > 0, "holder underflow on radix node {id}");
        n.holders -= 1;
        if n.is_evictable() {
            self.evictable.insert((n.last_used, id));
        }
    }

    /// Insert `chunk` under `parent` (None = root level) owning `block`.
    /// The cache takes its own pool reference; the caller keeps the
    /// session's. Starts with one holder (the inserting session).
    fn insert(
        &mut self,
        pool: &mut BlockPool,
        parent: Option<usize>,
        chunk: &[i32],
        block: BlockId,
        tick: u64,
    ) -> usize {
        pool.retain(block); // cache residency reference
        let node = RadixNode {
            chunk: chunk.to_vec(),
            block,
            parent,
            children: HashMap::new(),
            holders: 1,
            last_used: tick,
        };
        let id = match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        match parent {
            Some(p) => {
                let pn = self.nodes[p].as_mut().unwrap();
                if pn.is_evictable() {
                    self.evictable.remove(&(pn.last_used, p));
                }
                pn.children.insert(chunk.to_vec(), id);
            }
            None => {
                self.roots.insert(chunk.to_vec(), id);
            }
        }
        id
    }

    /// Evict the least-recently-used holder-free LEAF node, returning
    /// its block to the pool. False when nothing is evictable (every
    /// leaf has a mid-flight holder — the refcount veto).
    fn evict_lru(&mut self, pool: &mut BlockPool) -> bool {
        let Some((_, id)) = self.evictable.pop_first() else {
            return false;
        };
        let node = self.nodes[id].take().unwrap();
        match node.parent {
            Some(p) => {
                let pn = self.nodes[p].as_mut().unwrap();
                pn.children.remove(&node.chunk);
                if pn.is_evictable() {
                    self.evictable.insert((pn.last_used, p));
                }
            }
            None => {
                self.roots.remove(&node.chunk);
            }
        }
        self.free_nodes.push(id);
        pool.release(node.block);
        true
    }

    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }
}

/// Per-session block table: the shared prefix blocks leased from the
/// radix cache (read-only) followed by the session's private blocks
/// (uncached prompt suffix + the full reserved generation budget).
#[derive(Debug)]
pub struct BlockTable {
    pub shared: Vec<BlockId>,
    shared_nodes: Vec<usize>,
    pub private: Vec<BlockId>,
    /// Prompt tokens served from the cache (block-aligned).
    pub cached_len: usize,
}

impl BlockTable {
    pub fn n_blocks(&self) -> usize {
        self.shared.len() + self.private.len()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct PagedKvConfig {
    pub block_size: usize,
    pub total_blocks: usize,
    /// False disables prefix sharing (every session fully private) —
    /// the "dense" baseline the capacity bench compares against.
    pub prefix_cache: bool,
}

impl Default for PagedKvConfig {
    fn default() -> Self {
        PagedKvConfig {
            block_size: 16,
            total_blocks: 256,
            prefix_cache: true,
        }
    }
}

/// Load-shed verdict from `PagedKv::admit`: the pool cannot reserve the
/// session's worst-case footprint even after LRU eviction. No state was
/// changed — the request can simply be requeued.
#[derive(Debug, PartialEq, Eq)]
pub struct KvShed {
    pub blocks_needed: usize,
    pub blocks_free: usize,
}

/// The paged-KV manager: block pool + radix prefix cache + per-session
/// block tables, with RESERVATION-BASED admission. `admit` either
/// reserves every block the session can ever touch (uncached prompt
/// suffix + `max_new`) or sheds the request with no state change — a
/// admitted session can never OOM mid-decode, so live block tables are
/// never corrupted by allocation failure.
#[derive(Debug)]
pub struct PagedKv {
    pool: BlockPool,
    cache: RadixCache,
    prefix_cache: bool,
    tables: BTreeMap<u64, BlockTable>,
    tick: u64,
    /// Prompt tokens seen / served from cache across all admissions
    /// (the prefix hit-rate numerator/denominator).
    pub prompt_tokens: u64,
    pub prompt_tokens_cached: u64,
    pub sheds: u64,
    pub evictions: u64,
}

impl PagedKv {
    pub fn new(cfg: PagedKvConfig) -> PagedKv {
        PagedKv {
            pool: BlockPool::new(cfg.block_size, cfg.total_blocks),
            cache: RadixCache::default(),
            prefix_cache: cfg.prefix_cache,
            tables: BTreeMap::new(),
            tick: 0,
            prompt_tokens: 0,
            prompt_tokens_cached: 0,
            sheds: 0,
            evictions: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    pub fn blocks_live(&self) -> usize {
        self.pool.live_blocks()
    }

    pub fn blocks_free(&self) -> usize {
        self.pool.free_blocks()
    }

    pub fn sessions(&self) -> usize {
        self.tables.len()
    }

    pub fn table(&self, id: u64) -> Option<&BlockTable> {
        self.tables.get(&id)
    }

    /// Fraction of admitted prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.prompt_tokens_cached as f64 / self.prompt_tokens as f64
    }

    /// Admit session `id`: look up the shared prompt prefix, then
    /// reserve private blocks for the uncached suffix plus the FULL
    /// `max_new` budget, LRU-evicting holder-free cache leaves as
    /// needed. On success the full prompt's complete chunks are
    /// published to the cache for later sessions. Returns the cached
    /// token count: the block-aligned shared prefix. Capacity-wise the
    /// hit always saves blocks; compute-wise it becomes skipped work
    /// only when the scheduler's chunked-prefill lane is on — it
    /// authorizes skipping whole prefill chunks inside this prefix
    /// (whole-prompt joins recompute it, and `prefill_tokens_saved`
    /// counts only the skipped-compute case; see DESIGN.md §11).
    pub fn admit(&mut self, id: u64, prompt: &[i32], max_new: usize) -> Result<usize, KvShed> {
        // INVARIANT: session ids are scheduler-assigned (monotonic
        // `next_id`), never client-chosen, so a double admit is a
        // scheduler bug — not a state a malformed request can induce.
        assert!(
            !self.tables.contains_key(&id),
            "session {id} already admitted"
        );
        self.tick += 1;
        let bs = self.pool.block_size();

        // 1. Prefix lookup, taking holder references FIRST so eviction
        //    inside the reservation loop can never free a block this
        //    session is about to share (the refcount veto).
        let path = if self.prefix_cache {
            self.cache.lookup_path(prompt, bs)
        } else {
            Vec::new()
        };
        self.cache.acquire(&mut self.pool, &path, self.tick);
        let shared: Vec<BlockId> = path
            .iter()
            .map(|&n| self.cache.nodes[n].as_ref().unwrap().block)
            .collect();
        let cached_len = shared.len() * bs;

        // 2. Reserve the worst-case private footprint.
        let need = self.pool.blocks_for(prompt.len() - cached_len + max_new);
        let mut private = Vec::with_capacity(need);
        while private.len() < need {
            match self.pool.alloc() {
                Some(b) => private.push(b),
                None => {
                    if self.cache.evict_lru(&mut self.pool) {
                        self.evictions += 1;
                    } else {
                        // Shed: roll back every reference taken above.
                        for b in private {
                            self.pool.release(b);
                        }
                        for (&n, &b) in path.iter().zip(&shared) {
                            self.cache.release_holder(n);
                            self.pool.release(b);
                        }
                        self.sheds += 1;
                        return Err(KvShed {
                            blocks_needed: need,
                            blocks_free: self.pool.free_blocks(),
                        });
                    }
                }
            }
        }

        // 3. Publish the prompt's remaining complete chunks so later
        //    sessions share them. Private block j covers tokens
        //    [cached_len + j*bs, …), so full prompt chunk ci maps to
        //    private index ci - shared.len().
        let mut shared = shared;
        let mut shared_nodes = path;
        if self.prefix_cache {
            let full_chunks = prompt.len() / bs;
            // Chunk ci's tokens sit in private block ci - shared.len();
            // promoting in ascending ci order always moves the current
            // HEAD of `private` (earlier promotions shifted the rest).
            for ci in shared.len()..full_chunks {
                let chunk = &prompt[ci * bs..(ci + 1) * bs];
                let block = private.remove(0);
                let parent = shared_nodes.last().copied();
                let node = self
                    .cache
                    .insert(&mut self.pool, parent, chunk, block, self.tick);
                shared.push(block);
                shared_nodes.push(node);
            }
        }

        self.prompt_tokens += prompt.len() as u64;
        self.prompt_tokens_cached += cached_len as u64;
        self.tables.insert(
            id,
            BlockTable {
                shared,
                shared_nodes,
                private,
                cached_len,
            },
        );
        Ok(cached_len)
    }

    /// Release session `id`'s block table: private blocks free
    /// immediately; shared blocks drop the session's reference and stay
    /// cache-resident until LRU eviction reclaims them.
    pub fn release(&mut self, id: u64) {
        let Some(t) = self.tables.remove(&id) else {
            return;
        };
        for (&node, &block) in t.shared_nodes.iter().zip(&t.shared) {
            self.cache.release_holder(node);
            self.pool.release(block);
        }
        for b in t.private {
            self.pool.release(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn copy_row_middle_axis() {
        // [2, 3, 2] with batch axis 1
        let src = HostTensor::from_f32(
            &[2, 3, 2],
            &[
                0., 1., 10., 11., 20., 21., //
                100., 101., 110., 111., 120., 121.,
            ],
        );
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4, 2]);
        copy_row(&mut dst, 3, &src, 1, 1).unwrap();
        let d = dst.as_f32();
        assert_eq!(&d[6..8], &[10., 11.]); // outer 0, row 3
        assert_eq!(&d[14..16], &[110., 111.]); // outer 1, row 3
        assert!(d[..6].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_row_axis0_roundtrip() {
        let src = HostTensor::from_i32(&[2, 3], &[1, 2, 3, 4, 5, 6]);
        let mut dst = HostTensor::zeros(DType::I32, &[1, 3]);
        copy_row(&mut dst, 0, &src, 1, 0).unwrap();
        assert_eq!(dst.as_i32(), vec![4, 5, 6]);
        let mut back = HostTensor::zeros(DType::I32, &[2, 3]);
        copy_row(&mut back, 1, &dst, 0, 0).unwrap();
        assert_eq!(&back.as_i32()[3..], &[4, 5, 6]);
    }

    #[test]
    fn copy_row_rejects_mismatch() {
        let src = HostTensor::zeros(DType::F32, &[2, 3]);
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4]);
        assert!(copy_row(&mut dst, 0, &src, 0, 0).is_err());
        let mut dst2 = HostTensor::zeros(DType::I32, &[2, 3]);
        assert!(copy_row(&mut dst2, 0, &src, 0, 0).is_err());
    }

    /// The join path's actual shapes: draft KV [2, B, H, S, Dh] moved on
    /// axis 1 between buckets of different B (mini b=1 group -> b=4
    /// group), i.e. strided copies with unequal batch dims.
    #[test]
    fn copy_row_draft_kv_shape_across_buckets() {
        let (h, s, dh) = (2usize, 3usize, 2usize);
        let n_src = 2 * 1 * h * s * dh;
        let src = HostTensor::from_f32(
            &[2, 1, h, s, dh],
            &(0..n_src).map(|i| i as f32).collect::<Vec<_>>(),
        );
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4, h, s, dh]);
        copy_row(&mut dst, 2, &src, 0, 1).unwrap();
        let d = dst.as_f32();
        let inner = h * s * dh;
        for kv in 0..2 {
            for row in 0..4 {
                for i in 0..inner {
                    let got = d[(kv * 4 + row) * inner + i];
                    if row == 2 {
                        assert_eq!(got, (kv * inner + i) as f32, "kv {kv} i {i}");
                    } else {
                        assert_eq!(got, 0.0, "row {row} polluted");
                    }
                }
            }
        }
    }

    #[test]
    fn copy_row_out_of_range_rejected() {
        let src = HostTensor::zeros(DType::F32, &[2, 3]);
        let mut dst = HostTensor::zeros(DType::F32, &[2, 3]);
        assert!(copy_row(&mut dst, 2, &src, 0, 0).is_err());
        assert!(copy_row(&mut dst, 0, &src, 3, 1).is_err());
    }

    #[test]
    fn slotmap_alloc_free() {
        let mut m = SlotMap::new(4);
        assert_eq!(m.alloc(10), Some(0));
        assert_eq!(m.alloc(11), Some(1));
        assert_eq!(m.occupied(), 2);
        assert_eq!(m.free(10), Some(0));
        assert_eq!(m.alloc(12), Some(0)); // reuses freed slot
        assert_eq!(m.slot_of(12), Some(0));
        assert_eq!(m.slot_of(99), None);
        assert_eq!(m.high_water(), 2);
        m.alloc(13);
        m.alloc(14);
        assert!(m.is_full());
        assert_eq!(m.alloc(15), None);
    }

    /// Continuous-batching churn: iter_occupied tracks live sessions in
    /// slot order, freeing an unknown id is a no-op, and the high-water
    /// mark survives the group draining.
    #[test]
    fn slotmap_churn_iteration_and_high_water() {
        let mut m = SlotMap::new(3);
        assert_eq!(m.free(42), None, "freeing unknown id is None");
        m.alloc(100);
        m.alloc(101);
        m.alloc(102);
        assert_eq!(
            m.iter_occupied().collect::<Vec<_>>(),
            vec![(0, 100), (1, 101), (2, 102)]
        );
        m.free(101); // leave mid-flight
        assert_eq!(
            m.iter_occupied().collect::<Vec<_>>(),
            vec![(0, 100), (2, 102)]
        );
        assert_eq!(m.alloc(103), Some(1), "join reuses the freed row");
        m.free(100);
        m.free(102);
        m.free(103);
        assert_eq!(m.occupied(), 0);
        assert_eq!(m.high_water(), 3, "high water survives draining");
        assert!(!m.is_full());
    }

    #[test]
    fn gather_rows_matches_copy_row_loop() {
        let (h, s, dh) = (2usize, 3usize, 2usize);
        let n = 2 * 4 * h * s * dh;
        let src = HostTensor::from_f32(
            &[2, 4, h, s, dh],
            &(0..n).map(|i| i as f32 * 0.5 - 7.0).collect::<Vec<_>>(),
        );
        let map = [3usize, 0, 3, 2];
        let got = gather_rows(&src, &map, 1).unwrap();
        let mut want = HostTensor::zeros(DType::F32, &[2, 4, h, s, dh]);
        for (dst_b, &src_b) in map.iter().enumerate() {
            copy_row(&mut want, dst_b, &src, src_b, 1).unwrap();
        }
        assert_eq!(got.data, want.data, "gather != copy_row loop");
        assert_eq!(got.shape, want.shape);
    }

    #[test]
    fn gather_rows_shrink_and_bounds() {
        let src = HostTensor::from_i32(&[4, 2], &[0, 1, 10, 11, 20, 21, 30, 31]);
        let got = gather_rows(&src, &[2], 0).unwrap();
        assert_eq!(got.shape, vec![1, 2]);
        assert_eq!(got.as_i32(), vec![20, 21]);
        assert!(gather_rows(&src, &[4], 0).is_err(), "row out of range");
        assert!(gather_rows(&src, &[], 0).is_err(), "empty map");
    }

    #[test]
    fn block_pool_alloc_release_refcount() {
        let mut p = BlockPool::new(16, 3);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!((p.live_blocks(), p.free_blocks()), (2, 1));
        p.retain(a);
        p.release(a);
        assert_eq!(p.refcount(a), 1, "retained block survives one release");
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), 3);
        let c = p.alloc().unwrap();
        assert_eq!(p.refcount(c), 1);
    }

    fn paged(total_blocks: usize, prefix_cache: bool) -> PagedKv {
        PagedKv::new(PagedKvConfig {
            block_size: 4,
            total_blocks,
            prefix_cache,
        })
    }

    /// 8 shared prompt tokens (2 full chunks): session 1 pays 3 blocks,
    /// later sessions hit the radix cache and pay only the private one.
    #[test]
    fn radix_prefix_shares_blocks_between_sessions() {
        let mut kv = paged(16, true);
        let prompt: Vec<i32> = (0..10).collect();
        assert_eq!(kv.admit(1, &prompt, 2), Ok(0), "cold cache: no hit");
        let live_one = kv.blocks_live();
        assert_eq!(live_one, 3); // 10 prompt + 2 gen = 12 tokens / bs 4
        assert_eq!(kv.admit(2, &prompt, 2), Ok(8), "two full chunks hit");
        assert_eq!(
            kv.blocks_live(),
            live_one + 1,
            "second session adds only its private tail block"
        );
        let t = kv.table(2).unwrap();
        assert_eq!(t.shared.len(), 2);
        assert_eq!(t.private.len(), 1);
        assert_eq!(t.cached_len, 8);
        assert_eq!(
            kv.table(1).unwrap().shared,
            t.shared,
            "both sessions lease the SAME device blocks"
        );
        // Divergent continuation shares only the common prefix chunks.
        let mut other = prompt.clone();
        other[9] = 99; // inside the partial tail chunk -> same 2 hits
        assert_eq!(kv.admit(3, &other, 2), Ok(8));
        let mut fork = prompt.clone();
        fork[5] = 99; // inside chunk 1 -> only chunk 0 hits
        assert_eq!(kv.admit(4, &fork, 2), Ok(4));
        assert_eq!(kv.prefix_hit_rate(), (8 + 8 + 4) as f64 / 40.0);
        for id in 1..=4 {
            kv.release(id);
        }
        assert!(kv.blocks_live() > 0, "cache retains shared chunks");
    }

    #[test]
    fn dense_mode_never_shares() {
        let mut kv = paged(16, false);
        let prompt: Vec<i32> = (0..8).collect();
        assert_eq!(kv.admit(1, &prompt, 4), Ok(0));
        assert_eq!(kv.admit(2, &prompt, 4), Ok(0));
        assert_eq!(kv.blocks_live(), 6, "3 blocks per session, no sharing");
        assert_eq!(kv.prefix_hit_rate(), 0.0);
        kv.release(1);
        assert_eq!(kv.blocks_live(), 3, "dense release frees everything");
    }

    /// The eviction veto: a shared prefix node whose holder is
    /// mid-flight must survive pool pressure; the admission sheds
    /// instead. Once the holder leaves, the same admission succeeds by
    /// evicting the now holder-free node.
    #[test]
    fn lru_eviction_vetoed_while_holder_mid_flight() {
        let mut kv = paged(4, true);
        let prompt: Vec<i32> = (0..8).collect();
        // Session 1: 2 shared chunks + 1 private block = 3 of 4 blocks.
        assert_eq!(kv.admit(1, &prompt, 2), Ok(0));
        assert_eq!(kv.blocks_free(), 1);
        // Session 2 needs 3 private blocks (different prompt, no hits);
        // only 1 free + nothing evictable (session 1 holds both cache
        // nodes) -> shed, and session 1's table is untouched.
        let unrelated: Vec<i32> = (100..108).collect();
        let shed = kv.admit(2, &unrelated, 2).unwrap_err();
        assert_eq!(shed.blocks_needed, 3);
        assert_eq!(kv.sheds, 1);
        assert_eq!(kv.evictions, 0, "veto: no eviction while held");
        assert_eq!(kv.table(1).unwrap().n_blocks(), 3, "live table intact");
        assert!(kv.table(2).is_none());
        assert_eq!(kv.blocks_free(), 1, "shed rolled back every block");
        // Holder leaves -> the leaf cache node becomes evictable -> the
        // same admission now succeeds (2 free + 1 reclaimed = 3).
        kv.release(1);
        assert_eq!(kv.admit(2, &unrelated, 2), Ok(0));
        assert_eq!(kv.evictions, 1, "leaf evicted; root chunk survives");
        kv.release(2);
    }

    /// Free-list exhaustion under join pressure: admission is
    /// all-or-nothing, so a shed can never leave a half-built table or
    /// corrupt an existing one.
    #[test]
    fn exhaustion_sheds_without_corrupting_live_tables() {
        let mut kv = paged(6, true);
        let prompt: Vec<i32> = (0..8).collect();
        assert_eq!(kv.admit(1, &prompt, 2), Ok(0)); // 3 blocks
        assert_eq!(kv.admit(2, &prompt, 2), Ok(8)); // +1 private
        assert_eq!(kv.admit(3, &prompt, 2), Ok(8)); // +1 private
        assert_eq!(kv.blocks_free(), 1);
        // A cache-missing join wanting 3 blocks must shed...
        let cold: Vec<i32> = (50..58).collect();
        assert!(kv.admit(4, &cold, 2).is_err());
        // ...while a cache-hitting join still fits in the last block.
        assert_eq!(kv.admit(5, &prompt, 2), Ok(8));
        assert_eq!(kv.blocks_free(), 0);
        for id in [1, 2, 3, 5] {
            let t = kv.table(id).unwrap();
            assert_eq!(t.shared.len() + t.private.len(), t.n_blocks());
        }
        // Releasing everything (cache still holds the 2 shared chunks).
        for id in [1, 2, 3, 5] {
            kv.release(id);
        }
        assert_eq!(kv.blocks_live(), 2, "only cache-resident chunks left");
        assert_eq!(kv.sessions(), 0);
    }
}
