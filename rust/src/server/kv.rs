//! KV-cache management.
//!
//! The engine keeps each decode group's caches PACKED in the batched
//! layout the executables expect (target kv [L,2,B,H,Smax,Dh], draft kv
//! [2,B,H,Smax,Dh]); a sequence's cache is a batch ROW. Steady-state
//! rounds therefore move zero cache bytes on the host — the tensors flow
//! executable-to-executable — and only group membership changes (a
//! request joining/leaving under continuous batching) pay one row copy.
//! When the artifacts carry the `kv_copy_row_b*` / `dkv_copy_row_b*`
//! entries even that copy is a device-side splice
//! (`backend::copy_tkv_row_device`); the host `copy_row` below is the
//! strided fallback for older artifact sets.
//!
//! `SlotMap` tracks row occupancy; `copy_row` is the strided row mover.

use anyhow::Result;

use crate::tensor::HostTensor;

/// Copy batch row `src_b` of `src` into row `dst_b` of `dst`, where the
/// batch dimension is `axis` in both tensors (all other dims equal).
pub fn copy_row(
    dst: &mut HostTensor,
    dst_b: usize,
    src: &HostTensor,
    src_b: usize,
    axis: usize,
) -> Result<()> {
    anyhow::ensure!(dst.dtype == src.dtype, "dtype mismatch");
    anyhow::ensure!(
        dst.shape.len() == src.shape.len(),
        "rank mismatch {:?} vs {:?}",
        dst.shape,
        src.shape
    );
    for (i, (&d, &s)) in dst.shape.iter().zip(&src.shape).enumerate() {
        if i != axis {
            anyhow::ensure!(d == s, "dim {i} mismatch {:?} vs {:?}", dst.shape, src.shape);
        }
    }
    let db = dst.shape[axis];
    let sb = src.shape[axis];
    anyhow::ensure!(dst_b < db && src_b < sb, "row out of range");
    let outer: usize = dst.shape[..axis].iter().product();
    let inner: usize = dst.shape[axis + 1..].iter().product::<usize>() * dst.dtype.size();
    for o in 0..outer {
        let doff = (o * db + dst_b) * inner;
        let soff = (o * sb + src_b) * inner;
        dst.data[doff..doff + inner].copy_from_slice(&src.data[soff..soff + inner]);
    }
    Ok(())
}

/// Row-slot occupancy for one decode group (continuous batching).
#[derive(Debug, Clone)]
pub struct SlotMap {
    /// slot -> sequence id (None = free / padding row)
    slots: Vec<Option<u64>>,
    high_water: usize,
}

impl SlotMap {
    pub fn new(capacity: usize) -> SlotMap {
        SlotMap {
            slots: vec![None; capacity],
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_full(&self) -> bool {
        self.occupied() == self.slots.len()
    }

    pub fn alloc(&mut self, seq_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| s.is_none())?;
        self.slots[slot] = Some(seq_id);
        self.high_water = self.high_water.max(self.occupied());
        Some(slot)
    }

    pub fn free(&mut self, seq_id: u64) -> Option<usize> {
        let slot = self.slots.iter().position(|s| *s == Some(seq_id))?;
        self.slots[slot] = None;
        Some(slot)
    }

    pub fn slot_of(&self, seq_id: u64) -> Option<usize> {
        self.slots.iter().position(|s| *s == Some(seq_id))
    }

    pub fn iter_occupied(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.map(|id| (i, id)))
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn copy_row_middle_axis() {
        // [2, 3, 2] with batch axis 1
        let src = HostTensor::from_f32(
            &[2, 3, 2],
            &[
                0., 1., 10., 11., 20., 21., //
                100., 101., 110., 111., 120., 121.,
            ],
        );
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4, 2]);
        copy_row(&mut dst, 3, &src, 1, 1).unwrap();
        let d = dst.as_f32();
        assert_eq!(&d[6..8], &[10., 11.]); // outer 0, row 3
        assert_eq!(&d[14..16], &[110., 111.]); // outer 1, row 3
        assert!(d[..6].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_row_axis0_roundtrip() {
        let src = HostTensor::from_i32(&[2, 3], &[1, 2, 3, 4, 5, 6]);
        let mut dst = HostTensor::zeros(DType::I32, &[1, 3]);
        copy_row(&mut dst, 0, &src, 1, 0).unwrap();
        assert_eq!(dst.as_i32(), vec![4, 5, 6]);
        let mut back = HostTensor::zeros(DType::I32, &[2, 3]);
        copy_row(&mut back, 1, &dst, 0, 0).unwrap();
        assert_eq!(&back.as_i32()[3..], &[4, 5, 6]);
    }

    #[test]
    fn copy_row_rejects_mismatch() {
        let src = HostTensor::zeros(DType::F32, &[2, 3]);
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4]);
        assert!(copy_row(&mut dst, 0, &src, 0, 0).is_err());
        let mut dst2 = HostTensor::zeros(DType::I32, &[2, 3]);
        assert!(copy_row(&mut dst2, 0, &src, 0, 0).is_err());
    }

    /// The join path's actual shapes: draft KV [2, B, H, S, Dh] moved on
    /// axis 1 between buckets of different B (mini b=1 group -> b=4
    /// group), i.e. strided copies with unequal batch dims.
    #[test]
    fn copy_row_draft_kv_shape_across_buckets() {
        let (h, s, dh) = (2usize, 3usize, 2usize);
        let n_src = 2 * 1 * h * s * dh;
        let src = HostTensor::from_f32(
            &[2, 1, h, s, dh],
            &(0..n_src).map(|i| i as f32).collect::<Vec<_>>(),
        );
        let mut dst = HostTensor::zeros(DType::F32, &[2, 4, h, s, dh]);
        copy_row(&mut dst, 2, &src, 0, 1).unwrap();
        let d = dst.as_f32();
        let inner = h * s * dh;
        for kv in 0..2 {
            for row in 0..4 {
                for i in 0..inner {
                    let got = d[(kv * 4 + row) * inner + i];
                    if row == 2 {
                        assert_eq!(got, (kv * inner + i) as f32, "kv {kv} i {i}");
                    } else {
                        assert_eq!(got, 0.0, "row {row} polluted");
                    }
                }
            }
        }
    }

    #[test]
    fn copy_row_out_of_range_rejected() {
        let src = HostTensor::zeros(DType::F32, &[2, 3]);
        let mut dst = HostTensor::zeros(DType::F32, &[2, 3]);
        assert!(copy_row(&mut dst, 2, &src, 0, 0).is_err());
        assert!(copy_row(&mut dst, 0, &src, 3, 1).is_err());
    }

    #[test]
    fn slotmap_alloc_free() {
        let mut m = SlotMap::new(4);
        assert_eq!(m.alloc(10), Some(0));
        assert_eq!(m.alloc(11), Some(1));
        assert_eq!(m.occupied(), 2);
        assert_eq!(m.free(10), Some(0));
        assert_eq!(m.alloc(12), Some(0)); // reuses freed slot
        assert_eq!(m.slot_of(12), Some(0));
        assert_eq!(m.slot_of(99), None);
        assert_eq!(m.high_water(), 2);
        m.alloc(13);
        m.alloc(14);
        assert!(m.is_full());
        assert_eq!(m.alloc(15), None);
    }

    /// Continuous-batching churn: iter_occupied tracks live sessions in
    /// slot order, freeing an unknown id is a no-op, and the high-water
    /// mark survives the group draining.
    #[test]
    fn slotmap_churn_iteration_and_high_water() {
        let mut m = SlotMap::new(3);
        assert_eq!(m.free(42), None, "freeing unknown id is None");
        m.alloc(100);
        m.alloc(101);
        m.alloc(102);
        assert_eq!(
            m.iter_occupied().collect::<Vec<_>>(),
            vec![(0, 100), (1, 101), (2, 102)]
        );
        m.free(101); // leave mid-flight
        assert_eq!(
            m.iter_occupied().collect::<Vec<_>>(),
            vec![(0, 100), (2, 102)]
        );
        assert_eq!(m.alloc(103), Some(1), "join reuses the freed row");
        m.free(100);
        m.free(102);
        m.free(103);
        assert_eq!(m.occupied(), 0);
        assert_eq!(m.high_water(), 3, "high water survives draining");
        assert!(!m.is_full());
    }
}
