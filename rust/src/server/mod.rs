//! Serving engine (L3): the vLLM-shaped coordination layer around the
//! AOT-compiled target/draft executables.
//!
//!   * `kv`        — KV-cache row packing plus the paged-KV layer: a
//!     fixed-size block pool with a reference-counted radix prefix
//!     cache (shared system prompts hold one set of device blocks) and
//!     reservation-based admission (see DESIGN.md §8)
//!   * `backend`   — the `DraftBackend` trait + per-architecture
//!     implementations (recurrent EAGLE-3/MTP, MEDUSA, MLP); new draft
//!     architectures plug in here without touching the decode loop
//!   * `engine`    — architecture-agnostic draft-then-verify decode loop,
//!     exact rejection sampling via `spec::sampling`, vanilla
//!     autoregressive baseline
//!   * `fault`     — typed engine faults (`Transient` / `SessionFatal` /
//!     `EngineFatal`) and client-facing request verdicts; the failure
//!     model of DESIGN.md §9
//!   * `batcher`   — request admission / bucket selection policy
//!   * `scheduler` — continuous batching: decode groups as slot-mapped
//!     sessions with mid-flight join/leave (one-row KV copies) and
//!     long-tail downshift (groups migrate to smaller buckets when
//!     occupancy drops, ending padding verify FLOPs)
//!   * `router`    — thread-backed front-end with bounded queues and
//!     backpressure, driving the scheduler; one-shot replies or
//!     incremental [`router::Event`] streams
//!   * `http`      — dependency-light HTTP/1.1 edge: per-token SSE
//!     streaming over chunked transfer, `/healthz`, `/metrics`
//!     (DESIGN.md §10)
//!   * `adapt`     — the online-adaptation loop (DESIGN.md §12):
//!     replay-buffer harvest of live acceptance verdicts, background
//!     LK-loss fine-tune orchestration (subprocess, JSONL protocol,
//!     typed fault containment), and validate-then-commit draft
//!     weight hot-swaps at round boundaries
//!   * `metrics`   — engine + scheduler + HTTP-edge + adaptation
//!     counters, Prometheus-style text
//!
//! See DESIGN.md §3–§4 for the layering contract.

pub mod adapt;
pub mod backend;
pub mod batcher;
pub mod engine;
pub mod fault;
pub mod http;
pub mod kv;
pub mod metrics;
pub mod router;
pub mod scheduler;

pub use adapt::{
    AdaptConfig, AdaptDriver, ReplayBuffer, ReplayRecord, ReplaySink, TrainerChaos,
    TrainerChaosKind, TrainerFault, TrainerHandle, TrainerSpec,
};
pub use backend::DraftBackend;
pub use engine::{AdaptiveOpts, EngineOpts, RequestResult, SpecEngine, VerifyPath};
pub use fault::{EngineError, FaultKind, RequestError};
pub use http::{HttpOpts, HttpServer};
pub use kv::{PagedKv, PagedKvConfig};
pub use metrics::{AdaptMetrics, HttpMetrics};
pub use router::{Event, Router, RouterConfig, StreamSubmission, Submission};
pub use scheduler::{
    AdmitReq, DownshiftConfig, FaultConfig, FaultPlan, PlannedFault, Scheduler, SchedulerCore,
    SimCore, SubmitError,
};
