//! Serving engine (L3): the vLLM-shaped coordination layer around the
//! AOT-compiled target/draft executables.
//!
//!   * `kv`      — KV-cache slot management and batch-row packing
//!   * `engine`  — draft-then-verify decode loop (groups of sequences in
//!     lockstep), exact rejection sampling via `spec::sampling`, vanilla
//!     autoregressive baseline
//!   * `batcher` — request admission / bucket selection / slot assignment
//!   * `router`  — thread-backed front-end with bounded queues and
//!     backpressure
//!   * `metrics` — engine + per-request counters, Prometheus-style text

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod router;

pub use engine::{EngineOpts, RequestResult, SpecEngine};
pub use router::{Router, RouterConfig};
