//! SSE event and chunked-transfer framing — the pinned wire grammar of
//! the streaming edge (DESIGN.md §10).
//!
//! Every byte this module emits is covered by golden fixtures (here and
//! in `tests/http_edge.rs`): CRLF line endings throughout, event ids
//! monotonically increasing from 0, one `data:` line per event. A
//! refactor that changes the framing fails a byte-equality assertion,
//! not a prose review.

/// Server-Sent Events encoder with monotonically increasing event ids.
///
/// Emits exactly `id: N\r\nevent: E\r\ndata: D\r\n\r\n` per event. Ids
/// start at 0 and never repeat within a stream, so a client can detect
/// dropped events and tests can pin ordering.
pub struct SseEncoder {
    next_id: u64,
}

impl SseEncoder {
    pub fn new() -> SseEncoder {
        SseEncoder { next_id: 0 }
    }

    /// Frame one event. `data` must be a single line (JSON here is
    /// always single-line); a newline would split the SSE data field.
    pub fn event(&mut self, event: &str, data: &str) -> Vec<u8> {
        debug_assert!(
            !data.contains(['\r', '\n']),
            "SSE data must be single-line"
        );
        let id = self.next_id;
        self.next_id += 1;
        format!("id: {id}\r\nevent: {event}\r\ndata: {data}\r\n\r\n").into_bytes()
    }
}

impl Default for SseEncoder {
    fn default() -> Self {
        SseEncoder::new()
    }
}

/// Frame `payload` as one HTTP/1.1 chunk: lowercase-hex size, CRLF,
/// payload, CRLF.
pub fn chunk(payload: &[u8]) -> Vec<u8> {
    let mut out = format!("{:x}\r\n", payload.len()).into_bytes();
    out.extend_from_slice(payload);
    out.extend_from_slice(b"\r\n");
    out
}

/// The terminal zero-length chunk that ends a chunked response body.
pub const LAST_CHUNK: &[u8] = b"0\r\n\r\n";

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden bytes: the exact frames a client sees, ids from 0.
    #[test]
    fn golden_event_frames() {
        let mut enc = SseEncoder::new();
        assert_eq!(
            enc.event("queued", "{}"),
            b"id: 0\r\nevent: queued\r\ndata: {}\r\n\r\n"
        );
        assert_eq!(
            enc.event("token", "{\"tokens\": [7]}"),
            b"id: 1\r\nevent: token\r\ndata: {\"tokens\": [7]}\r\n\r\n"
        );
    }

    #[test]
    fn golden_chunk_framing() {
        // 5 payload bytes -> "5\r\nhello\r\n"; sizes are lowercase hex.
        assert_eq!(chunk(b"hello"), b"5\r\nhello\r\n");
        assert_eq!(chunk(&[0u8; 26]), {
            let mut want = b"1a\r\n".to_vec();
            want.extend_from_slice(&[0u8; 26]);
            want.extend_from_slice(b"\r\n");
            want
        });
        assert_eq!(LAST_CHUNK, b"0\r\n\r\n");
    }

    /// No bare LF anywhere in a frame: every `\n` is preceded by `\r`.
    #[test]
    fn crlf_only() {
        let mut enc = SseEncoder::new();
        let frame = enc.event("done", "{\"n\": 1}");
        for (i, b) in frame.iter().enumerate() {
            if *b == b'\n' {
                assert_eq!(frame[i - 1], b'\r', "bare LF at offset {i}");
            }
        }
    }
}
