//! Incremental HTTP/1.1 request parser for the streaming edge.
//!
//! TCP delivers arbitrary framings — a request head may arrive
//! byte-at-a-time, glued to its body, or torn anywhere in between — so
//! the parser accumulates bytes across [`RequestParser::feed`] calls
//! and yields the request only once it is complete. Feeding the same
//! byte stream under ANY split sequence produces the identical parse or
//! the identical error (`prop_http_parser_split_invariant` pins this).
//!
//! Limits surface as typed errors that map straight onto status codes
//! ([`ParseError::http_status`]): an oversized head is 431, an
//! oversized declared body 413, anything malformed 400 — never a
//! panic. The grammar is deliberately strict: CRLF line endings only
//! (a bare LF or CR is malformed), no obsolete line folding, no
//! whitespace before the header colon (request-smuggling vectors), and
//! request bodies must be `Content-Length`-delimited — this server
//! never accepts chunked REQUEST bodies (responses are another matter:
//! the SSE stream is chunked on the way out).

use std::fmt;

/// Parser limits. The head cap bounds memory per connection BEFORE any
/// request is accepted; the body cap bounds it after.
#[derive(Clone, Copy, Debug)]
pub struct ParseLimits {
    /// Max bytes of request line + headers, terminator included.
    pub max_head_bytes: usize,
    /// Max declared `Content-Length`.
    pub max_body_bytes: usize,
    /// Max number of header fields.
    pub max_headers: usize,
}

impl Default for ParseLimits {
    fn default() -> Self {
        ParseLimits {
            max_head_bytes: 8192,
            max_body_bytes: 1 << 20,
            max_headers: 64,
        }
    }
}

/// Why a request failed to parse; maps onto a status code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// 400 Bad Request.
    Malformed(String),
    /// 431 Request Header Fields Too Large.
    HeadersTooLarge,
    /// 413 Payload Too Large (declared `Content-Length` over the cap).
    BodyTooLarge,
}

impl ParseError {
    pub fn http_status(&self) -> u16 {
        match self {
            ParseError::Malformed(_) => 400,
            ParseError::HeadersTooLarge => 431,
            ParseError::BodyTooLarge => 413,
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::Malformed(why) => write!(f, "malformed request: {why}"),
            ParseError::HeadersTooLarge => write!(f, "request head too large"),
            ParseError::BodyTooLarge => write!(f, "request body too large"),
        }
    }
}

impl std::error::Error for ParseError {}

/// One parsed request. Header names are lowercased; values keep their
/// case with surrounding spaces/tabs trimmed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    /// (name, value) pairs in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header named `name` (give it lowercased), if any.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Parsed head awaiting its body.
struct Head {
    req: HttpRequest,
    body_start: usize,
    content_length: usize,
}

/// Incremental parser: feed it byte slices as they arrive.
pub struct RequestParser {
    limits: ParseLimits,
    buf: Vec<u8>,
    head: Option<Head>,
    /// Head-terminator scan resumes here (keeps feed O(new bytes)).
    scanned: usize,
    /// Errors are sticky; completion is terminal for THIS parser (one
    /// request per parser). Keep-alive connections read [`Self::residual`]
    /// after completion and seed a fresh parser with it.
    failed: Option<ParseError>,
    done: bool,
    /// Offset just past the completed request's body (valid once `done`).
    body_end: usize,
}

impl RequestParser {
    pub fn new(limits: ParseLimits) -> RequestParser {
        RequestParser {
            limits,
            buf: Vec::new(),
            head: None,
            scanned: 0,
            failed: None,
            done: false,
            body_end: 0,
        }
    }

    /// Accumulate `bytes`; returns the request once complete, `None`
    /// while more bytes are needed. Errors are sticky: every later call
    /// returns the same error the stream first produced.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Option<HttpRequest>, ParseError> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        if self.done {
            return Err(self.fail(ParseError::Malformed(
                "fed past a complete request (seed a fresh parser with residual())".into(),
            )));
        }
        self.buf.extend_from_slice(bytes);
        if self.head.is_none() {
            match self.find_head_end() {
                Some(end) => {
                    if end > self.limits.max_head_bytes {
                        return Err(self.fail(ParseError::HeadersTooLarge));
                    }
                    match parse_head(&self.limits, &self.buf[..end - 4]) {
                        Ok((req, content_length)) => {
                            self.head = Some(Head {
                                req,
                                body_start: end,
                                content_length,
                            });
                        }
                        Err(e) => return Err(self.fail(e)),
                    }
                }
                None => {
                    // No terminator yet: a head already over the cap can
                    // only grow — fail now, identically under any split.
                    if self.buf.len() > self.limits.max_head_bytes {
                        return Err(self.fail(ParseError::HeadersTooLarge));
                    }
                    return Ok(None);
                }
            }
        }
        let head = self.head.as_ref().expect("head parsed above");
        if self.buf.len() < head.body_start + head.content_length {
            return Ok(None);
        }
        let head = self.head.take().expect("head parsed above");
        let mut req = head.req;
        req.body = self.buf[head.body_start..head.body_start + head.content_length].to_vec();
        self.body_end = head.body_start + head.content_length;
        self.done = true;
        Ok(Some(req))
    }

    /// Bytes received past the completed request — the start of the
    /// next request on a keep-alive connection (TCP reads tear on
    /// arbitrary boundaries, so the final read of one request may carry
    /// the head of the next). Empty until `feed` yields a request; the
    /// connection loop seeds the NEXT parser with these bytes instead
    /// of feeding this one further.
    pub fn residual(&self) -> &[u8] {
        if self.done {
            &self.buf[self.body_end..]
        } else {
            &[]
        }
    }

    fn fail(&mut self, e: ParseError) -> ParseError {
        self.failed = Some(e.clone());
        e
    }

    /// Byte offset just past the first `\r\n\r\n`, if present.
    fn find_head_end(&mut self) -> Option<usize> {
        let from = self.scanned.saturating_sub(3);
        if let Some(i) = self.buf[from..]
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
        {
            return Some(from + i + 4);
        }
        self.scanned = self.buf.len();
        None
    }
}

/// Parse the head section (everything before the `\r\n\r\n`); returns
/// the request (body empty) and its declared content length.
fn parse_head(
    limits: &ParseLimits,
    head: &[u8],
) -> Result<(HttpRequest, usize), ParseError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| ParseError::Malformed("non-UTF-8 request head".into()))?;
    let mut lines = Vec::new();
    for line in split_crlf(text)? {
        lines.push(line);
    }
    let mut it = lines.into_iter();
    let request_line = it
        .next()
        .ok_or_else(|| ParseError::Malformed("empty request head".into()))?;
    let (method, target) = parse_request_line(request_line)?;
    let mut headers: Vec<(String, String)> = Vec::new();
    let mut content_length: Option<usize> = None;
    for line in it {
        if line.starts_with(' ') || line.starts_with('\t') {
            return Err(ParseError::Malformed(
                "obsolete line folding in headers".into(),
            ));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| ParseError::Malformed(format!("header without colon: {line:?}")))?;
        if name.is_empty() || name.contains(|c: char| c.is_ascii_whitespace()) {
            // Whitespace in a field name is a classic smuggling vector.
            return Err(ParseError::Malformed(format!(
                "invalid header field name: {name:?}"
            )));
        }
        let name = name.to_ascii_lowercase();
        let value = value.trim_matches([' ', '\t']).to_string();
        if name == "content-length" {
            let n: u64 = value
                .parse()
                .map_err(|_| ParseError::Malformed(format!("bad content-length: {value:?}")))?;
            if n > limits.max_body_bytes as u64 {
                return Err(ParseError::BodyTooLarge);
            }
            let n = n as usize;
            if content_length.replace(n).is_some_and(|prev| prev != n) {
                return Err(ParseError::Malformed(
                    "conflicting content-length headers".into(),
                ));
            }
        }
        if name == "transfer-encoding" {
            return Err(ParseError::Malformed(
                "chunked request bodies unsupported (use content-length)".into(),
            ));
        }
        headers.push((name, value));
        if headers.len() > limits.max_headers {
            return Err(ParseError::HeadersTooLarge);
        }
    }
    Ok((
        HttpRequest {
            method,
            target,
            headers,
            body: Vec::new(),
        },
        content_length.unwrap_or(0),
    ))
}

/// Split on CRLF exactly, rejecting any stray CR or LF — the CRLF
/// framing is part of the pinned wire grammar.
fn split_crlf(text: &str) -> Result<Vec<&str>, ParseError> {
    let mut out = Vec::new();
    for line in text.split("\r\n") {
        if line.contains(['\r', '\n']) {
            return Err(ParseError::Malformed(
                "bare CR or LF in request head (CRLF required)".into(),
            ));
        }
        if line.contains(|c: char| c.is_ascii_control()) {
            return Err(ParseError::Malformed(
                "control bytes in request head".into(),
            ));
        }
        out.push(line);
    }
    Ok(out)
}

fn parse_request_line(line: &str) -> Result<(String, String), ParseError> {
    let parts: Vec<&str> = line.split(' ').collect();
    let [method, target, version] = parts.as_slice() else {
        return Err(ParseError::Malformed(format!(
            "bad request line: {line:?}"
        )));
    };
    if *version != "HTTP/1.1" {
        return Err(ParseError::Malformed(format!(
            "unsupported protocol version: {version:?}"
        )));
    }
    if method.is_empty() || !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(ParseError::Malformed(format!("bad method: {method:?}")));
    }
    if !(target.starts_with('/') || *target == "*") {
        return Err(ParseError::Malformed(format!(
            "bad request target: {target:?}"
        )));
    }
    Ok((method.to_string(), target.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_whole(raw: &[u8]) -> Result<Option<HttpRequest>, ParseError> {
        RequestParser::new(ParseLimits::default()).feed(raw)
    }

    const GET: &[u8] = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
    const POST: &[u8] =
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\": 1}\n";

    #[test]
    fn parses_get_and_post() {
        let get = parse_whole(GET).unwrap().unwrap();
        assert_eq!(get.method, "GET");
        assert_eq!(get.target, "/healthz");
        assert_eq!(get.header("host"), Some("x"));
        assert!(get.body.is_empty());
        let post = parse_whole(POST).unwrap().unwrap();
        assert_eq!(post.method, "POST");
        assert_eq!(post.body, b"{\"a\": 1}\n");
    }

    /// The torn-read contract in miniature (the full property lives in
    /// tests/properties.rs): byte-at-a-time equals whole-buffer.
    #[test]
    fn byte_at_a_time_matches_whole() {
        for raw in [GET, POST] {
            let whole = parse_whole(raw).unwrap().unwrap();
            let mut p = RequestParser::new(ParseLimits::default());
            let mut torn = None;
            for b in raw {
                if let Some(req) = p.feed(std::slice::from_ref(b)).unwrap() {
                    torn = Some(req);
                }
            }
            assert_eq!(torn.as_ref(), Some(&whole));
        }
    }

    #[test]
    fn bare_lf_is_malformed() {
        let raw = b"GET / HTTP/1.1\nHost: x\r\n\r\n";
        match parse_whole(raw) {
            Err(ParseError::Malformed(why)) => assert!(why.contains("CRLF"), "got: {why}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn rejects_smuggling_shapes() {
        // Whitespace before the colon.
        let raw = b"GET / HTTP/1.1\r\nHost : x\r\n\r\n";
        assert_eq!(parse_whole(raw).unwrap_err().http_status(), 400);
        // Obsolete line folding.
        let raw = b"GET / HTTP/1.1\r\nHost: x\r\n folded\r\n\r\n";
        assert_eq!(parse_whole(raw).unwrap_err().http_status(), 400);
        // Conflicting content lengths.
        let raw = b"GET / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\n";
        assert_eq!(parse_whole(raw).unwrap_err().http_status(), 400);
        // Chunked request body.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse_whole(raw).unwrap_err().http_status(), 400);
    }

    #[test]
    fn oversized_head_is_431_under_any_split() {
        let mut raw = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        raw.resize(raw.len() + 9000, b'a');
        raw.extend_from_slice(b"\r\n\r\n");
        assert_eq!(parse_whole(&raw), Err(ParseError::HeadersTooLarge));
        let mut p = RequestParser::new(ParseLimits::default());
        let mut torn = Ok(None);
        for b in &raw {
            torn = p.feed(std::slice::from_ref(b));
            if torn.is_err() {
                break;
            }
        }
        assert_eq!(torn, Err(ParseError::HeadersTooLarge));
    }

    #[test]
    fn oversized_declared_body_is_413() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n";
        assert_eq!(parse_whole(raw), Err(ParseError::BodyTooLarge));
        assert_eq!(ParseError::BodyTooLarge.http_status(), 413);
    }

    /// Two requests glued into one read: the first parses, the second
    /// rides out through `residual()` into a fresh parser — the
    /// keep-alive loop's contract.
    #[test]
    fn residual_carries_the_next_request() {
        let mut glued = GET.to_vec();
        glued.extend_from_slice(POST);
        let mut p = RequestParser::new(ParseLimits::default());
        let first = p.feed(&glued).unwrap().unwrap();
        assert_eq!(first.target, "/healthz");
        let mut p2 = RequestParser::new(ParseLimits::default());
        let second = p2.feed(p.residual()).unwrap().unwrap();
        assert_eq!(second.target, "/v1/generate");
        assert_eq!(second.body, b"{\"a\": 1}\n");
        assert!(p2.residual().is_empty());
    }

    #[test]
    fn errors_are_sticky() {
        let mut p = RequestParser::new(ParseLimits::default());
        assert!(p.feed(b"NOT A REQUEST\r\n\r\n").is_err());
        assert_eq!(
            p.feed(GET).unwrap_err().http_status(),
            400,
            "a poisoned parser must keep refusing"
        );
    }
}
