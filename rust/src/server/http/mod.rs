//! HTTP/1.1 streaming edge over the router (DESIGN.md §10).
//!
//! Dependency-light by construction: `std::net::TcpListener`, one
//! thread per connection, no async runtime — the decode loop is the
//! concurrency-critical path and it already lives on the router's
//! worker thread; the edge only shuttles bytes. Three routes:
//!
//!   * `POST /v1/generate` — token streaming as Server-Sent Events over
//!     chunked transfer (`"stream": false` for a one-shot JSON body);
//!   * `GET /healthz` — 200 serving / 503 draining;
//!   * `GET /metrics` — `lkspec_http_*` edge gauges plus the
//!     scheduler's `lkspec_sched_*` block fetched from the worker.
//!
//! Admission verdicts map onto status codes via
//! [`RequestError::http_status`](super::fault::RequestError::http_status):
//! queue-full is 429 with `Retry-After`, an inadmissible request 413,
//! drain 503, a deadline miss 504. Connections over `max_conns` are
//! refused with an immediate 503 — the accept loop never queues work it
//! cannot serve. Connections are persistent (HTTP/1.1 keep-alive):
//! sequential requests reuse the socket — and its `max_conns` slot —
//! until the client closes, sends `Connection: close`, idles past
//! `read_timeout`, or finishes an SSE stream. Every edge behavior here
//! is pinned PJRT-free by
//! `tests/http_edge.rs` over [`SimCore`](super::scheduler::SimCore) and
//! loopback TCP.

mod conn;
pub mod parse;
pub mod sse;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::HttpMetrics;
use super::router::Router;
use conn::GaugeGuard;

/// Edge knobs (`serve --http` flags).
#[derive(Clone, Copy, Debug)]
pub struct HttpOpts {
    /// Open-connection cap; excess connections get an immediate 503.
    pub max_conns: usize,
    /// Max tokens coalesced into one SSE `token` event when the client
    /// lags the decode loop.
    pub stream_buffer: usize,
    /// `max_new` when the request body doesn't set one.
    pub default_max_new: usize,
    /// Socket read timeout while a request head/body arrives.
    pub read_timeout: Duration,
}

impl Default for HttpOpts {
    fn default() -> Self {
        HttpOpts {
            max_conns: 64,
            stream_buffer: 32,
            default_max_new: 32,
            read_timeout: Duration::from_secs(5),
        }
    }
}

/// State every connection thread sees.
struct Shared {
    router: Arc<Router>,
    metrics: HttpMetrics,
    opts: HttpOpts,
    /// Once set, `/v1/generate` refuses with 503 and `/healthz` flips —
    /// in-flight streams keep running to completion.
    draining: AtomicBool,
}

/// The listening edge. Bind with [`HttpServer::spawn`], stop with
/// [`HttpServer::shutdown`] (drain → stop accepting → bounded wait for
/// open streams).
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use port 0 to let the OS pick — [`HttpServer::addr`]
    /// reports the real one) and start accepting.
    pub fn spawn(addr: &str, router: Arc<Router>, opts: HttpOpts) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http edge on {addr}"))?;
        let local = listener
            .local_addr()
            .context("resolving bound http address")?;
        let shared = Arc::new(Shared {
            router,
            metrics: HttpMetrics::default(),
            opts,
            draining: AtomicBool::new(false),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("lkspec-http-accept".into())
                .spawn(move || accept_loop(listener, shared, stop))
                .context("spawning http accept thread")?
        };
        Ok(HttpServer {
            addr: local,
            shared,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Edge gauges, for scraping in-process (benches); HTTP clients use
    /// `GET /metrics`.
    pub fn metrics(&self) -> &HttpMetrics {
        &self.shared.metrics
    }

    /// Begin graceful drain: `/healthz` flips to 503 so load balancers
    /// stop routing here, new generate requests are refused with 503,
    /// the router drains (accepted work decodes to completion).
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.router.drain();
    }

    /// Drain, stop accepting, and wait (bounded) for open connections
    /// to finish their streams.
    pub fn shutdown(mut self) {
        self.drain();
        self.stop_accepting();
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.shared.metrics.conns.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    fn stop_accepting(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // The accept loop blocks in accept(2); a self-connection wakes
        // it so it can observe the stop flag and exit.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_accepting();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, stop: Arc<AtomicBool>) {
    for incoming in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match incoming {
            Ok(s) => s,
            Err(_) => continue, // transient accept error (EMFILE, reset)
        };
        shared.metrics.conns_total.fetch_add(1, Ordering::Relaxed);
        // Claim a slot BEFORE spawning so the cap can't be raced past.
        let open = shared.metrics.conns.fetch_add(1, Ordering::Relaxed) + 1;
        if open > shared.opts.max_conns as u64 {
            shared.metrics.conns.fetch_sub(1, Ordering::Relaxed);
            shared.metrics.sheds.fetch_add(1, Ordering::Relaxed);
            conn::refuse_overloaded(stream);
            continue;
        }
        let per_conn = Arc::clone(&shared);
        let spawned = std::thread::Builder::new()
            .name("lkspec-http-conn".into())
            .spawn(move || {
                let _open = GaugeGuard::adopt(&per_conn.metrics.conns);
                conn::handle(stream, &per_conn);
            });
        if spawned.is_err() {
            shared.metrics.conns.fetch_sub(1, Ordering::Relaxed);
            shared.metrics.sheds.fetch_add(1, Ordering::Relaxed);
        }
    }
}
