//! Per-connection handling: parse requests, route them, answer —
//! sequentially reusing the connection (HTTP/1.1 keep-alive).
//!
//! A connection serves requests one at a time in a loop: each request
//! gets a fresh parser seeded with whatever bytes the previous read
//! pulled past its request's body, so torn reads and glued ("pipelined")
//! requests both work. The connection holds its `--max-conns` slot for
//! its whole lifetime — reuse is sequential, never concurrent — and
//! closes on parse errors (the stream framing is unrecoverable), a
//! client `Connection: close`, the idle read timeout, or after an SSE
//! stream (which still answers `Connection: close`). The interesting
//! path is the streaming one. `POST /v1/generate` with `"stream": true`
//! (the default) maps the router's event grammar onto the wire:
//!
//!   * the FIRST event decides the status line — a pre-admission
//!     `Fault` becomes a plain 4xx/5xx response (the client never sees
//!     SSE), `Queued` opens a chunked `text/event-stream`;
//!   * each `Tokens` delta becomes an `event: token` SSE frame
//!     (coalesced up to `stream_buffer` tokens when the client lags);
//!   * the terminal `Done`/`Fault` becomes `event: done` (carrying the
//!     [`AcceptanceStats`] summary) or `event: fault`, then the
//!     zero-length chunk ends the response.
//!
//! Between events the handler probes the socket for client departure: a
//! read returning 0 means the peer closed, so the session is cancelled
//! through the router — its slot and paged-KV blocks free instead of
//! decoding for nobody (pinned by `disconnect_cancels_session` in
//! tests/http_edge.rs).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use crate::server::engine::RequestResult;
use crate::server::fault::RequestError;
use crate::server::router::{Event, StreamSubmission};
use crate::spec::accept::AcceptanceStats;
use crate::util::Json;

use super::parse::{HttpRequest, ParseLimits, RequestParser};
use super::sse::{chunk, SseEncoder, LAST_CHUNK};
use super::Shared;

/// How long the edge waits for the router's admission answer before
/// declaring the worker wedged.
const FIRST_EVENT_TIMEOUT: Duration = Duration::from_secs(30);
/// Event-wait slice between client-liveness probes while streaming.
const EVENT_POLL: Duration = Duration::from_millis(20);

/// Increments an [`AtomicU64`] gauge and decrements it on drop — keeps
/// `conns`/`queue_depth` honest across every early-return path.
pub(super) struct GaugeGuard<'a>(&'a AtomicU64);

impl<'a> GaugeGuard<'a> {
    pub(super) fn inc(gauge: &'a AtomicU64) -> GaugeGuard<'a> {
        gauge.fetch_add(1, Ordering::Relaxed);
        GaugeGuard(gauge)
    }

    /// Wrap a gauge the caller already incremented (the accept loop
    /// claims a conn slot before spawning the handler thread).
    pub(super) fn adopt(gauge: &'a AtomicU64) -> GaugeGuard<'a> {
        GaugeGuard(gauge)
    }
}

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Serve one connection end-to-end: sequential requests until the
/// client closes, asks to close, goes idle past the read timeout, or a
/// request ends the reuse (parse failure — the framing is
/// unrecoverable — or an SSE stream). Any parse failure answers with
/// the error's status ([`super::parse::ParseError::http_status`]) and
/// closes; a vanished client just closes.
pub(super) fn handle(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let mut residual: Vec<u8> = Vec::new();
    loop {
        // Re-arm per request: streaming shrinks the timeout for its
        // liveness probes, and the full window doubles as the
        // keep-alive idle budget between requests.
        let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
        let mut parser = RequestParser::new(ParseLimits::default());
        // The previous read may have pulled bytes past its request's
        // body; they are the start of THIS request.
        let mut seed = Some(std::mem::take(&mut residual));
        let req = loop {
            let fed = match seed.take() {
                Some(bytes) => parser.feed(&bytes),
                None => {
                    let mut buf = [0u8; 4096];
                    let n = match stream.read(&mut buf) {
                        Ok(0) => return, // peer closed between/inside requests
                        Ok(n) => n,
                        Err(_) => return, // idle timeout or reset: nobody to answer
                    };
                    parser.feed(&buf[..n])
                }
            };
            match fed {
                Ok(Some(req)) => break req,
                Ok(None) => {}
                Err(e) => {
                    shared.metrics.sheds.fetch_add(1, Ordering::Relaxed);
                    let body = error_body(&e.to_string());
                    let _ = stream.write_all(&simple_response(
                        e.http_status(),
                        "application/json",
                        &body,
                        &[],
                        false,
                    ));
                    return;
                }
            }
        };
        residual = parser.residual().to_vec();
        // RFC 9112 connection option: any `close` token ends reuse
        // after this response.
        let client_close = req
            .header("connection")
            .is_some_and(|v| v.split(',').any(|t| t.trim().eq_ignore_ascii_case("close")));
        if !route(&mut stream, shared, &req, !client_close) {
            return;
        }
    }
}

/// Refuse a connection over the `max_conns` cap without spawning a
/// handler thread for it (the caller counts the shed).
pub(super) fn refuse_overloaded(mut stream: TcpStream) {
    let body = error_body("server at max connections");
    let _ = stream.write_all(&simple_response(
        503,
        "application/json",
        &body,
        &[("Retry-After", "1")],
        false,
    ));
}

/// Dispatch one request; returns whether the connection stays reusable
/// (`allow_keep` ANDed with the route's own verdict — SSE streams and
/// wedged-worker responses close).
fn route(stream: &mut TcpStream, shared: &Shared, req: &HttpRequest, allow_keep: bool) -> bool {
    let path = req.target.split('?').next().unwrap_or(&req.target);
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(stream, shared, allow_keep),
        ("GET", "/metrics") => metrics(stream, shared, allow_keep),
        ("POST", "/v1/generate") => generate(stream, shared, req, allow_keep),
        _ => {
            let body = error_body(&format!("no route {} {}", req.method, path));
            let _ = stream.write_all(&simple_response(
                404,
                "application/json",
                &body,
                &[],
                allow_keep,
            ));
            allow_keep
        }
    }
}

/// Liveness for load balancers: 200 while serving, 503 once draining —
/// flip first, then stop sending traffic, then shut down.
fn healthz(stream: &mut TcpStream, shared: &Shared, keep: bool) -> bool {
    let (status, body) = if shared.draining.load(Ordering::SeqCst) {
        (503, "{\"status\": \"draining\"}")
    } else {
        (200, "{\"status\": \"ok\"}")
    };
    let _ = stream.write_all(&simple_response(status, "application/json", body, &[], keep));
    keep
}

/// Edge gauges (`lkspec_http_*`) plus the scheduler's own counters
/// fetched from the worker thread; if the worker is wedged the edge
/// block still renders, annotated with the probe failure.
fn metrics(stream: &mut TcpStream, shared: &Shared, keep: bool) -> bool {
    let mut text = shared.metrics.render();
    match shared.router.metrics_text(Duration::from_secs(2)) {
        Ok(sched) => text.push_str(&sched),
        Err(e) => text.push_str(&format!("# scheduler metrics unavailable: {e:#}\n")),
    }
    let _ = stream.write_all(&simple_response(
        200,
        "text/plain; version=0.0.4",
        &text,
        &[],
        keep,
    ));
    keep
}

struct GenerateReq {
    prompt: Vec<i32>,
    max_new: Option<usize>,
    stream: bool,
    deadline_ms: Option<f64>,
}

fn parse_body(raw: &[u8]) -> Result<GenerateReq, String> {
    let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("body is not JSON: {e}"))?;
    let arr = json
        .get("prompt")
        .as_arr()
        .ok_or_else(|| "missing 'prompt' (array of token ids)".to_string())?;
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let id = v
            .as_i64()
            .ok_or_else(|| "'prompt' must contain integer token ids".to_string())?;
        prompt.push(id as i32);
    }
    Ok(GenerateReq {
        prompt,
        max_new: json.get("max_new").as_usize(),
        stream: json.get("stream").as_bool().unwrap_or(true),
        deadline_ms: json.get("deadline_ms").as_f64(),
    })
}

fn generate(stream: &mut TcpStream, shared: &Shared, req: &HttpRequest, keep: bool) -> bool {
    shared.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
    if shared.draining.load(Ordering::SeqCst) {
        // Answer the drain refusal at the edge and close: in-flight
        // streams keep running, new work never reaches the router, and
        // a draining server should not hold idle keep-alive slots.
        shed(stream, shared, 503, "draining: not accepting new requests", &[], false);
        return false;
    }
    let body = match parse_body(&req.body) {
        Ok(b) => b,
        Err(why) => {
            shed(stream, shared, 400, &why, &[], keep);
            return keep;
        }
    };
    let max_new = body.max_new.unwrap_or(shared.opts.default_max_new);
    let deadline = body
        .deadline_ms
        .map(|ms| Instant::now() + Duration::from_millis(ms as u64));
    if body.stream {
        generate_stream(stream, shared, body.prompt, max_new, deadline, keep)
    } else {
        generate_oneshot(stream, shared, body.prompt, max_new, deadline, keep)
    }
}

fn generate_oneshot(
    stream: &mut TcpStream,
    shared: &Shared,
    prompt: Vec<i32>,
    max_new: usize,
    deadline: Option<Instant>,
    keep: bool,
) -> bool {
    let sub = match shared.router.submit_with(prompt, max_new, deadline) {
        Ok(s) => s,
        Err(e) => {
            shed(stream, shared, 503, &format!("{e:#}"), &[], keep);
            return keep;
        }
    };
    let _depth = GaugeGuard::inc(&shared.metrics.queue_depth);
    match sub.rx.recv() {
        Ok(Ok(res)) => {
            let body = result_json(&res).to_string();
            let _ = stream.write_all(&simple_response(200, "application/json", &body, &[], keep));
            keep
        }
        Ok(Err(err)) => {
            respond_verdict(stream, shared, &err, keep);
            keep
        }
        Err(_) => {
            shed(stream, shared, 500, "router worker vanished", &[], false);
            false
        }
    }
}

fn generate_stream(
    stream: &mut TcpStream,
    shared: &Shared,
    prompt: Vec<i32>,
    max_new: usize,
    deadline: Option<Instant>,
    keep: bool,
) -> bool {
    let sub = match shared.router.submit_stream(prompt, max_new, deadline) {
        Ok(s) => s,
        Err(e) => {
            shed(stream, shared, 503, &format!("{e:#}"), &[], keep);
            return keep;
        }
    };
    // The first event decides the status line: a refusal must be a
    // plain error response, not a 200 stream that immediately faults.
    match sub.rx.recv_timeout(FIRST_EVENT_TIMEOUT) {
        Ok(Event::Queued) => {}
        Ok(Event::Fault(err)) => {
            respond_verdict(stream, shared, &err, keep);
            return keep;
        }
        Ok(Event::Tokens(_)) | Ok(Event::Done(_)) => {
            // `Queued` always precedes tokens; reaching here is a bug.
            shed(stream, shared, 500, "event stream violated its grammar", &[], false);
            return false;
        }
        Err(_) => {
            shed(stream, shared, 500, "router worker did not answer", &[], false);
            return false;
        }
    }
    let _depth = GaugeGuard::inc(&shared.metrics.queue_depth);
    // The SSE response is `Connection: close` by design: its liveness
    // probes consume the socket, so reuse after a stream is unsound.
    stream_events(stream, shared, &sub);
    false
}

fn stream_events(stream: &mut TcpStream, shared: &Shared, sub: &StreamSubmission) {
    const HEAD: &str = "HTTP/1.1 200 OK\r\n\
                        Content-Type: text/event-stream\r\n\
                        Cache-Control: no-cache\r\n\
                        Connection: close\r\n\
                        Transfer-Encoding: chunked\r\n\r\n";
    let mut enc = SseEncoder::new();
    let mut head = HEAD.as_bytes().to_vec();
    head.extend_from_slice(&chunk(&enc.event("queued", "{}")));
    if stream.write_all(&head).is_err() {
        disconnect(shared, sub);
        return;
    }
    // The request is fully read; shrink the read timeout so liveness
    // probes between events cost ~1ms instead of blocking.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(1)));
    let started = Instant::now();
    let mut last_token_at: Option<Instant> = None;
    let mut carry: Option<Event> = None;
    loop {
        let ev = match carry.take() {
            Some(ev) => ev,
            None => match sub.rx.recv_timeout(EVENT_POLL) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    if client_gone(stream) {
                        disconnect(shared, sub);
                        return;
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    Event::Fault(RequestError::EngineFault("router worker vanished".into()))
                }
            },
        };
        match ev {
            Event::Queued => {} // only ever first; already announced
            Event::Tokens(mut toks) => {
                // Coalesce queued deltas so a lagging client gets fewer,
                // bigger frames instead of one chunk per scheduler tick.
                while toks.len() < shared.opts.stream_buffer {
                    match sub.rx.try_recv() {
                        Ok(Event::Tokens(more)) => toks.extend(more),
                        Ok(other) => {
                            carry = Some(other);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                let now = Instant::now();
                match last_token_at {
                    None => shared.metrics.observe_ttft(ms_between(started, now)),
                    Some(prev) => shared.metrics.observe_inter_token(ms_between(prev, now)),
                }
                last_token_at = Some(now);
                let data = Json::obj(vec![("tokens", arr_i32(&toks))]).to_string();
                if stream.write_all(&chunk(&enc.event("token", &data))).is_err() {
                    disconnect(shared, sub);
                    return;
                }
            }
            Event::Done(res) => {
                let mut tail = chunk(&enc.event("done", &done_json(&res).to_string()));
                tail.extend_from_slice(LAST_CHUNK);
                let _ = stream.write_all(&tail);
                return;
            }
            Event::Fault(err) => {
                let data = Json::obj(vec![
                    ("error", Json::Str(err.to_string())),
                    ("status", Json::Num(f64::from(err.http_status()))),
                ])
                .to_string();
                let mut tail = chunk(&enc.event("fault", &data));
                tail.extend_from_slice(LAST_CHUNK);
                let _ = stream.write_all(&tail);
                return;
            }
        }
    }
}

/// A vanished client must cancel its session: probe with a short read.
/// `Ok(0)` is an orderly close; stray request bytes are ignored
/// (pipelining is unsupported); timeouts mean "still there".
fn client_gone(stream: &mut TcpStream) -> bool {
    let mut scratch = [0u8; 64];
    match stream.read(&mut scratch) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::Interrupted
        ),
    }
}

fn disconnect(shared: &Shared, sub: &StreamSubmission) {
    shared.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
    let _ = shared.router.cancel(sub.id);
}

/// Answer a request verdict as a status code; 429 tells clients when to
/// retry. Every non-200 verdict counts as an edge shed.
fn respond_verdict(stream: &mut TcpStream, shared: &Shared, err: &RequestError, keep: bool) {
    shared.metrics.sheds.fetch_add(1, Ordering::Relaxed);
    let retry: &[(&str, &str)] = if matches!(err, RequestError::QueueFull) {
        &[("Retry-After", "1")]
    } else {
        &[]
    };
    let body = error_body(&err.to_string());
    let _ = stream.write_all(&simple_response(
        err.http_status(),
        "application/json",
        &body,
        retry,
        keep,
    ));
}

fn shed(
    stream: &mut TcpStream,
    shared: &Shared,
    status: u16,
    why: &str,
    extra: &[(&str, &str)],
    keep: bool,
) {
    shared.metrics.sheds.fetch_add(1, Ordering::Relaxed);
    let body = error_body(why);
    let _ = stream.write_all(&simple_response(status, "application/json", &body, extra, keep));
}

/// A `Content-Length`-delimited response. `keep` decides the
/// `Connection` header — the length framing is what makes sequential
/// reuse sound (the client knows exactly where this response ends).
fn simple_response(
    status: u16,
    content_type: &str,
    body: &str,
    extra: &[(&str, &str)],
    keep: bool,
) -> Vec<u8> {
    let conn = if keep { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
        reason(status),
        body.len(),
    );
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body.as_bytes());
    out
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

fn error_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

/// One-shot response body: the full result, tokens included.
fn result_json(res: &RequestResult) -> Json {
    let mut fields = vec![("tokens", arr_i32(&res.tokens))];
    fields.extend(summary_fields(res));
    Json::obj(fields)
}

/// `event: done` data: the result summary WITHOUT the token array — the
/// tokens already streamed as deltas (their concatenation equals the
/// one-shot `tokens` field exactly).
fn done_json(res: &RequestResult) -> Json {
    Json::obj(summary_fields(res))
}

fn summary_fields(res: &RequestResult) -> Vec<(&'static str, Json)> {
    vec![
        ("n_tokens", Json::Num(res.tokens.len() as f64)),
        ("rounds", Json::Num(res.rounds as f64)),
        ("latency_ms", Json::Num(res.latency_ms)),
        ("ttft_ms", Json::Num(res.ttft_ms)),
        ("queue_ms", Json::Num(res.queue_ms)),
        ("stats", stats_json(&res.stats)),
    ]
}

fn stats_json(s: &AcceptanceStats) -> Json {
    Json::obj(vec![
        ("k", Json::Num(s.k as f64)),
        ("rounds", Json::Num(s.rounds as f64)),
        ("generated_tokens", Json::Num(s.generated_tokens as f64)),
        ("tau", Json::Num(s.tau())),
        ("drafted", arr_u64(&s.drafted)),
        ("accepted", arr_u64(&s.accepted)),
        ("prefix_hist", arr_u64(&s.prefix_hist)),
    ])
}

fn arr_i32(xs: &[i32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(f64::from(x))).collect())
}

fn arr_u64(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn ms_between(from: Instant, to: Instant) -> f64 {
    to.duration_since(from).as_secs_f64() * 1e3
}
