//! Continuous-batching scheduler: decode groups as slot-mapped sessions.
//!
//! The old serving path ran lockstep groups to completion: finished rows
//! kept burning verify FLOPs as padding and queued requests waited out
//! the whole group. The scheduler instead owns one active decode group
//! whose rows are tracked by a `kv::SlotMap`:
//!
//!   * when a sequence finishes, its result is returned IMMEDIATELY and
//!     its row slot is freed mid-flight;
//!   * when a slot is free and requests are queued, the next request is
//!     admitted into the running group — a per-row prefill at the
//!     smallest bucket plus a one-row KV copy (`kv::copy_row`) into the
//!     group's packed caches;
//!   * group formation (cold start) still follows the `Batcher` policy:
//!     dispatch on a full bucket or when the oldest request exceeds
//!     `max_wait`.
//!
//! Because per-request RNG streams are keyed by stable request ids,
//! a session's sample path and acceptance statistics are identical
//! whether it runs lockstep or joins a group mid-flight — the property
//! the tests below pin down with the PJRT-free `SimCore`.
//!
//! The engine side of the contract is the `SchedulerCore` trait,
//! implemented by `SpecEngine` (real XLA decode) and by `SimCore` (a
//! deterministic simulation used by unit tests and benches).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::spec::accept::AcceptanceStats;
use crate::util::Pcg64;

use super::batcher::{Batcher, BatcherConfig};
use super::engine::{request_rng, RequestResult};
use super::kv::SlotMap;
use super::metrics::SchedulerMetrics;

/// An admitted request: what a core needs to bootstrap a session.
#[derive(Clone, Debug)]
pub struct AdmitReq {
    /// Stable request id; keys the RNG stream.
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Submission time (queue wait + latency are measured from here).
    pub enqueued: Instant,
}

/// What the scheduler needs from a decode engine. One group is a batch
/// of rows decoding together; rows are independent sessions.
pub trait SchedulerCore {
    type Group;

    /// Executable batch capacity chosen for `n` initial requests.
    fn bucket(&self, n: usize) -> usize;

    /// Prefill + draft-bootstrap a fresh group sized `bucket(reqs.len())`
    /// with `reqs` occupying rows 0..reqs.len().
    fn bootstrap(&mut self, reqs: &[AdmitReq]) -> Result<Self::Group>;

    /// Admit one request into free row `row` of a running group.
    fn join(&mut self, g: &mut Self::Group, row: usize, req: &AdmitReq) -> Result<()>;

    /// One draft-verify-accept round over all rows.
    fn round(&mut self, g: &mut Self::Group) -> Result<()>;

    fn row_done(&self, g: &Self::Group, row: usize) -> bool;

    /// Harvest the finished row's result; the row becomes inert padding
    /// until a join replaces it.
    fn take_result(&mut self, g: &mut Self::Group, row: usize) -> RequestResult;
}

struct Active<G> {
    group: G,
    slots: SlotMap,
    /// Rounds since the last session finished (stuck detection).
    rounds_since_finish: u64,
    stuck_cap: u64,
}

/// Session scheduler over one `SchedulerCore`.
pub struct Scheduler<C: SchedulerCore> {
    core: C,
    batcher: Batcher<AdmitReq>,
    active: Option<Active<C::Group>>,
    next_id: u64,
    pub metrics: SchedulerMetrics,
}

impl<C: SchedulerCore> Scheduler<C> {
    pub fn new(core: C, cfg: BatcherConfig) -> Scheduler<C> {
        Scheduler {
            core,
            batcher: Batcher::new(cfg),
            active: None,
            next_id: 0,
            metrics: SchedulerMetrics::default(),
        }
    }

    pub fn core(&self) -> &C {
        &self.core
    }

    pub fn core_mut(&mut self) -> &mut C {
        &mut self.core
    }

    /// Queue a request; returns its id, or the prompt back when the
    /// queue is full (backpressure).
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> std::result::Result<u64, Vec<i32>> {
        let id = self.next_id;
        let req = AdmitReq {
            id,
            prompt,
            max_new,
            enqueued: Instant::now(),
        };
        match self.batcher.push(req) {
            Ok(()) => {
                self.next_id += 1;
                Ok(id)
            }
            Err(req) => Err(req.prompt),
        }
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Sessions currently decoding.
    pub fn in_flight(&self) -> usize {
        self.active.as_ref().map_or(0, |a| a.slots.occupied())
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.batcher.is_empty()
    }

    /// Drop the active group and the queue (engine-fault recovery).
    pub fn reset(&mut self) {
        self.active = None;
        let n = self.batcher.len();
        let _ = self.batcher.take(n);
    }

    /// One scheduling step: admit (form a group, or join free slots of
    /// the running one), run one decode round, harvest finished rows.
    /// Returns (request id, result) for every session that completed.
    pub fn tick(&mut self, now: Instant) -> Result<Vec<(u64, RequestResult)>> {
        let mut finished = Vec::new();

        // --- admission ------------------------------------------------
        if self.active.is_none() {
            if let Some(mut reqs) = self.batcher.next_group(now) {
                self.metrics.note_started();
                let b = self.core.bucket(reqs.len());
                // The batcher's buckets and the core's lowered buckets
                // are independent configs: if the popped group exceeds
                // the core's capacity, the tail goes back to the front
                // of the queue (it will join as slots free up).
                if reqs.len() > b {
                    for req in reqs.drain(b..).rev() {
                        self.batcher.requeue_front(req);
                    }
                }
                let mut slots = SlotMap::new(b);
                let mut cap = 0u64;
                for r in &reqs {
                    slots.alloc(r.id).expect("fresh slot map full");
                    cap = cap.max(4 * r.max_new as u64 + 32);
                }
                let group = self.core.bootstrap(&reqs)?;
                self.metrics.groups_formed += 1;
                self.metrics.sessions_admitted += reqs.len() as u64;
                self.active = Some(Active {
                    group,
                    slots,
                    rounds_since_finish: 0,
                    stuck_cap: cap,
                });
            }
        } else {
            // Continuous join: a free slot should never idle while
            // requests wait — no batching delay on this path.
            let active = self.active.as_mut().unwrap();
            let free = active.slots.capacity() - active.slots.occupied();
            if free > 0 {
                for req in self.batcher.take(free) {
                    let row = active.slots.alloc(req.id).expect("free slot disappeared");
                    self.core.join(&mut active.group, row, &req)?;
                    active.stuck_cap = active.stuck_cap.max(4 * req.max_new as u64 + 32);
                    self.metrics.joins += 1;
                    self.metrics.sessions_admitted += 1;
                }
            }
        }

        // --- one decode round + harvest -------------------------------
        let mut retire = false;
        if let Some(active) = self.active.as_mut() {
            self.core.round(&mut active.group)?;
            self.metrics.rounds += 1;
            self.metrics
                .slot_occupancy
                .push(active.slots.occupied() as f64 / active.slots.capacity() as f64);

            let mut done_rows: Vec<(usize, u64)> = Vec::new();
            for (row, id) in active.slots.iter_occupied() {
                if self.core.row_done(&active.group, row) {
                    done_rows.push((row, id));
                }
            }
            active.rounds_since_finish += 1;
            if !done_rows.is_empty() {
                active.rounds_since_finish = 0;
            }
            for (row, id) in done_rows {
                let res = self.core.take_result(&mut active.group, row);
                active.slots.free(id);
                self.metrics.observe_session(&res);
                finished.push((id, res));
            }
            if active.rounds_since_finish > active.stuck_cap {
                bail!(
                    "scheduler stuck: {} rounds without a session finishing",
                    active.rounds_since_finish
                );
            }
            retire = active.slots.occupied() == 0;
        }
        if retire {
            self.active = None;
            self.metrics.groups_retired += 1;
        }
        Ok(finished)
    }
}

// ---------------------------------------------------------------------------
// SimCore: deterministic PJRT-free core for tests and benches
// ---------------------------------------------------------------------------

/// A simulated decode core: per-request RNG streams keyed by request id
/// drive random accepted-prefix lengths, so a session's statistics are a
/// pure function of (seed, id) — independent of batch composition,
/// admission order and join timing. Token j of a session echoes
/// `prompt[j % len] + 1000`. Used by the scheduler unit tests and the
/// hot-path bench; also handy for policy experiments without artifacts.
pub struct SimCore {
    pub k: usize,
    pub seed: u64,
    pub buckets: Vec<usize>,
}

pub struct SimGroup {
    rows: Vec<SimSeq>,
}

struct SimSeq {
    done: bool,
    rng: Pcg64,
    stats: AcceptanceStats,
    tokens: Vec<i32>,
    prompt: Vec<i32>,
    max_new: usize,
    rounds: u64,
    enqueued: Instant,
    queue_ms: f64,
    ttft_ms: f64,
    total_ms: f64,
}

impl SimCore {
    pub fn new(k: usize, seed: u64, buckets: Vec<usize>) -> SimCore {
        let mut buckets = buckets;
        buckets.sort_unstable();
        assert!(!buckets.is_empty());
        SimCore { k, seed, buckets }
    }

    fn seq_for(&self, req: &AdmitReq) -> SimSeq {
        let rng = request_rng(self.seed, req.id);
        let first = req.prompt[0] + 1000;
        SimSeq {
            done: false,
            rng,
            stats: AcceptanceStats::new(self.k),
            tokens: vec![first],
            prompt: req.prompt.clone(),
            max_new: req.max_new,
            rounds: 0,
            enqueued: req.enqueued,
            queue_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
            ttft_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
            total_ms: 0.0,
        }
    }

    fn pad_seq(&self) -> SimSeq {
        SimSeq {
            done: true,
            rng: Pcg64::new(self.seed, u64::MAX),
            stats: AcceptanceStats::new(self.k),
            tokens: Vec::new(),
            prompt: Vec::new(),
            max_new: 0,
            rounds: 0,
            enqueued: Instant::now(),
            queue_ms: 0.0,
            ttft_ms: 0.0,
            total_ms: 0.0,
        }
    }
}

impl SchedulerCore for SimCore {
    type Group = SimGroup;

    fn bucket(&self, n: usize) -> usize {
        *self
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    fn bootstrap(&mut self, reqs: &[AdmitReq]) -> Result<SimGroup> {
        let b = self.bucket(reqs.len());
        let rows = (0..b)
            .map(|row| {
                if row < reqs.len() {
                    self.seq_for(&reqs[row])
                } else {
                    self.pad_seq()
                }
            })
            .collect();
        Ok(SimGroup { rows })
    }

    fn join(&mut self, g: &mut SimGroup, row: usize, req: &AdmitReq) -> Result<()> {
        anyhow::ensure!(row < g.rows.len(), "join row out of range");
        g.rows[row] = self.seq_for(req);
        Ok(())
    }

    fn round(&mut self, g: &mut SimGroup) -> Result<()> {
        for seq in g.rows.iter_mut() {
            if seq.done {
                continue;
            }
            // Short final rounds: never draft past the generation cap.
            let remaining = seq.max_new.saturating_sub(seq.tokens.len()).max(1);
            let n_drafted = self.k.min(remaining);
            let n_acc = seq.rng.below(n_drafted + 1);
            seq.stats.record_round(n_drafted, n_acc);
            for _ in 0..n_acc + 1 {
                let j = seq.tokens.len();
                seq.tokens.push(seq.prompt[j % seq.prompt.len()] + 1000);
            }
            seq.rounds += 1;
            if seq.tokens.len() >= seq.max_new {
                seq.done = true;
                seq.total_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
            }
        }
        Ok(())
    }

    fn row_done(&self, g: &SimGroup, row: usize) -> bool {
        g.rows[row].done
    }

    fn take_result(&mut self, g: &mut SimGroup, row: usize) -> RequestResult {
        let seq = &mut g.rows[row];
        let mut tokens = seq.tokens.clone();
        tokens.truncate(seq.max_new);
        RequestResult {
            tokens,
            stats: seq.stats.clone(),
            latency_ms: seq.total_ms,
            ttft_ms: seq.ttft_ms,
            queue_ms: seq.queue_ms,
            rounds: seq.rounds,
        }
        // The row stays inert (done) padding until a join replaces it.
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn cfg(queue_cap: usize) -> BatcherConfig {
        BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::ZERO, // dispatch whatever is queued
            queue_cap,
        }
    }

    fn sim() -> SimCore {
        SimCore::new(4, 42, vec![1, 4])
    }

    /// Tick until idle, collecting results; panics if the scheduler
    /// fails to converge within `guard` ticks.
    fn drain(s: &mut Scheduler<SimCore>, guard: usize) -> Vec<(u64, RequestResult)> {
        let mut out = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            out.extend(s.tick(Instant::now()).unwrap());
            ticks += 1;
            assert!(ticks < guard, "scheduler did not converge");
        }
        out
    }

    /// THE tentpole behaviour: a queued request joins a running group
    /// mid-flight as soon as another sequence finishes — no new group is
    /// formed for it.
    #[test]
    fn queued_request_joins_mid_flight() {
        let mut s = Scheduler::new(sim(), cfg(64));
        // One short session plus three long ones fill the b=4 bucket.
        s.submit(vec![1, 2], 3).unwrap();
        for p in 0..3 {
            s.submit(vec![10 + p, 20 + p], 60).unwrap();
        }
        // Run until the short session finishes.
        let mut first_done = Vec::new();
        let mut ticks = 0;
        while first_done.is_empty() {
            first_done = s.tick(Instant::now()).unwrap();
            ticks += 1;
            assert!(ticks < 1000);
        }
        assert_eq!(first_done[0].0, 0, "short session should finish first");
        assert_eq!(s.metrics.groups_formed, 1);
        assert_eq!(s.metrics.joins, 0);
        // Queue a fifth request AFTER the group is already running.
        let late_id = s.submit(vec![9, 9, 9], 8).unwrap();
        assert_eq!(late_id, 4);
        assert!(s.in_flight() >= 1, "group must still be running");
        let rest = drain(&mut s, 10_000);
        // The late request was served by joining the running group, not
        // by forming a second one.
        assert_eq!(s.metrics.groups_formed, 1, "no new group for the join");
        assert_eq!(s.metrics.joins, 1);
        let ids: Vec<u64> = rest.iter().map(|(id, _)| *id).collect();
        assert!(ids.contains(&late_id));
        // All five sessions completed exactly once.
        let mut all: Vec<u64> = first_done.iter().chain(&rest).map(|(id, _)| *id).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    /// Per-position acceptance stats of the continuous path (join
    /// mid-flight) are IDENTICAL to the lockstep run-to-completion path
    /// for the same seeds/ids — the RNG stream is keyed by request id,
    /// not by group composition.
    #[test]
    fn continuous_stats_match_lockstep() {
        let caps = [5usize, 24, 24, 24, 10];
        // --- continuous path: 4 upfront, the 5th joins mid-flight ------
        let mut s = Scheduler::new(sim(), cfg(64));
        for (i, &m) in caps.iter().take(4).enumerate() {
            s.submit(vec![i as i32 + 1, 7], m).unwrap();
        }
        let mut got: BTreeMap<u64, RequestResult> = BTreeMap::new();
        let mut ticks = 0;
        while got.is_empty() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            ticks += 1;
            assert!(ticks < 1000);
        }
        s.submit(vec![5, 7], caps[4]).unwrap();
        for (id, r) in drain(&mut s, 10_000) {
            got.insert(id, r);
        }
        assert_eq!(got.len(), 5);
        assert!(s.metrics.joins >= 1);

        // --- lockstep reference: drive the core directly ---------------
        let mut core = sim();
        let now = Instant::now();
        let reqs: Vec<AdmitReq> = caps
            .iter()
            .take(4)
            .enumerate()
            .map(|(i, &m)| AdmitReq {
                id: i as u64,
                prompt: vec![i as i32 + 1, 7],
                max_new: m,
                enqueued: now,
            })
            .collect();
        let mut g = core.bootstrap(&reqs).unwrap();
        for _ in 0..1000 {
            if (0..4).all(|r| core.row_done(&g, r)) {
                break;
            }
            core.round(&mut g).unwrap();
        }
        let mut reference: BTreeMap<u64, RequestResult> = (0..4)
            .map(|r| (r as u64, core.take_result(&mut g, r)))
            .collect();
        let late = AdmitReq {
            id: 4,
            prompt: vec![5, 7],
            max_new: caps[4],
            enqueued: now,
        };
        let mut g2 = core.bootstrap(std::slice::from_ref(&late)).unwrap();
        for _ in 0..1000 {
            if core.row_done(&g2, 0) {
                break;
            }
            core.round(&mut g2).unwrap();
        }
        reference.insert(4, core.take_result(&mut g2, 0));

        for id in 0..5u64 {
            let a = &got[&id];
            let b = &reference[&id];
            assert_eq!(a.tokens, b.tokens, "tokens diverge for id {id}");
            assert_eq!(a.stats.drafted, b.stats.drafted, "drafted[] for id {id}");
            assert_eq!(a.stats.accepted, b.stats.accepted, "accepted[] for id {id}");
            assert_eq!(
                a.stats.prefix_hist, b.stats.prefix_hist,
                "prefix histogram for id {id}"
            );
        }
    }

    /// Admission-order / batch-composition independence of the RNG
    /// seeding: all-upfront vs one-at-a-time give identical per-id
    /// results (the old `next_seed` counter failed exactly this).
    #[test]
    fn rng_streams_admission_order_independent() {
        let run = |staggered: bool| -> BTreeMap<u64, RequestResult> {
            let mut s = Scheduler::new(sim(), cfg(64));
            let mut got = BTreeMap::new();
            if staggered {
                for i in 0..5 {
                    s.submit(vec![i + 1, 3, 9], 12).unwrap();
                    for (id, r) in drain(&mut s, 10_000) {
                        got.insert(id, r);
                    }
                }
            } else {
                for i in 0..5 {
                    s.submit(vec![i + 1, 3, 9], 12).unwrap();
                }
                for (id, r) in drain(&mut s, 10_000) {
                    got.insert(id, r);
                }
            }
            got
        };
        let upfront = run(false);
        let one_by_one = run(true);
        assert_eq!(upfront.len(), 5);
        for id in 0..5u64 {
            assert_eq!(upfront[&id].tokens, one_by_one[&id].tokens, "id {id}");
            assert_eq!(
                upfront[&id].stats.accepted, one_by_one[&id].stats.accepted,
                "id {id}"
            );
        }
    }

    /// Batcher buckets and core buckets are independent configs: a
    /// popped group larger than the core's capacity must not silently
    /// drop the tail — it returns to the queue and joins later.
    #[test]
    fn oversized_group_requeues_tail() {
        let cfg = BatcherConfig {
            buckets: vec![1, 8], // batcher willing to pop 8 at once
            max_wait: Duration::ZERO,
            queue_cap: 64,
        };
        let mut s = Scheduler::new(sim(), cfg); // core caps groups at 4
        for i in 0..8 {
            s.submit(vec![i + 1, 2], 6).unwrap();
        }
        let out = drain(&mut s, 10_000);
        assert_eq!(out.len(), 8, "every session must complete");
        let mut ids: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        // The tail was served through joins/new groups, never dropped.
        assert!(s.metrics.joins > 0 || s.metrics.groups_formed > 1);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut s = Scheduler::new(sim(), cfg(2));
        s.submit(vec![1, 2], 4).unwrap();
        s.submit(vec![3, 4], 4).unwrap();
        let rejected = s.submit(vec![5, 6], 4);
        assert_eq!(rejected, Err(vec![5, 6]));
        // The queue drains normally afterwards.
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn metrics_track_occupancy_and_waits() {
        let mut s = Scheduler::new(sim(), cfg(64));
        for i in 0..4 {
            s.submit(vec![i + 1, 2], 8).unwrap();
        }
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 4);
        assert_eq!(s.metrics.sessions, 4);
        assert!(s.metrics.rounds > 0);
        assert!(s.metrics.slot_occupancy.n > 0);
        assert!(s.metrics.slot_occupancy.mean() > 0.0);
        assert!(s.metrics.tokens_out >= 4 * 8);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_slot_occupancy_mean"));
        assert!(text.contains("lkspec_sched_tokens_per_second"));
    }
}
