//! Continuous-batching scheduler: decode groups as slot-mapped sessions.
//!
//! The old serving path ran lockstep groups to completion: finished rows
//! kept burning verify FLOPs as padding and queued requests waited out
//! the whole group. The scheduler instead owns one active decode group
//! whose rows are tracked by a `kv::SlotMap`:
//!
//!   * when a sequence finishes, its result is returned IMMEDIATELY and
//!     its row slot is freed mid-flight;
//!   * when a slot is free and requests are queued, the next request is
//!     admitted into the running group — a per-row prefill at the
//!     smallest bucket plus a one-row KV copy (`kv::copy_row`) into the
//!     group's packed caches;
//!   * group formation (cold start) still follows the `Batcher` policy:
//!     dispatch on a full bucket or when the oldest request exceeds
//!     `max_wait`;
//!   * LONG-TAIL DOWNSHIFT: when a group's occupancy has fitted a
//!     smaller lowered bucket for [`DownshiftConfig::after_rounds`]
//!     consecutive rounds with nothing queued, the live rows migrate
//!     into a fresh smaller-bucket group (`SchedulerCore::migrate`) —
//!     ending the padding verify FLOPs the retired rows were burning.
//!     Queued requests veto the shift (a free slot is about to be
//!     joined, not wasted), which also settles the migrate-vs-join race
//!     on the same slot: admission runs first in every tick. The
//!     mirror UPSHIFT re-grows a full group when requests queue behind
//!     it, so an arrival after a shift never waits out the tail.
//!   * PAGED-KV ADMISSION (optional, [`Scheduler::with_paged_kv`]):
//!     before any prefill, a session reserves fixed-size cache blocks
//!     from a `kv::BlockPool` for its uncached prompt suffix and full
//!     generation budget; a refcounted radix prefix cache shares
//!     identical system-prompt blocks between sessions, and a session
//!     whose reservation cannot be met even after LRU eviction is
//!     load-shed back to the queue front — reservation is
//!     all-or-nothing, so a live block table is never corrupted by
//!     allocation failure. See DESIGN.md §8.
//!
//! Because per-request RNG streams are keyed by stable request ids,
//! a session's sample path and acceptance statistics are identical
//! whether it runs lockstep or joins a group mid-flight — the property
//! the tests below pin down with the PJRT-free `SimCore`. (With the
//! engine's stochastic speculation controller ENABLED the per-round
//! budget is shared group state, so this equivalence is guaranteed for
//! fixed budgets and for greedy decoding — see the engine header and
//! DESIGN.md §4a; migration itself never touches a session's stream,
//! which the downshift tests pin at fixed budgets.)
//!
//! FAULT CONTAINMENT (DESIGN.md §9): a core round error is classified
//! by the typed [`EngineError`] it carries — a transient fault retries
//! the round with bounded backoff (rounds are atomic on failure), a
//! session-fatal fault evicts ONLY the offending row (slot + paged-KV
//! blocks freed, typed verdict recorded), and only an engine-fatal
//! fault propagates out of `tick` to the router's reset path. The same
//! eviction machinery serves per-request DEADLINES and CANCELLATION
//! (queued requests are shed before any prefill or block reservation is
//! spent on them), and a graceful [`Scheduler::drain`] finishes
//! accepted work while refusing new submits. Every containment claim
//! is pinned PJRT-free by [`SimCore`]'s [`FaultPlan`] injection harness
//! (ChaosCore) in the tests below.
//!
//! The engine side of the contract is the `SchedulerCore` trait,
//! implemented by `SpecEngine` (real XLA decode) and by `SimCore` (a
//! deterministic simulation used by unit tests and benches).

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::spec::accept::AcceptanceStats;
use crate::spec::adaptive::PrefillArbiter;
use crate::util::Pcg64;

use super::adapt::{harvest_row, AdaptConfig, AdaptDriver, ReplaySink, TrainerChaos};
use super::batcher::{Batcher, BatcherConfig};
use super::engine::{request_rng, RequestResult};
use super::fault::{EngineError, FaultKind, RequestError};
use super::kv::{PagedKv, PagedKvConfig, SlotMap};
use super::metrics::SchedulerMetrics;

/// An admitted request: what a core needs to bootstrap a session.
#[derive(Clone, Debug)]
pub struct AdmitReq {
    /// Stable request id; keys the RNG stream.
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Submission time (queue wait + latency are measured from here).
    pub enqueued: Instant,
    /// Absolute deadline: past it the request is shed (queued or
    /// mid-flight) with a typed `DeadlineExceeded` verdict.
    pub deadline: Option<Instant>,
}

/// What the scheduler needs from a decode engine. One group is a batch
/// of rows decoding together; rows are independent sessions.
pub trait SchedulerCore {
    type Group;

    /// Executable batch capacity chosen for `n` initial requests.
    fn bucket(&self, n: usize) -> usize;

    /// Prefill + draft-bootstrap a fresh group sized `bucket(reqs.len())`
    /// with `reqs` occupying rows 0..reqs.len().
    fn bootstrap(&mut self, reqs: &[AdmitReq]) -> Result<Self::Group>;

    /// Admit one request into free row `row` of a running group.
    fn join(&mut self, g: &mut Self::Group, row: usize, req: &AdmitReq) -> Result<()>;

    /// One draft-verify-accept round over all rows.
    fn round(&mut self, g: &mut Self::Group) -> Result<()>;

    fn row_done(&self, g: &Self::Group, row: usize) -> bool;

    /// Harvest the finished row's result; the row becomes inert padding
    /// until a join replaces it.
    fn take_result(&mut self, g: &mut Self::Group, row: usize) -> RequestResult;

    /// Committed tokens of live row `row` so far — the prefix of what
    /// [`SchedulerCore::take_result`] will eventually return. The
    /// sequence must be APPEND-ONLY across rounds (accepted tokens are
    /// committed, never rolled back), because per-token streaming emits
    /// deltas against it. `None` — the default — means the core cannot
    /// observe mid-flight progress; streaming then degrades to one
    /// terminal burst at harvest, and nothing else changes.
    fn row_tokens(&self, _g: &Self::Group, _row: usize) -> Option<&[i32]> {
        None
    }

    /// Bucket migration (long-tail downshift, or an upshift when
    /// arrivals outgrow a shrunk group): repack the listed live rows
    /// into a fresh group at lowered bucket `b_new` — row `i` of the
    /// new group hosts old row `rows[i]` with its session state (and
    /// RNG stream) intact, so migrated sessions' sample paths are
    /// untouched. The old group is dropped by the scheduler on return.
    fn migrate(&mut self, g: &mut Self::Group, rows: &[usize], b_new: usize)
        -> Result<Self::Group>;

    /// Validate a request's shape BEFORE it is queued. The default
    /// rejects empty prompts (no core can bootstrap them); cores with
    /// tighter contracts (the engine's lowered prompt window) override
    /// it, so a malformed request fails ITSELF at submit time instead
    /// of surfacing later as a group-level engine fault.
    fn validate(&self, prompt: &[i32], _max_new: usize) -> std::result::Result<(), String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        Ok(())
    }

    /// Discard row `row`'s session mid-flight (session-fatal fault,
    /// deadline expiry, cancellation): the row becomes inert padding —
    /// exactly like a harvested row — and its partial output is
    /// dropped. Must leave every OTHER row's state and RNG stream
    /// untouched.
    fn evict(&mut self, g: &mut Self::Group, row: usize);

    /// Chunked-prefill support (DESIGN.md §11): the fixed chunk length
    /// this core lowers. `None` — the default — means unsupported, and
    /// the scheduler prefills whole prompts inside `join`.
    fn prefill_chunk_len(&self) -> Option<usize> {
        None
    }

    /// A budget arbiter sized from this core's OWN cost model (the same
    /// one its speculation controller plans K with), capped at
    /// `max_chunks_per_round` chunks per tick. `None` when chunked
    /// prefill is unsupported. The router calls this so operators
    /// configure one number (`--prefill-budget`) and the verify-vs-
    /// prefill exchange rate stays consistent with the engine's.
    fn prefill_arbiter(&self, _max_chunks_per_round: usize) -> Option<PrefillArbiter> {
        None
    }

    /// Begin a chunked prefill for `req` on free row `row`. `skip` is
    /// the chunk-aligned token count the scheduler AUTHORIZES the core
    /// to skip (a cached prefix whose compute need not rerun); the
    /// return value is the count actually skipped (≤ `skip` — a core
    /// without the cached carry resident recomputes it). The row is
    /// not live yet: it emits no tokens and must read as not-done until
    /// [`SchedulerCore::prefill_step`] reports completion. On `Err` the
    /// row is left (or put back) inert — the same contract as a failed
    /// `join`, so only the joining request fails.
    fn prefill_begin(
        &mut self,
        _g: &mut Self::Group,
        _row: usize,
        _req: &AdmitReq,
        _skip: usize,
    ) -> Result<usize> {
        bail!("core does not support chunked prefill")
    }

    /// Advance row `row`'s pending prefill by one chunk. Returns true
    /// once the prompt is fully prefilled and the row is LIVE: first
    /// token sampled from the final chunk's logits, decode-ready on the
    /// next round.
    fn prefill_step(&mut self, _g: &mut Self::Group, _row: usize) -> Result<bool> {
        bail!("core does not support chunked prefill")
    }

    /// Online-adaptation harvest (DESIGN.md §12): attach the replay
    /// ring this core should push per-slot verdict records into. The
    /// default — no harvest — is correct for cores without an
    /// adaptation loop; harvesting cores push via
    /// [`adapt::harvest_row`](super::adapt::harvest_row) at verdict
    /// time on every decode path.
    fn attach_replay(&mut self, _sink: super::adapt::ReplaySink) {}

    /// Hot-swap the draft model's weights from a fine-tuned checkpoint
    /// at a round boundary — validate-then-commit: the core must fully
    /// load AND validate `ckpt` before replacing its live weights, and
    /// on ANY error leave the old weights serving (rollback is simply
    /// not swapping). Never affects the exactness contract: draft
    /// weights change what is PROPOSED, never the accept/resample rule.
    fn swap_draft(&mut self, ckpt: &std::path::Path) -> Result<()> {
        bail!("core does not support draft hot-swap ({})", ckpt.display())
    }
}

/// Transient-fault retry policy (see DESIGN.md §9): how many times a
/// round that failed with a [`FaultKind::Transient`] fault is retried
/// before the fault escalates to engine-fatal, and the linear backoff
/// between attempts (attempt `n` sleeps `n × backoff`).
#[derive(Clone, Copy, Debug)]
pub struct FaultConfig {
    pub transient_retries: u32,
    pub backoff: Duration,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            transient_retries: 3,
            backoff: Duration::from_millis(2),
        }
    }
}

/// Long-tail downshift policy.
#[derive(Clone, Copy, Debug)]
pub struct DownshiftConfig {
    pub enabled: bool,
    /// Consecutive qualifying rounds (occupancy fits a smaller bucket,
    /// queue empty) before the group migrates.
    pub after_rounds: u64,
}

impl Default for DownshiftConfig {
    fn default() -> Self {
        DownshiftConfig {
            enabled: true,
            after_rounds: 4,
        }
    }
}

struct Active<G> {
    group: G,
    slots: SlotMap,
    /// Rounds since the last session finished (stuck detection).
    rounds_since_finish: u64,
    stuck_cap: u64,
    /// Consecutive rounds the group qualified for a downshift.
    shrink_rounds: u64,
}

/// Why `Scheduler::submit` refused a request. All are PER-REQUEST
/// verdicts: the scheduler and every other session keep running.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is full (backpressure); the prompt is handed back so
    /// the caller can retry later.
    QueueFull(Vec<i32>),
    /// The request's worst-case KV footprint exceeds the whole paged
    /// block pool — it can NEVER be admitted, at any load.
    TooLarge {
        blocks_needed: usize,
        pool_blocks: usize,
    },
    /// The core refused the request's shape (`SchedulerCore::validate`):
    /// it could never bootstrap, so it fails here rather than poisoning
    /// a whole group later.
    Invalid { reason: String },
    /// The scheduler is draining (graceful shutdown): accepted work is
    /// being finished, new work is refused.
    Draining,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(_) => write!(f, "queue full (backpressure)"),
            SubmitError::TooLarge {
                blocks_needed,
                pool_blocks,
            } => write!(
                f,
                "request needs {blocks_needed} KV blocks but the pool holds \
                 {pool_blocks} (raise --kv-blocks or shrink the prompt/max_new)"
            ),
            SubmitError::Invalid { reason } => write!(f, "invalid request: {reason}"),
            SubmitError::Draining => write!(f, "scheduler draining (graceful shutdown)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Session scheduler over one `SchedulerCore`.
pub struct Scheduler<C: SchedulerCore> {
    core: C,
    batcher: Batcher<AdmitReq>,
    active: Option<Active<C::Group>>,
    next_id: u64,
    downshift: DownshiftConfig,
    /// Optional paged-KV admission gate (block pool + radix prefix
    /// cache); None admits unconditionally (legacy dense accounting).
    paged: Option<PagedKv>,
    paged_cfg: Option<PagedKvConfig>,
    /// Chunked-prefill budget arbiter; None joins whole prompts only.
    arbiter: Option<PrefillArbiter>,
    /// Sessions mid-prefill: id → (row, remaining-chunk estimate). A
    /// prefilling row occupies its slot (its KV is being written) but
    /// is skipped by streaming, harvest, and bucket migration until the
    /// lane completes it.
    prefilling: HashMap<u64, (usize, usize)>,
    fault_cfg: FaultConfig,
    /// Graceful-drain state: refuse new submits, flush the queue,
    /// finish in-flight rows. `is_idle()` is the completion signal.
    draining: bool,
    /// Sessions with a cancel pending; consumed at the next tick.
    cancelled: HashSet<u64>,
    /// Deadline per live (queued or in-flight) session.
    deadlines: HashMap<u64, Instant>,
    /// Typed per-session verdicts accumulated since `take_failures`.
    failures: Vec<(u64, RequestError)>,
    /// Tokens already surfaced as stream events, per live session.
    streamed: HashMap<u64, usize>,
    /// Per-session token deltas accumulated since `take_token_events`.
    token_events: Vec<(u64, Vec<i32>)>,
    /// Online-adaptation driver (DESIGN.md §12): harvest → background
    /// fine-tune → hot-swap, stepped once per tick AFTER the decode
    /// round. None = no adaptation loop (the default).
    adapt: Option<AdaptDriver>,
    pub metrics: SchedulerMetrics,
}

impl<C: SchedulerCore> Scheduler<C> {
    pub fn new(core: C, cfg: BatcherConfig) -> Scheduler<C> {
        Scheduler::with_downshift(core, cfg, DownshiftConfig::default())
    }

    pub fn with_downshift(
        core: C,
        cfg: BatcherConfig,
        downshift: DownshiftConfig,
    ) -> Scheduler<C> {
        Scheduler {
            core,
            batcher: Batcher::new(cfg),
            active: None,
            next_id: 0,
            downshift,
            paged: None,
            paged_cfg: None,
            arbiter: None,
            prefilling: HashMap::new(),
            fault_cfg: FaultConfig::default(),
            draining: false,
            cancelled: HashSet::new(),
            deadlines: HashMap::new(),
            failures: Vec::new(),
            streamed: HashMap::new(),
            token_events: Vec::new(),
            adapt: None,
            metrics: SchedulerMetrics::default(),
        }
    }

    /// Override the transient-fault retry policy.
    pub fn with_fault_config(mut self, cfg: FaultConfig) -> Scheduler<C> {
        self.fault_cfg = cfg;
        self
    }

    /// Attach a paged-KV block pool with a radix prefix cache: every
    /// admission (group formation AND mid-flight join) must first
    /// reserve the session's worst-case block footprint — uncached
    /// prompt suffix plus its full `max_new` budget. A session whose
    /// reservation cannot be met even after LRU eviction is LOAD-SHED
    /// back to the queue front (original queue age preserved) rather
    /// than admitted into a pool that could OOM a live block table
    /// mid-decode. Scheduling decisions are otherwise unchanged, so
    /// emitted tokens and acceptance stats are identical with the pool
    /// on or off (`paged_admission_never_changes_tokens` pins this).
    pub fn with_paged_kv(mut self, cfg: PagedKvConfig) -> Scheduler<C> {
        self.paged = Some(PagedKv::new(cfg));
        self.paged_cfg = Some(cfg);
        self
    }

    /// Attach the chunked-prefill lane (DESIGN.md §11): a JOINING
    /// session whose prompt exceeds the core's chunk length enters a
    /// `Prefilling` row state and advances chunk-by-chunk between
    /// decode rounds, under the arbiter's per-round chunk budget —
    /// instead of stalling the whole group on one long whole-prompt
    /// prefill. Requires a core that reports `prefill_chunk_len`; cold
    /// bootstraps still prefill whole prompts (no decode cadence exists
    /// to protect yet).
    pub fn with_chunked_prefill(mut self, arbiter: PrefillArbiter) -> Scheduler<C> {
        self.arbiter = Some(arbiter);
        self
    }

    /// Attach the online-adaptation loop (DESIGN.md §12): the core
    /// harvests per-slot verdict records into the driver's replay ring,
    /// and every `interval_rounds` decode rounds the driver snapshots a
    /// transcript, runs a background LK fine-tune, and hot-swaps the
    /// draft weights through [`SchedulerCore::swap_draft`] at a round
    /// boundary. Serving semantics are unchanged by contract: draft
    /// weights steer what is PROPOSED, never the accept/resample rule,
    /// so greedy output stays the target's greedy path and stochastic
    /// output stays distribution-lossless across arbitrary swap
    /// boundaries (`tests/adapt_loop.rs` pins both).
    pub fn with_adaptation(mut self, cfg: AdaptConfig) -> Scheduler<C> {
        let driver = AdaptDriver::new(cfg);
        self.core.attach_replay(driver.buffer.clone());
        self.adapt = Some(driver);
        self
    }

    /// The adaptation driver, if attached (gauges + tests).
    pub fn adapt(&self) -> Option<&AdaptDriver> {
        self.adapt.as_ref()
    }

    /// Step the adaptation driver at the tick's round boundary. The
    /// take/put-back dance lets the driver borrow the core mutably for
    /// the hot-swap without aliasing `self`.
    fn step_adapt(&mut self, now: Instant) {
        if let Some(mut driver) = self.adapt.take() {
            driver.step(&mut self.core, self.metrics.rounds, now);
            self.adapt = Some(driver);
        }
    }

    /// The attached paged-KV pool, if any (gauges + tests).
    pub fn paged_kv(&self) -> Option<&PagedKv> {
        self.paged.as_ref()
    }

    /// Reserve `req`'s paged-KV footprint (no-op without a pool). The
    /// prefix-cache lookup happens here — BEFORE the core prefills —
    /// returning the cached prefix length; None = load-shed. Prefill
    /// COMPUTE accounting is the caller's job: whole-prompt prefill
    /// recomputes the cached prefix anyway (the hit saves only block
    /// capacity), while the chunked lane actually skips those chunks
    /// and credits `prefill_tokens_saved`.
    fn reserve_kv(paged: &mut Option<PagedKv>, req: &AdmitReq) -> Option<usize> {
        match paged.as_mut() {
            None => Some(0),
            Some(kv) => kv.admit(req.id, &req.prompt, req.max_new).ok(),
        }
    }

    pub fn core(&self) -> &C {
        &self.core
    }

    pub fn core_mut(&mut self) -> &mut C {
        &mut self.core
    }

    /// Queue a request; returns its id, or a `SubmitError` saying why
    /// it was refused. An oversized request — one whose worst-case KV
    /// footprint `blocks_for(prompt + max_new)` exceeds the WHOLE block
    /// pool — is rejected here, at submit time: sharing never shrinks a
    /// session's total footprint (shared blocks are still resident
    /// blocks), so it could never be admitted, and surfacing it from
    /// `tick` would read as an engine fault that aborts every
    /// concurrent session instead of just this one.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> std::result::Result<u64, SubmitError> {
        self.submit_with(prompt, max_new, None)
    }

    /// `submit` with an absolute deadline: past it the request is shed
    /// (queued or mid-flight) with a typed `DeadlineExceeded` verdict
    /// instead of being served late.
    pub fn submit_with(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> std::result::Result<u64, SubmitError> {
        if self.draining {
            return Err(SubmitError::Draining);
        }
        if let Err(reason) = self.core.validate(&prompt, max_new) {
            return Err(SubmitError::Invalid { reason });
        }
        if let Some(cfg) = &self.paged_cfg {
            let tokens = prompt.len().saturating_add(max_new);
            let need = tokens.saturating_add(cfg.block_size - 1) / cfg.block_size;
            if need > cfg.total_blocks {
                return Err(SubmitError::TooLarge {
                    blocks_needed: need,
                    pool_blocks: cfg.total_blocks,
                });
            }
        }
        let id = self.next_id;
        let req = AdmitReq {
            id,
            prompt,
            max_new,
            enqueued: Instant::now(),
            deadline,
        };
        match self.batcher.push(req) {
            Ok(()) => {
                self.next_id += 1;
                if let Some(d) = deadline {
                    self.deadlines.insert(id, d);
                }
                Ok(id)
            }
            Err(req) => Err(SubmitError::QueueFull(req.prompt)),
        }
    }

    /// Request cancellation of session `id` (queued or mid-flight).
    /// Takes effect on the next tick: a queued entry is shed before any
    /// group-formation work, an in-flight row is evicted and its slot +
    /// paged-KV blocks freed. Unknown or already-finished ids are a
    /// no-op.
    pub fn cancel(&mut self, id: u64) {
        self.cancelled.insert(id);
    }

    /// Enter the graceful-drain state: new submits are refused with
    /// [`SubmitError::Draining`], queued requests flush into groups
    /// without waiting out the batching window, in-flight rows run to
    /// completion. `is_idle()` doubles as the completion signal: once
    /// it returns true every accepted request has been answered (as a
    /// result or a typed failure).
    pub fn drain(&mut self) {
        self.draining = true;
        self.metrics.draining = true;
        // Cancel-on-drain: an in-flight fine-tune is advisory work — a
        // graceful shutdown kills the subprocess instead of waiting out
        // a training run. The ring and the serving weights are left as
        // they are.
        if let Some(driver) = self.adapt.as_mut() {
            driver.cancel();
        }
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Typed per-session verdicts recorded since the last call:
    /// session-fatal evictions, deadline expiries, cancellations. The
    /// router forwards these on the per-request reply channels.
    pub fn take_failures(&mut self) -> Vec<(u64, RequestError)> {
        std::mem::take(&mut self.failures)
    }

    /// Per-session token deltas committed since the last call — the
    /// streaming feed. Deltas for one session, concatenated in order,
    /// equal the session's terminal `RequestResult::tokens` EXACTLY
    /// (`stream_deltas_concat_to_result` pins this): mid-flight deltas
    /// come from [`SchedulerCore::row_tokens`], and harvest emits
    /// whatever tail the core had not yet surfaced. Sessions that end
    /// in a typed failure may have emitted deltas before the verdict;
    /// the failure discards them (same contract as the one-shot path,
    /// which drops partial output on failure).
    pub fn take_token_events(&mut self) -> Vec<(u64, Vec<i32>)> {
        std::mem::take(&mut self.token_events)
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Sessions currently decoding.
    pub fn in_flight(&self) -> usize {
        self.active.as_ref().map_or(0, |a| a.slots.occupied())
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.batcher.is_empty()
    }

    /// Drop the active group and the queue (engine-fault recovery).
    /// The paged pool is rebuilt from its config — every table and
    /// cache entry of the faulted engine is invalid — and pending
    /// cancel/deadline/failure state is discarded with the sessions it
    /// referred to.
    pub fn reset(&mut self) {
        self.active = None;
        let n = self.batcher.len();
        let _ = self.batcher.take(n);
        self.paged = self.paged_cfg.map(PagedKv::new);
        self.prefilling.clear();
        self.cancelled.clear();
        self.deadlines.clear();
        self.failures.clear();
        self.streamed.clear();
        self.token_events.clear();
        // An in-flight fine-tune was reading transcripts of the faulted
        // engine's sessions; kill it rather than swap weights trained
        // against state the reset just invalidated.
        if let Some(driver) = self.adapt.as_mut() {
            driver.cancel();
        }
        self.metrics.engine_resets += 1;
    }

    /// Shed cancelled / deadline-expired requests still in the queue —
    /// BEFORE any group formation, prefill, or paged-KV reservation is
    /// spent on them.
    fn shed_queued(&mut self, now: Instant) {
        if self.cancelled.is_empty() && self.deadlines.is_empty() {
            return;
        }
        let cancelled = &self.cancelled;
        let deadlines = &self.deadlines;
        let shed = self.batcher.drain_where(|r| {
            cancelled.contains(&r.id) || deadlines.get(&r.id).is_some_and(|&d| d <= now)
        });
        for req in shed {
            let verdict = if self.cancelled.contains(&req.id) {
                self.metrics.cancelled += 1;
                RequestError::Cancelled
            } else {
                self.metrics.deadline_expired_queued += 1;
                RequestError::DeadlineExceeded
            };
            self.deadlines.remove(&req.id);
            self.failures.push((req.id, verdict));
        }
    }

    /// Shed cancelled / deadline-expired rows mid-flight: evict the row
    /// (the core turns it into inert padding), free its slot and paged-
    /// KV blocks — the same release path a harvested row takes, so the
    /// freed capacity is reusable by the admission step that follows in
    /// the same tick.
    fn shed_inflight(&mut self, now: Instant) {
        if self.cancelled.is_empty() && self.deadlines.is_empty() {
            return;
        }
        let Some(active) = self.active.as_mut() else {
            return;
        };
        let doomed: Vec<(usize, u64)> = active
            .slots
            .iter_occupied()
            .filter(|(_, id)| {
                self.cancelled.contains(id)
                    || self.deadlines.get(id).is_some_and(|&d| d <= now)
            })
            .collect();
        for (row, id) in doomed {
            self.core.evict(&mut active.group, row);
            active.slots.free(id);
            self.prefilling.remove(&id);
            if let Some(kv) = self.paged.as_mut() {
                kv.release(id);
            }
            let verdict = if self.cancelled.contains(&id) {
                self.metrics.cancelled += 1;
                RequestError::Cancelled
            } else {
                self.metrics.deadline_expired_inflight += 1;
                RequestError::DeadlineExceeded
            };
            self.deadlines.remove(&id);
            self.streamed.remove(&id);
            self.failures.push((id, verdict));
        }
    }

    /// Advance pending chunked prefills under the arbiter's per-round
    /// chunk budget, shortest-remaining-first — a near-done prompt goes
    /// live (TTFT) before a longer one monopolizes the lane. The budget
    /// is a HARD bound: the decode round that follows in the same tick
    /// is never delayed by more than `max_chunks_per_round` chunks of
    /// prefill compute. A failing step evicts only the prefilling
    /// session (typed verdict, slot + paged blocks freed) unless the
    /// fault is engine-fatal.
    fn run_prefill_lane(&mut self) -> Result<()> {
        let Some(arb) = self.arbiter.as_ref() else {
            return Ok(());
        };
        if self.prefilling.is_empty() {
            return Ok(());
        }
        let Some(active) = self.active.as_mut() else {
            return Ok(());
        };
        // `.max(1)`: the per-session count is an ESTIMATE (the core may
        // take an extra step to finish); never let a zero estimate
        // starve a still-pending session out of the quota.
        let backlog: usize = self.prefilling.values().map(|&(_, n)| n.max(1)).sum();
        let mut quota = arb.chunks_for_round(self.batcher.len(), backlog);
        let mut order: Vec<(usize, u64, usize)> = self
            .prefilling
            .iter()
            .map(|(&id, &(row, n))| (n, id, row))
            .collect();
        order.sort_unstable();
        let mut ran = false;
        'lane: for (_, id, row) in order {
            while quota > 0 {
                match self.core.prefill_step(&mut active.group, row) {
                    Ok(done) => {
                        quota -= 1;
                        ran = true;
                        self.metrics.prefill_chunks += 1;
                        if done {
                            self.prefilling.remove(&id);
                            continue 'lane;
                        }
                        if let Some(e) = self.prefilling.get_mut(&id) {
                            e.1 = e.1.saturating_sub(1);
                        }
                    }
                    Err(e) => {
                        if EngineError::classify(&e) == FaultKind::EngineFatal {
                            return Err(e);
                        }
                        // Contained: only the half-prefilled session
                        // fails; every live row's state is untouched.
                        self.prefilling.remove(&id);
                        self.core.evict(&mut active.group, row);
                        active.slots.free(id);
                        if let Some(kv) = self.paged.as_mut() {
                            kv.release(id);
                        }
                        self.deadlines.remove(&id);
                        self.streamed.remove(&id);
                        self.metrics.session_faults += 1;
                        self.failures
                            .push((id, RequestError::SessionFault(format!("{e:#}"))));
                        continue 'lane;
                    }
                }
            }
            break;
        }
        if ran {
            self.metrics.prefill_lane_rounds += 1;
        }
        Ok(())
    }

    /// One scheduling step: shed expired/cancelled work, admit (form a
    /// group, or join free slots of the running one), run one decode
    /// round, harvest finished rows. Returns (request id, result) for
    /// every session that completed; typed failure verdicts accumulate
    /// in [`Scheduler::take_failures`].
    ///
    /// An `Err` from tick means ENGINE-FATAL: the engine itself is
    /// unrecoverable (typed `EngineFatal`, an untyped core error, or a
    /// transient fault that survived the whole retry budget) and the
    /// caller is expected to fail in-flight work and `reset`. Transient
    /// and session-fatal faults are contained here and never surface.
    pub fn tick(&mut self, now: Instant) -> Result<Vec<(u64, RequestResult)>> {
        let mut finished = Vec::new();

        // --- deadline / cancel shed -----------------------------------
        self.shed_queued(now);
        self.shed_inflight(now);
        // Every live match was processed; the rest are unknown or
        // already-finished ids (documented no-op).
        self.cancelled.clear();

        // --- admission ------------------------------------------------
        if self.active.is_none() {
            // Drain mode flushes the queue without waiting out the
            // batching window: the stragglers max_wait holds out for
            // will never arrive.
            let popped = if self.draining {
                self.batcher.flush_group()
            } else {
                self.batcher.next_group(now)
            };
            if let Some(mut reqs) = popped {
                self.metrics.note_started();
                let b = self.core.bucket(reqs.len());
                // The batcher's buckets and the core's lowered buckets
                // are independent configs: if the popped group exceeds
                // the core's capacity, the tail goes back to the front
                // of the queue (it will join as slots free up) with its
                // original queue age intact.
                if reqs.len() > b {
                    for req in reqs.drain(b..).rev() {
                        let at = req.enqueued;
                        self.batcher.requeue_front_at(req, at);
                    }
                }
                // Paged-KV admission: each session reserves its block
                // footprint in FIFO order; the first shed returns itself
                // and everything behind it to the queue front, and a
                // partial group still forms from the admitted head.
                let mut shed_at = reqs.len();
                for (i, r) in reqs.iter().enumerate() {
                    if Self::reserve_kv(&mut self.paged, r).is_none() {
                        shed_at = i;
                        break;
                    }
                    // Cold bootstrap prefills the whole prompt: every
                    // token's compute runs, cache hit or not.
                    self.metrics.prefill_tokens += r.prompt.len() as u64;
                }
                if shed_at < reqs.len() {
                    for req in reqs.drain(shed_at..).rev() {
                        let at = req.enqueued;
                        self.batcher.requeue_front_at(req, at);
                    }
                    // A shed with NO live reservation can never succeed:
                    // the request alone outsizes the pool. `submit`
                    // already rejects such requests (`SubmitError::
                    // TooLarge`), so this is a backstop invariant — a
                    // queued request that trips it means the admission
                    // accounting itself is broken.
                    if let Some(kv) = self.paged.as_ref() {
                        anyhow::ensure!(
                            shed_at > 0 || kv.sessions() > 0,
                            "request needs more KV blocks than the pool holds \
                             (raise --kv-blocks or shrink the prompt/max_new)"
                        );
                    }
                }
                if reqs.is_empty() {
                    self.metrics.observe_occupancy(0.0, now);
                    self.metrics.idle_ticks += 1;
                } else {
                    let b = self.core.bucket(reqs.len());
                    // Invariant, not a request-reachable panic: the slot
                    // map was sized `bucket(reqs.len()) >= reqs.len()`
                    // one line up.
                    let mut slots = SlotMap::new(b);
                    let mut cap = 0u64;
                    for r in &reqs {
                        slots.alloc(r.id).expect("fresh slot map full");
                        cap = cap.max(4 * r.max_new as u64 + 32);
                    }
                    match self.core.bootstrap(&reqs) {
                        Ok(group) => {
                            self.metrics.groups_formed += 1;
                            self.metrics.sessions_admitted += reqs.len() as u64;
                            self.active = Some(Active {
                                group,
                                slots,
                                rounds_since_finish: 0,
                                stuck_cap: cap,
                                shrink_rounds: 0,
                            });
                        }
                        Err(e) => {
                            // A failed bootstrap leaves no group behind
                            // (the trait contract), so a TYPED transient
                            // or session-fatal bootstrap error fails the
                            // COHORT, not the engine: release the
                            // cohort's reservations and answer each
                            // request with a typed verdict. Engine-fatal
                            // (and untyped — unknown radius is the
                            // widest) still propagates.
                            if EngineError::classify(&e) == FaultKind::EngineFatal {
                                return Err(e);
                            }
                            for r in &reqs {
                                if let Some(kv) = self.paged.as_mut() {
                                    kv.release(r.id);
                                }
                                self.deadlines.remove(&r.id);
                                self.metrics.session_faults += 1;
                                self.failures
                                    .push((r.id, RequestError::SessionFault(format!("{e:#}"))));
                            }
                        }
                    }
                }
            } else if !self.batcher.is_empty() {
                // Requests are waiting but no group is decoding (the
                // batcher is holding out for a fuller bucket): record
                // the idle tick so the occupancy gauges aren't biased
                // by sampling only while a group is active.
                self.metrics.observe_occupancy(0.0, now);
                self.metrics.idle_ticks += 1;
            }
        } else {
            // Continuous join: a free slot should never idle while
            // requests wait — no batching delay on this path.
            let active = self.active.as_mut().unwrap();
            // Upshift first: a FULL group with requests queued grows
            // back to the bucket that fits them (the mirror of the
            // long-tail downshift — without it, a request arriving
            // after a shift to a headroom-less bucket would wait out
            // the whole tail instead of joining). Pending prefills veto
            // the shift: their core-side carry is keyed by row index,
            // so rows must not move mid-prefill (they finish within a
            // few lane rounds and the shift fires then).
            if active.slots.occupied() == active.slots.capacity()
                && !self.batcher.is_empty()
                && self.prefilling.is_empty()
            {
                let occ = active.slots.occupied();
                let b_new = self.core.bucket(occ + self.batcher.len());
                if b_new > active.slots.capacity() {
                    let (rows, ids): (Vec<usize>, Vec<u64>) =
                        active.slots.iter_occupied().unzip();
                    let migrated = self.core.migrate(&mut active.group, &rows, b_new)?;
                    let mut slots = SlotMap::new(b_new);
                    for id in ids {
                        slots.alloc(id).expect("fresh upshifted slot map full");
                    }
                    active.group = migrated;
                    active.slots = slots;
                    active.shrink_rounds = 0;
                    self.metrics.upshifts += 1;
                }
            }
            let free = active.slots.capacity() - active.slots.occupied();
            if free > 0 {
                // Join pressure load-shed, mirroring the bootstrap
                // path: reserve in FIFO order; the first request whose
                // footprint the pool cannot cover waits at the queue
                // front TOGETHER with everything taken behind it
                // (order and queue age preserved — a shed must never
                // drop the rest of the taken batch) until a finishing
                // session or an eviction frees blocks. Live block
                // tables stay untouched: reservation is all-or-nothing.
                let mut reqs = self.batcher.take(free);
                let mut cached = Vec::with_capacity(reqs.len());
                let mut shed_at = reqs.len();
                for (i, r) in reqs.iter().enumerate() {
                    match Self::reserve_kv(&mut self.paged, r) {
                        Some(c) => cached.push(c),
                        None => {
                            shed_at = i;
                            break;
                        }
                    }
                }
                if shed_at < reqs.len() {
                    for req in reqs.drain(shed_at..).rev() {
                        let at = req.enqueued;
                        self.batcher.requeue_front_at(req, at);
                    }
                }
                for (req, cached) in reqs.into_iter().zip(cached) {
                    // Invariant, not a request-reachable panic: at most
                    // `free` requests were taken, admission is the only
                    // slot writer in a tick, and the shed step above ran
                    // before the take.
                    let row = active.slots.alloc(req.id).expect("free slot disappeared");
                    // Chunked lane: a joining prompt longer than one
                    // chunk amortizes across rounds. The cache-hit
                    // prefix is skipped in COMPLETE chunks only, and
                    // never the final chunk — its logits seed the first
                    // sampled token (DESIGN.md §11).
                    let chunk = self
                        .arbiter
                        .as_ref()
                        .and_then(|_| self.core.prefill_chunk_len())
                        .filter(|&c| req.prompt.len() > c);
                    let joined = match chunk {
                        Some(c) => {
                            let len = req.prompt.len();
                            let skip_auth = (cached / c * c).min((len - 1) / c * c);
                            match self
                                .core
                                .prefill_begin(&mut active.group, row, &req, skip_auth)
                            {
                                Ok(skipped) => {
                                    self.metrics.prefill_tokens += (len - skipped) as u64;
                                    self.metrics.prefill_tokens_saved += skipped as u64;
                                    let chunks = (len - skipped + c - 1) / c;
                                    self.prefilling.insert(req.id, (row, chunks));
                                    Ok(())
                                }
                                Err(e) => Err(e),
                            }
                        }
                        None => {
                            self.metrics.prefill_tokens += req.prompt.len() as u64;
                            self.core.join(&mut active.group, row, &req)
                        }
                    };
                    match joined {
                        Ok(()) => {
                            active.stuck_cap =
                                active.stuck_cap.max(4 * req.max_new as u64 + 32);
                            self.metrics.joins += 1;
                            self.metrics.sessions_admitted += 1;
                        }
                        Err(e) => {
                            // A failed join leaves the group untouched
                            // (the trait contract: the one-row KV copy
                            // either lands or doesn't), so only the
                            // JOINING request fails — unless the fault is
                            // engine-fatal / untyped (unknown radius).
                            if EngineError::classify(&e) == FaultKind::EngineFatal {
                                return Err(e);
                            }
                            active.slots.free(req.id);
                            if let Some(kv) = self.paged.as_mut() {
                                kv.release(req.id);
                            }
                            self.deadlines.remove(&req.id);
                            self.metrics.session_faults += 1;
                            self.failures
                                .push((req.id, RequestError::SessionFault(format!("{e:#}"))));
                        }
                    }
                }
            }
        }

        // --- prefill lane (chunked prefill, DESIGN.md §11) ------------
        self.run_prefill_lane()?;

        // --- one decode round + harvest -------------------------------
        let mut retire = false;
        if let Some(active) = self.active.as_mut() {
            // Fault-contained round. Transient faults retry with
            // bounded linear backoff — rounds are atomic on failure, so
            // a retry replays the identical round; one that survives
            // the whole budget escalates to engine-fatal. Session-fatal
            // faults evict ONLY the offending row (slot + paged-KV
            // blocks freed, typed verdict) and retry the round for the
            // survivors. Engine-fatal and untyped faults propagate.
            let mut transient_attempts = 0u32;
            while active.slots.occupied() > 0 {
                match self.core.round(&mut active.group) {
                    Ok(()) => break,
                    Err(e) => match EngineError::classify(&e) {
                        FaultKind::Transient => {
                            if transient_attempts >= self.fault_cfg.transient_retries {
                                return Err(e.context(format!(
                                    "transient fault persisted after \
                                     {transient_attempts} round retries"
                                )));
                            }
                            transient_attempts += 1;
                            self.metrics.transient_retries += 1;
                            let backoff = self.fault_cfg.backoff * transient_attempts;
                            if !backoff.is_zero() {
                                std::thread::sleep(backoff);
                            }
                        }
                        FaultKind::SessionFatal => {
                            // Contained only when the fault names a live
                            // session; anything else gets the widest
                            // blast radius.
                            let Some(id) = EngineError::of(&e).and_then(|ee| ee.session)
                            else {
                                return Err(e);
                            };
                            let Some(row) = active.slots.slot_of(id) else {
                                return Err(e);
                            };
                            self.core.evict(&mut active.group, row);
                            active.slots.free(id);
                            self.prefilling.remove(&id);
                            if let Some(kv) = self.paged.as_mut() {
                                kv.release(id);
                            }
                            self.deadlines.remove(&id);
                            self.streamed.remove(&id);
                            self.metrics.session_faults += 1;
                            self.failures
                                .push((id, RequestError::SessionFault(format!("{e:#}"))));
                        }
                        FaultKind::EngineFatal => return Err(e),
                    },
                }
            }
            if active.slots.occupied() == 0 {
                // Every row was shed or evicted before a round could
                // complete: nothing ran, retire the empty group.
                self.active = None;
                self.metrics.groups_retired += 1;
                if let Some(kv) = self.paged.as_ref() {
                    self.metrics.kv_blocks_live = kv.blocks_live() as u64;
                    self.metrics.kv_blocks_free = kv.blocks_free() as u64;
                    self.metrics.prefix_hit_rate = kv.prefix_hit_rate();
                    self.metrics.kv_sheds = kv.sheds;
                    self.metrics.kv_evictions = kv.evictions;
                }
                self.step_adapt(now);
                return Ok(finished);
            }
            let (occ, cap) = (active.slots.occupied(), active.slots.capacity());
            self.metrics.rounds += 1;
            self.metrics
                .observe_occupancy(occ as f64 / cap as f64, now);
            self.metrics.live_row_rounds += occ as u64;
            self.metrics.padded_row_rounds += (cap - occ) as u64;

            // --- stream progress --------------------------------------
            // Surface the round's newly committed tokens as per-session
            // deltas (cores without `row_tokens` visibility are covered
            // by the harvest tail below). Prefilling rows are skipped:
            // their row state is not live (the engine's is a stale pad
            // whose tokens belong to a finished session).
            for (row, id) in active.slots.iter_occupied() {
                if self.prefilling.contains_key(&id) {
                    continue;
                }
                if let Some(toks) = self.core.row_tokens(&active.group, row) {
                    let seen = self.streamed.get(&id).copied().unwrap_or(0);
                    if toks.len() > seen {
                        self.token_events.push((id, toks[seen..].to_vec()));
                        self.streamed.insert(id, toks.len());
                    }
                }
            }

            let mut done_rows: Vec<(usize, u64)> = Vec::new();
            for (row, id) in active.slots.iter_occupied() {
                if self.prefilling.contains_key(&id) {
                    continue; // mid-prefill: never harvestable
                }
                if self.core.row_done(&active.group, row) {
                    done_rows.push((row, id));
                }
            }
            active.rounds_since_finish += 1;
            if !done_rows.is_empty() {
                active.rounds_since_finish = 0;
            }
            for (row, id) in done_rows {
                let res = self.core.take_result(&mut active.group, row);
                active.slots.free(id);
                if let Some(kv) = self.paged.as_mut() {
                    kv.release(id);
                }
                self.deadlines.remove(&id);
                // Harvest tail: whatever the mid-flight deltas had not
                // yet surfaced (everything, for a `row_tokens`-less
                // core) — so concatenated deltas always equal
                // `res.tokens` exactly, before the Done event fires.
                let seen = self.streamed.remove(&id).unwrap_or(0);
                if res.tokens.len() > seen {
                    self.token_events.push((id, res.tokens[seen..].to_vec()));
                }
                self.metrics.observe_session(&res);
                finished.push((id, res));
            }
            if active.rounds_since_finish > active.stuck_cap {
                bail!(
                    "scheduler stuck: {} rounds without a session finishing",
                    active.rounds_since_finish
                );
            }

            // --- long-tail downshift ----------------------------------
            // After the harvest (freed slots count) and only when the
            // queue is empty: a pending request would join the free
            // slots on the next tick, so migrating them away would
            // trade a cheap join for a prefill — admission always wins
            // the race for a slot.
            let occ = active.slots.occupied();
            retire = occ == 0;
            let fits_smaller = occ > 0 && self.core.bucket(occ) < active.slots.capacity();
            if self.downshift.enabled
                && fits_smaller
                && self.batcher.is_empty()
                && self.prefilling.is_empty()
            {
                active.shrink_rounds += 1;
                if active.shrink_rounds >= self.downshift.after_rounds {
                    let b_new = self.core.bucket(occ);
                    let (rows, ids): (Vec<usize>, Vec<u64>) =
                        active.slots.iter_occupied().unzip();
                    let migrated = self.core.migrate(&mut active.group, &rows, b_new)?;
                    let mut slots = SlotMap::new(b_new);
                    for id in ids {
                        slots.alloc(id).expect("fresh migrated slot map full");
                    }
                    active.group = migrated;
                    active.slots = slots;
                    active.shrink_rounds = 0;
                    self.metrics.downshifts += 1;
                }
            } else {
                active.shrink_rounds = 0;
            }
        }
        if retire {
            self.active = None;
            self.metrics.groups_retired += 1;
        }
        if let Some(kv) = self.paged.as_ref() {
            self.metrics.kv_blocks_live = kv.blocks_live() as u64;
            self.metrics.kv_blocks_free = kv.blocks_free() as u64;
            self.metrics.prefix_hit_rate = kv.prefix_hit_rate();
            self.metrics.kv_sheds = kv.sheds;
            self.metrics.kv_evictions = kv.evictions;
        }
        // --- adaptation round boundary (DESIGN.md §12) ----------------
        // AFTER the round and harvest: polls / launches the background
        // fine-tune and commits any hot-swap between rounds, never
        // mid-round.
        self.step_adapt(now);
        Ok(finished)
    }
}

// ---------------------------------------------------------------------------
// SimCore: deterministic PJRT-free core for tests and benches
// ---------------------------------------------------------------------------

/// One planned fault for the ChaosCore harness
/// ([`SimCore::with_fault_plan`]). Fires when the core is about to run
/// successful round `at_round` (0-based over `rounds_run`) — BEFORE any
/// group state mutates, so an injected round is atomic exactly as the
/// containment contract demands, and a retried round replays
/// identically.
#[derive(Clone, Debug)]
pub struct PlannedFault {
    pub at_round: u64,
    pub kind: FaultKind,
    /// Offending session (session-fatal faults only).
    pub session: Option<u64>,
    /// Consecutive firings before the round is let through (transient
    /// storms; 1 = fault once).
    pub times: u32,
}

/// Deterministic fault-injection plan for [`SimCore`] — the ChaosCore
/// harness: every containment claim in DESIGN.md §9 is pinned by
/// PJRT-free tests that inject exactly one failure class at exactly one
/// round, then compare the survivors bit-for-bit against an unfaulted
/// run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub faults: Vec<PlannedFault>,
    /// Edge-chaos extension (DESIGN.md §10): a chaos HTTP client severs
    /// its TCP connection after observing this many `token` events. The
    /// core never sees connections — the HTTP edge tests read the field
    /// and act it out client-side — but it lives here so ONE declarative
    /// plan describes a whole chaos scenario (engine faults + edge
    /// faults) and the vocabulary stays in one place.
    pub drop_conn_at: Option<u64>,
    /// Trainer-chaos extension (DESIGN.md §12): fault the Nth
    /// background fine-tune launch. Like `drop_conn_at`, the core never
    /// sees these — the [`AdaptDriver`] reads the list (via
    /// [`AdaptConfig::with_chaos`]) and substitutes a known-faulty
    /// subprocess at launch time, so the REAL orchestration machinery
    /// (reader thread, deadline kill, exit-status mapping) is what gets
    /// exercised — but the vocabulary stays in the one declarative
    /// plan.
    pub trainer: Vec<TrainerChaos>,
}

impl FaultPlan {
    pub fn transient_at(mut self, round: u64, times: u32) -> FaultPlan {
        self.faults.push(PlannedFault {
            at_round: round,
            kind: FaultKind::Transient,
            session: None,
            times,
        });
        self
    }

    pub fn session_fatal_at(mut self, round: u64, session: u64) -> FaultPlan {
        self.faults.push(PlannedFault {
            at_round: round,
            kind: FaultKind::SessionFatal,
            session: Some(session),
            times: 1,
        });
        self
    }

    pub fn engine_fatal_at(mut self, round: u64) -> FaultPlan {
        self.faults.push(PlannedFault {
            at_round: round,
            kind: FaultKind::EngineFatal,
            session: None,
            times: 1,
        });
        self
    }

    /// Edge chaos: the test's HTTP client drops its connection after
    /// `token_events` streamed `token` events (see the field docs).
    pub fn drop_conn_at(mut self, token_events: u64) -> FaultPlan {
        self.drop_conn_at = Some(token_events);
        self
    }

    /// Trainer chaos: the `run`th fine-tune launch (0-based) dies
    /// mid-stream after a valid first event.
    pub fn trainer_kill_at(mut self, run: u64) -> FaultPlan {
        self.trainer.push(TrainerChaos {
            at_run: run,
            kind: super::adapt::TrainerChaosKind::Kill,
        });
        self
    }

    /// Trainer chaos: the `run`th launch emits nothing until the
    /// deadline kills it.
    pub fn trainer_hang_at(mut self, run: u64) -> FaultPlan {
        self.trainer.push(TrainerChaos {
            at_run: run,
            kind: super::adapt::TrainerChaosKind::Hang,
        });
        self
    }

    /// Trainer chaos: the `run`th launch emits a non-protocol line.
    pub fn trainer_malformed_at(mut self, run: u64) -> FaultPlan {
        self.trainer.push(TrainerChaos {
            at_run: run,
            kind: super::adapt::TrainerChaosKind::Malformed,
        });
        self
    }
}

/// A simulated decode core: per-request RNG streams keyed by request id
/// drive random accepted-prefix lengths, so a session's statistics are a
/// pure function of (seed, id) — independent of batch composition,
/// admission order and join timing. Token j of a session echoes
/// `prompt[j % len] + 1000` — position-deterministic, so emitted tokens
/// are additionally independent of the per-round draft budget. Used by
/// the scheduler unit tests and the hot-path bench; also handy for
/// policy experiments without artifacts.
///
/// Two optional extensions serve the speculation-controller bench:
/// [`SimCore::with_alpha`] replaces the uniform accepted-length draw
/// with a per-position Bernoulli acceptance walk (each request may
/// carry its own profile, keyed by `id % profiles`), and
/// [`SimCore::with_controller`] lets a
/// [`SpecController`](crate::spec::adaptive::SpecController) pick each
/// round's chain length. Rounds and drafted-slot totals are tracked in
/// `rounds_run` / `round_k_sum` for cost accounting.
pub struct SimCore {
    pub k: usize,
    pub seed: u64,
    pub buckets: Vec<usize>,
    /// Per-position acceptance profiles; a request uses profile
    /// `id % profiles.len()`. Empty = the legacy uniform draw.
    pub profiles: Vec<Vec<f64>>,
    /// Optional online controller choosing each round's chain length.
    pub controller: Option<crate::spec::adaptive::SpecController>,
    /// Decode rounds executed (all groups).
    pub rounds_run: u64,
    /// Sum of per-round chain lengths (draft-cost accounting).
    pub round_k_sum: u64,
    /// ChaosCore: faults injected before the rounds they target.
    pub fault_plan: FaultPlan,
    /// Faults actually fired (tests assert the plan was consumed).
    pub faults_injected: u64,
    /// Chunked-prefill modeling: fixed chunk length (None = whole-
    /// prompt joins only)…
    pub prefill_chunk: Option<usize>,
    /// …and prefill chunks actually executed (cost accounting: one
    /// chunk = `chunk` tokens of prefill compute).
    pub prefill_chunks_run: u64,
    /// ChaosCore: fail `prefill_step` (session-fatal, one-shot) when
    /// `prefill_chunks_run` reaches this value.
    pub fail_prefill_at: Option<u64>,
    /// Online-adaptation harvest sink ([`SchedulerCore::attach_replay`]).
    pub replay: Option<ReplaySink>,
    /// Epoch of the last committed draft hot-swap (0 = the bootstrap
    /// profiles) and total swaps committed — test observability.
    pub draft_epoch: u64,
    pub swaps_committed: u64,
}

pub struct SimGroup {
    rows: Vec<SimSeq>,
}

struct SimSeq {
    done: bool,
    id: u64,
    rng: Pcg64,
    stats: AcceptanceStats,
    tokens: Vec<i32>,
    prompt: Vec<i32>,
    max_new: usize,
    rounds: u64,
    enqueued: Instant,
    queue_ms: f64,
    ttft_ms: f64,
    total_ms: f64,
    /// Prompt tokens still to prefill (chunked lane); > 0 means the row
    /// is mid-prefill: decode rounds skip it and its RNG stream is
    /// untouched, so chunking can never shift a session's draws.
    prefill_remaining: usize,
}

impl SimCore {
    pub fn new(k: usize, seed: u64, buckets: Vec<usize>) -> SimCore {
        let mut buckets = buckets;
        buckets.sort_unstable();
        assert!(!buckets.is_empty());
        SimCore {
            k,
            seed,
            buckets,
            profiles: Vec::new(),
            controller: None,
            rounds_run: 0,
            round_k_sum: 0,
            fault_plan: FaultPlan::default(),
            faults_injected: 0,
            prefill_chunk: None,
            prefill_chunks_run: 0,
            fail_prefill_at: None,
            replay: None,
            draft_epoch: 0,
            swaps_committed: 0,
        }
    }

    /// Model chunked prefill: a joining prompt longer than `chunk`
    /// enters through `prefill_begin`/`prefill_step` instead of `join`.
    pub fn with_chunked_prefill(mut self, chunk: usize) -> SimCore {
        assert!(chunk > 0, "chunk length must be positive");
        self.prefill_chunk = Some(chunk);
        self
    }

    /// Attach a ChaosCore fault-injection plan (see [`FaultPlan`]).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> SimCore {
        self.fault_plan = plan;
        self
    }

    /// Per-position Bernoulli acceptance profiles (request `id` uses
    /// `profiles[id % len]`). The walk draws a FIXED `k` uniforms per
    /// round regardless of the round's chain length, so a session's
    /// acceptance outcomes stay aligned across budget schedules.
    pub fn with_alpha(mut self, profiles: Vec<Vec<f64>>) -> SimCore {
        assert!(profiles.iter().all(|p| !p.is_empty()));
        self.profiles = profiles;
        self
    }

    pub fn with_controller(mut self, c: crate::spec::adaptive::SpecController) -> SimCore {
        self.controller = Some(c);
        self
    }

    fn seq_for(&self, req: &AdmitReq) -> SimSeq {
        let rng = request_rng(self.seed, req.id);
        let first = req.prompt[0] + 1000;
        SimSeq {
            done: false,
            id: req.id,
            rng,
            stats: AcceptanceStats::new(self.k),
            tokens: vec![first],
            prompt: req.prompt.clone(),
            max_new: req.max_new,
            rounds: 0,
            enqueued: req.enqueued,
            queue_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
            ttft_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
            total_ms: 0.0,
            prefill_remaining: 0,
        }
    }

    fn pad_seq(&self) -> SimSeq {
        SimSeq {
            done: true,
            id: u64::MAX,
            rng: Pcg64::new(self.seed, u64::MAX),
            stats: AcceptanceStats::new(self.k),
            tokens: Vec::new(),
            prompt: Vec::new(),
            max_new: 0,
            rounds: 0,
            enqueued: Instant::now(),
            queue_ms: 0.0,
            ttft_ms: 0.0,
            total_ms: 0.0,
            prefill_remaining: 0,
        }
    }
}

impl SchedulerCore for SimCore {
    type Group = SimGroup;

    fn bucket(&self, n: usize) -> usize {
        *self
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    fn bootstrap(&mut self, reqs: &[AdmitReq]) -> Result<SimGroup> {
        let b = self.bucket(reqs.len());
        let rows = (0..b)
            .map(|row| {
                if row < reqs.len() {
                    self.seq_for(&reqs[row])
                } else {
                    self.pad_seq()
                }
            })
            .collect();
        Ok(SimGroup { rows })
    }

    fn join(&mut self, g: &mut SimGroup, row: usize, req: &AdmitReq) -> Result<()> {
        anyhow::ensure!(row < g.rows.len(), "join row out of range");
        g.rows[row] = self.seq_for(req);
        Ok(())
    }

    fn round(&mut self, g: &mut SimGroup) -> Result<()> {
        // ChaosCore injection — BEFORE any state mutates (controller,
        // counters, RNG streams), so a faulted round is atomic and a
        // retry replays it identically. `rounds_run` only counts
        // completed rounds, so `at_round` indexes successful rounds.
        if let Some(f) = self
            .fault_plan
            .faults
            .iter_mut()
            .find(|f| f.times > 0 && f.at_round == self.rounds_run)
        {
            f.times -= 1;
            self.faults_injected += 1;
            let round = self.rounds_run;
            return Err(match f.kind {
                FaultKind::Transient => {
                    EngineError::transient(format!("injected transient fault at round {round}"))
                }
                FaultKind::SessionFatal => EngineError::session_fatal(
                    f.session.unwrap_or(u64::MAX),
                    format!("injected session fault at round {round}"),
                ),
                FaultKind::EngineFatal => {
                    EngineError::engine_fatal(format!("injected engine fault at round {round}"))
                }
            });
        }
        // One chain length per GROUP round, like the real engine (the
        // lowered entries take one k_active per call).
        let k_round = match self.controller.as_mut() {
            Some(c) => c.choose_k().min(self.k),
            None => self.k,
        };
        self.rounds_run += 1;
        self.round_k_sum += k_round as u64;
        for seq in g.rows.iter_mut() {
            if seq.done || seq.prefill_remaining > 0 {
                // Done padding, or a row still mid-prefill (chunked
                // lane): neither decodes, neither touches its RNG.
                continue;
            }
            // Short final rounds: never draft past the generation cap.
            let remaining = seq.max_new.saturating_sub(seq.tokens.len()).max(1);
            let n_drafted = k_round.min(remaining);
            let n_acc = if self.profiles.is_empty() {
                seq.rng.below(n_drafted + 1)
            } else {
                // Per-position Bernoulli walk over the session's alpha
                // profile. A FIXED k draws per round keep the stream
                // aligned across budget schedules (the emitted tokens
                // are position-deterministic either way).
                let profile = &self.profiles[(seq.id as usize) % self.profiles.len()];
                let draws: Vec<f64> = (0..self.k).map(|_| seq.rng.uniform()).collect();
                let mut acc = 0usize;
                for (i, &u) in draws.iter().take(n_drafted).enumerate() {
                    if u < profile[i.min(profile.len() - 1)] {
                        acc += 1;
                    } else {
                        break;
                    }
                }
                acc
            };
            if let Some(c) = self.controller.as_mut() {
                c.observe_chain(n_drafted, n_acc);
            }
            // Adaptation harvest: the sim's proposals are its committed
            // tokens (position-deterministic), so the drafted chain is
            // reconstructible before the verdict mutates the row. q/p
            // are unavailable here, as on the device-verify paths.
            if let Some(sink) = &self.replay {
                let pos0 = seq.tokens.len();
                let drafts: Vec<i32> = (0..n_drafted)
                    .map(|i| seq.prompt[(pos0 + i) % seq.prompt.len()] + 1000)
                    .collect();
                harvest_row(
                    sink,
                    seq.id,
                    self.rounds_run - 1,
                    pos0,
                    &seq.tokens,
                    &drafts,
                    n_acc,
                    &[],
                );
            }
            seq.stats.record_round(n_drafted, n_acc);
            for _ in 0..n_acc + 1 {
                let j = seq.tokens.len();
                seq.tokens.push(seq.prompt[j % seq.prompt.len()] + 1000);
            }
            seq.rounds += 1;
            if seq.tokens.len() >= seq.max_new {
                seq.done = true;
                seq.total_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
            }
        }
        Ok(())
    }

    fn migrate(&mut self, g: &mut SimGroup, rows: &[usize], b_new: usize) -> Result<SimGroup> {
        anyhow::ensure!(b_new != g.rows.len(), "migration must change the bucket");
        anyhow::ensure!(rows.len() <= b_new, "migrated rows exceed the target bucket");
        let mut moved = Vec::with_capacity(b_new);
        for &r in rows {
            anyhow::ensure!(r < g.rows.len(), "migrate row out of range");
            let pad = self.pad_seq();
            moved.push(std::mem::replace(&mut g.rows[r], pad));
        }
        while moved.len() < b_new {
            moved.push(self.pad_seq());
        }
        Ok(SimGroup { rows: moved })
    }

    fn row_done(&self, g: &SimGroup, row: usize) -> bool {
        g.rows[row].done
    }

    fn row_tokens(&self, g: &SimGroup, row: usize) -> Option<&[i32]> {
        // Truncate like `take_result`: a final short round can commit
        // past the generation cap, and the overshoot is never served.
        let seq = &g.rows[row];
        Some(&seq.tokens[..seq.tokens.len().min(seq.max_new)])
    }

    fn evict(&mut self, g: &mut SimGroup, row: usize) {
        // The evicted session's partial state is dropped wholesale; the
        // replacement pad row draws nothing, so no other row's RNG
        // stream or tokens can shift (the containment tests pin this
        // bit-for-bit against unfaulted runs).
        g.rows[row] = self.pad_seq();
    }

    fn attach_replay(&mut self, sink: ReplaySink) {
        self.replay = Some(sink);
    }

    fn swap_draft(&mut self, ckpt: &std::path::Path) -> Result<()> {
        // Validate-then-commit: parse + range-check the whole sim-draft
        // checkpoint before touching the live profiles; any error keeps
        // the old profiles serving (rollback = not swapping). Swapping
        // changes the Bernoulli acceptance walk only — emitted tokens
        // are position-deterministic and the walk draws a FIXED `k`
        // uniforms per round, so neither token values nor RNG alignment
        // can shift (the swap-safety properties pin this).
        let (epoch, profile) = super::adapt::read_sim_checkpoint(ckpt)
            .map_err(|e| super::adapt::swap_error(ckpt, e))?;
        self.profiles = vec![profile];
        self.draft_epoch = epoch;
        self.swaps_committed += 1;
        Ok(())
    }

    fn prefill_chunk_len(&self) -> Option<usize> {
        self.prefill_chunk
    }

    fn prefill_arbiter(&self, max_chunks_per_round: usize) -> Option<PrefillArbiter> {
        use crate::spec::adaptive::{CostModel, PrefillArbiterCfg};
        let chunk = self.prefill_chunk?;
        Some(PrefillArbiter::new(PrefillArbiterCfg {
            max_chunks_per_round,
            ..PrefillArbiterCfg::for_chunk(chunk, 8, CostModel::chained(0.25), 4)
        }))
    }

    fn prefill_begin(
        &mut self,
        g: &mut SimGroup,
        row: usize,
        req: &AdmitReq,
        skip: usize,
    ) -> Result<usize> {
        let chunk = self.prefill_chunk.expect("chunked prefill not enabled");
        anyhow::ensure!(row < g.rows.len(), "prefill row out of range");
        anyhow::ensure!(
            skip % chunk == 0 && skip < req.prompt.len(),
            "bad skip authorization"
        );
        let mut seq = self.seq_for(req);
        // Not live yet: the first token samples when the final chunk
        // lands (`prefill_step` → true), which is also when TTFT
        // stamps — chunking changes WHEN the token appears, never what
        // it is. The sim honors the full authorized skip (its "cached
        // carry" is free), so saved-compute accounting is exact.
        seq.tokens.clear();
        seq.prefill_remaining = req.prompt.len() - skip;
        g.rows[row] = seq;
        Ok(skip)
    }

    fn prefill_step(&mut self, g: &mut SimGroup, row: usize) -> Result<bool> {
        let chunk = self.prefill_chunk.expect("chunked prefill not enabled");
        let id = g.rows[row].id;
        if self.fail_prefill_at == Some(self.prefill_chunks_run) {
            // One-shot: a fault doesn't advance the chunk counter, so
            // without clearing it would re-fire on the NEXT session the
            // lane visits in the same round.
            self.fail_prefill_at = None;
            self.faults_injected += 1;
            return Err(EngineError::session_fatal(
                id,
                format!("injected prefill-chunk fault on session {id}"),
            ));
        }
        let seq = &mut g.rows[row];
        anyhow::ensure!(seq.prefill_remaining > 0, "no prefill pending on row {row}");
        self.prefill_chunks_run += 1;
        seq.prefill_remaining = seq.prefill_remaining.saturating_sub(chunk);
        if seq.prefill_remaining == 0 {
            seq.tokens.push(seq.prompt[0] + 1000);
            seq.ttft_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
            return Ok(true);
        }
        Ok(false)
    }

    fn take_result(&mut self, g: &mut SimGroup, row: usize) -> RequestResult {
        let seq = &mut g.rows[row];
        let mut tokens = seq.tokens.clone();
        tokens.truncate(seq.max_new);
        RequestResult {
            tokens,
            stats: seq.stats.clone(),
            latency_ms: seq.total_ms,
            ttft_ms: seq.ttft_ms,
            queue_ms: seq.queue_ms,
            rounds: seq.rounds,
        }
        // The row stays inert (done) padding until a join replaces it.
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn cfg(queue_cap: usize) -> BatcherConfig {
        BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::ZERO, // dispatch whatever is queued
            queue_cap,
        }
    }

    fn sim() -> SimCore {
        SimCore::new(4, 42, vec![1, 4])
    }

    /// Tick until idle, collecting results; panics if the scheduler
    /// fails to converge within `guard` ticks.
    fn drain(s: &mut Scheduler<SimCore>, guard: usize) -> Vec<(u64, RequestResult)> {
        let mut out = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            out.extend(s.tick(Instant::now()).unwrap());
            ticks += 1;
            assert!(ticks < guard, "scheduler did not converge");
        }
        out
    }

    /// THE tentpole behaviour: a queued request joins a running group
    /// mid-flight as soon as another sequence finishes — no new group is
    /// formed for it.
    #[test]
    fn queued_request_joins_mid_flight() {
        let mut s = Scheduler::new(sim(), cfg(64));
        // One short session plus three long ones fill the b=4 bucket.
        s.submit(vec![1, 2], 3).unwrap();
        for p in 0..3 {
            s.submit(vec![10 + p, 20 + p], 60).unwrap();
        }
        // Run until the short session finishes.
        let mut first_done = Vec::new();
        let mut ticks = 0;
        while first_done.is_empty() {
            first_done = s.tick(Instant::now()).unwrap();
            ticks += 1;
            assert!(ticks < 1000);
        }
        assert_eq!(first_done[0].0, 0, "short session should finish first");
        assert_eq!(s.metrics.groups_formed, 1);
        assert_eq!(s.metrics.joins, 0);
        // Queue a fifth request AFTER the group is already running.
        let late_id = s.submit(vec![9, 9, 9], 8).unwrap();
        assert_eq!(late_id, 4);
        assert!(s.in_flight() >= 1, "group must still be running");
        let rest = drain(&mut s, 10_000);
        // The late request was served by joining the running group, not
        // by forming a second one.
        assert_eq!(s.metrics.groups_formed, 1, "no new group for the join");
        assert_eq!(s.metrics.joins, 1);
        let ids: Vec<u64> = rest.iter().map(|(id, _)| *id).collect();
        assert!(ids.contains(&late_id));
        // All five sessions completed exactly once.
        let mut all: Vec<u64> = first_done.iter().chain(&rest).map(|(id, _)| *id).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    /// Per-position acceptance stats of the continuous path (join
    /// mid-flight) are IDENTICAL to the lockstep run-to-completion path
    /// for the same seeds/ids — the RNG stream is keyed by request id,
    /// not by group composition.
    #[test]
    fn continuous_stats_match_lockstep() {
        let caps = [5usize, 24, 24, 24, 10];
        // --- continuous path: 4 upfront, the 5th joins mid-flight ------
        let mut s = Scheduler::new(sim(), cfg(64));
        for (i, &m) in caps.iter().take(4).enumerate() {
            s.submit(vec![i as i32 + 1, 7], m).unwrap();
        }
        let mut got: BTreeMap<u64, RequestResult> = BTreeMap::new();
        let mut ticks = 0;
        while got.is_empty() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            ticks += 1;
            assert!(ticks < 1000);
        }
        s.submit(vec![5, 7], caps[4]).unwrap();
        for (id, r) in drain(&mut s, 10_000) {
            got.insert(id, r);
        }
        assert_eq!(got.len(), 5);
        assert!(s.metrics.joins >= 1);

        // --- lockstep reference: drive the core directly ---------------
        let mut core = sim();
        let now = Instant::now();
        let reqs: Vec<AdmitReq> = caps
            .iter()
            .take(4)
            .enumerate()
            .map(|(i, &m)| AdmitReq {
                id: i as u64,
                prompt: vec![i as i32 + 1, 7],
                max_new: m,
                enqueued: now,
                deadline: None,
            })
            .collect();
        let mut g = core.bootstrap(&reqs).unwrap();
        for _ in 0..1000 {
            if (0..4).all(|r| core.row_done(&g, r)) {
                break;
            }
            core.round(&mut g).unwrap();
        }
        let mut reference: BTreeMap<u64, RequestResult> = (0..4)
            .map(|r| (r as u64, core.take_result(&mut g, r)))
            .collect();
        let late = AdmitReq {
            id: 4,
            prompt: vec![5, 7],
            max_new: caps[4],
            enqueued: now,
            deadline: None,
        };
        let mut g2 = core.bootstrap(std::slice::from_ref(&late)).unwrap();
        for _ in 0..1000 {
            if core.row_done(&g2, 0) {
                break;
            }
            core.round(&mut g2).unwrap();
        }
        reference.insert(4, core.take_result(&mut g2, 0));

        for id in 0..5u64 {
            let a = &got[&id];
            let b = &reference[&id];
            assert_eq!(a.tokens, b.tokens, "tokens diverge for id {id}");
            assert_eq!(a.stats.drafted, b.stats.drafted, "drafted[] for id {id}");
            assert_eq!(a.stats.accepted, b.stats.accepted, "accepted[] for id {id}");
            assert_eq!(
                a.stats.prefix_hist, b.stats.prefix_hist,
                "prefix histogram for id {id}"
            );
        }
    }

    /// Admission-order / batch-composition independence of the RNG
    /// seeding: all-upfront vs one-at-a-time give identical per-id
    /// results (the old `next_seed` counter failed exactly this).
    #[test]
    fn rng_streams_admission_order_independent() {
        let run = |staggered: bool| -> BTreeMap<u64, RequestResult> {
            let mut s = Scheduler::new(sim(), cfg(64));
            let mut got = BTreeMap::new();
            if staggered {
                for i in 0..5 {
                    s.submit(vec![i + 1, 3, 9], 12).unwrap();
                    for (id, r) in drain(&mut s, 10_000) {
                        got.insert(id, r);
                    }
                }
            } else {
                for i in 0..5 {
                    s.submit(vec![i + 1, 3, 9], 12).unwrap();
                }
                for (id, r) in drain(&mut s, 10_000) {
                    got.insert(id, r);
                }
            }
            got
        };
        let upfront = run(false);
        let one_by_one = run(true);
        assert_eq!(upfront.len(), 5);
        for id in 0..5u64 {
            assert_eq!(upfront[&id].tokens, one_by_one[&id].tokens, "id {id}");
            assert_eq!(
                upfront[&id].stats.accepted, one_by_one[&id].stats.accepted,
                "id {id}"
            );
        }
    }

    /// Batcher buckets and core buckets are independent configs: a
    /// popped group larger than the core's capacity must not silently
    /// drop the tail — it returns to the queue and joins later.
    #[test]
    fn oversized_group_requeues_tail() {
        let cfg = BatcherConfig {
            buckets: vec![1, 8], // batcher willing to pop 8 at once
            max_wait: Duration::ZERO,
            queue_cap: 64,
        };
        let mut s = Scheduler::new(sim(), cfg); // core caps groups at 4
        for i in 0..8 {
            s.submit(vec![i + 1, 2], 6).unwrap();
        }
        let out = drain(&mut s, 10_000);
        assert_eq!(out.len(), 8, "every session must complete");
        let mut ids: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        // The tail was served through joins/new groups, never dropped.
        assert!(s.metrics.joins > 0 || s.metrics.groups_formed > 1);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let mut s = Scheduler::new(sim(), cfg(2));
        s.submit(vec![1, 2], 4).unwrap();
        s.submit(vec![3, 4], 4).unwrap();
        let rejected = s.submit(vec![5, 6], 4);
        assert_eq!(rejected, Err(SubmitError::QueueFull(vec![5, 6])));
        // The queue drains normally afterwards.
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 2);
    }

    /// Satellite: the long-tail downshift. One long session + three
    /// short ones fill the b=4 bucket; once the shorts retire the group
    /// must migrate to the b=1 bucket — and the migrated session's
    /// tokens AND acceptance stats must be identical to a lockstep run
    /// of the same (seed, id): migration moves state, never draws.
    #[test]
    fn downshift_migrates_long_tail_and_matches_lockstep() {
        let ds = DownshiftConfig {
            enabled: true,
            after_rounds: 2,
        };
        let mut s = Scheduler::with_downshift(sim(), cfg(64), ds);
        s.submit(vec![9, 4], 40).unwrap(); // id 0: the long tail
        for p in 0..3 {
            s.submit(vec![10 + p, 2], 4).unwrap(); // ids 1..3: short
        }
        let mut got: BTreeMap<u64, RequestResult> = BTreeMap::new();
        for (id, r) in drain(&mut s, 10_000) {
            got.insert(id, r);
        }
        assert_eq!(got.len(), 4);
        assert!(
            s.metrics.downshifts >= 1,
            "long tail never migrated (downshifts = {})",
            s.metrics.downshifts
        );
        // padding accounting: the b=4 phase burned padding, the
        // migrated b=1 phase burns none — so padded row-rounds must be
        // well below (capacity-1) x rounds.
        assert!(s.metrics.padded_row_rounds < 3 * s.metrics.rounds);

        // Lockstep reference for the migrated session.
        let mut core = sim();
        let req = AdmitReq {
            id: 0,
            prompt: vec![9, 4],
            max_new: 40,
            enqueued: Instant::now(),
            deadline: None,
        };
        let mut g = core.bootstrap(std::slice::from_ref(&req)).unwrap();
        for _ in 0..1000 {
            if core.row_done(&g, 0) {
                break;
            }
            core.round(&mut g).unwrap();
        }
        let reference = core.take_result(&mut g, 0);
        let migrated = &got[&0];
        assert_eq!(migrated.tokens, reference.tokens, "tokens diverge");
        assert_eq!(migrated.stats.drafted, reference.stats.drafted);
        assert_eq!(migrated.stats.accepted, reference.stats.accepted);
        assert_eq!(migrated.stats.prefix_hist, reference.stats.prefix_hist);
        assert_eq!(migrated.rounds, reference.rounds);
    }

    /// Edge: a migration racing a join on the same free slot. Admission
    /// runs first in every tick and a non-empty queue vetoes the shift,
    /// so the queued request wins the slot and no downshift happens.
    #[test]
    fn downshift_race_prefers_join() {
        let ds = DownshiftConfig {
            enabled: true,
            after_rounds: 2,
        };
        let mut s = Scheduler::with_downshift(sim(), cfg(64), ds);
        s.submit(vec![9, 4], 60).unwrap(); // id 0: long
        for p in 0..3 {
            s.submit(vec![10 + p, 2], 4).unwrap();
        }
        // Run until the three short sessions are done; the group now
        // qualifies for a downshift (occupancy 1, queue empty) but has
        // not reached after_rounds = 2 qualifying rounds on the tick
        // the last short was harvested.
        let mut done = std::collections::BTreeSet::new();
        let mut ticks = 0;
        while !(done.contains(&1) && done.contains(&2) && done.contains(&3)) {
            for (id, _) in s.tick(Instant::now()).unwrap() {
                done.insert(id);
            }
            ticks += 1;
            assert!(ticks < 1000);
        }
        assert_eq!(s.metrics.downshifts, 0, "shift fired before the race");
        // The racing request arrives before the would-be migration tick…
        let late = s.submit(vec![7, 7], 4).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        // …and wins the slot: joined, not migrated.
        assert_eq!(s.metrics.joins, 1, "queued request must join the group");
        assert_eq!(s.metrics.downshifts, 0, "join must veto the downshift");
        let rest = drain(&mut s, 10_000);
        let ids: Vec<u64> = rest.iter().map(|(id, _)| *id).collect();
        assert!(ids.contains(&late));
        assert!(ids.contains(&0));
        assert_eq!(s.metrics.groups_formed, 1);
    }

    /// The downshift's mirror: a request arriving AFTER the group
    /// shrank to a headroom-less bucket must not wait out the tail —
    /// the scheduler re-grows the group (upshift) and joins it in the
    /// same tick.
    #[test]
    fn upshift_regrows_downshifted_group() {
        let ds = DownshiftConfig {
            enabled: true,
            after_rounds: 1,
        };
        let mut s = Scheduler::with_downshift(sim(), cfg(64), ds);
        s.submit(vec![9, 4], 60).unwrap(); // id 0: the long tail
        for p in 0..3 {
            s.submit(vec![10 + p, 2], 4).unwrap();
        }
        let mut ticks = 0;
        while s.metrics.downshifts == 0 {
            let _ = s.tick(Instant::now()).unwrap();
            ticks += 1;
            assert!(ticks < 1000, "downshift never fired");
        }
        assert_eq!(s.in_flight(), 1, "only the tail survives the shift");
        // The b=1 group is FULL; the new arrival must trigger an
        // upshift and join on the next tick, not queue behind the tail.
        let late = s.submit(vec![7, 7], 4).unwrap();
        let mut rest = s.tick(Instant::now()).unwrap();
        assert_eq!(s.metrics.upshifts, 1, "full shrunk group must re-grow");
        assert_eq!(s.metrics.joins, 1, "arrival joins the re-grown group");
        assert_eq!(s.metrics.groups_formed, 1, "never a second group");
        rest.extend(drain(&mut s, 10_000));
        let ids: Vec<u64> = rest.iter().map(|(id, _)| *id).collect();
        assert!(ids.contains(&late) && ids.contains(&0));
    }

    /// Satellite: occupancy is no longer sampled only while a group is
    /// active — ticks spent holding a partial bucket record 0.0.
    #[test]
    fn occupancy_records_idle_ticks() {
        let cfg = BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_secs(1000), // hold for a full bucket
            queue_cap: 64,
        };
        let mut s = Scheduler::new(sim(), cfg);
        s.submit(vec![1, 2], 4).unwrap();
        for _ in 0..3 {
            let out = s.tick(Instant::now()).unwrap();
            assert!(out.is_empty(), "nothing can finish while batching waits");
        }
        assert_eq!(s.pending(), 1);
        assert_eq!(s.metrics.idle_ticks, 3);
        assert_eq!(s.metrics.slot_occupancy.n, 3);
        assert_eq!(s.metrics.slot_occupancy.mean(), 0.0);
    }

    /// The speculation controller on SimCore: enabling it changes the
    /// per-round draft budget (and hence round counts) but NEVER the
    /// emitted token sequences.
    #[test]
    fn adaptive_controller_changes_budget_not_tokens() {
        use crate::spec::adaptive::{ControllerCfg, CostModel, SpecController};
        let run = |controller: bool| -> (BTreeMap<u64, RequestResult>, u64, u64) {
            let mut core = SimCore::new(7, 77, vec![1, 4])
                .with_alpha(vec![vec![0.05; 7]]); // hopeless draft
            if controller {
                core = core.with_controller(SpecController::new(ControllerCfg {
                    k_max: 7,
                    warmup: 8,
                    cost: CostModel::chained(0.25),
                    ..Default::default()
                }));
            }
            let mut s = Scheduler::new(core, cfg(64));
            for i in 0..4 {
                s.submit(vec![i + 1, 5, 9], 12).unwrap();
            }
            let mut got = BTreeMap::new();
            for (id, r) in drain(&mut s, 10_000) {
                got.insert(id, r);
            }
            (got, s.core().rounds_run, s.core().round_k_sum)
        };
        let (fixed, fixed_rounds, fixed_k_sum) = run(false);
        let (adaptive, ad_rounds, ad_k_sum) = run(true);
        assert_eq!(fixed.len(), 4);
        for id in 0..4u64 {
            assert_eq!(
                fixed[&id].tokens, adaptive[&id].tokens,
                "controller changed emitted tokens for id {id}"
            );
        }
        // Fixed runs spend k = 7 every round; the controller collapses
        // to short chains once the 5% acceptance shows up.
        assert_eq!(fixed_k_sum, 7 * fixed_rounds);
        let ad_mean_k = ad_k_sum as f64 / ad_rounds as f64;
        assert!(
            ad_mean_k < 5.0,
            "controller kept drafting long under 5% acceptance (mean k {ad_mean_k:.2})"
        );
    }

    #[test]
    fn metrics_track_occupancy_and_waits() {
        let mut s = Scheduler::new(sim(), cfg(64));
        for i in 0..4 {
            s.submit(vec![i + 1, 2], 8).unwrap();
        }
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 4);
        assert_eq!(s.metrics.sessions, 4);
        assert!(s.metrics.rounds > 0);
        assert!(s.metrics.slot_occupancy.n > 0);
        assert!(s.metrics.slot_occupancy.mean() > 0.0);
        assert!(s.metrics.tokens_out >= 4 * 8);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_slot_occupancy_mean"));
        assert!(text.contains("lkspec_sched_tokens_per_second"));
    }

    fn paged_cfg(total_blocks: usize) -> PagedKvConfig {
        PagedKvConfig {
            block_size: 4,
            total_blocks,
            prefix_cache: true,
        }
    }

    /// Tentpole invariant at the scheduler level: the paged pool is an
    /// ACCOUNTING layer. With a roomy pool, every admission decision is
    /// identical to the dense run, so per-id tokens and acceptance
    /// stats are bit-identical — while the radix cache reports real
    /// sharing on a shared-system-prompt mix.
    #[test]
    fn paged_admission_never_changes_tokens() {
        let shared_prompt: Vec<i32> = (100..108).collect(); // 2 chunks at bs=4
        let run = |paged: Option<PagedKvConfig>| -> (BTreeMap<u64, RequestResult>, u64) {
            let mut s = Scheduler::new(sim(), cfg(64));
            if let Some(p) = paged {
                s = s.with_paged_kv(p);
            }
            for _ in 0..6 {
                s.submit(shared_prompt.clone(), 8).unwrap();
            }
            let mut got = BTreeMap::new();
            for (id, r) in drain(&mut s, 10_000) {
                got.insert(id, r);
            }
            (got, s.metrics.prefill_tokens_saved)
        };
        let (dense, dense_saved) = run(None);
        let (paged, paged_saved) = run(Some(paged_cfg(32)));
        assert_eq!(dense.len(), 6);
        assert_eq!(dense_saved, 0, "dense path never reports cache savings");
        for id in 0..6u64 {
            assert_eq!(paged[&id].tokens, dense[&id].tokens, "tokens diverge for id {id}");
            assert_eq!(paged[&id].stats.accepted, dense[&id].stats.accepted, "id {id}");
            assert_eq!(paged[&id].stats.prefix_hist, dense[&id].stats.prefix_hist, "id {id}");
        }
        // Whole-prompt prefill recomputes cached prefixes: the radix
        // hits share BLOCKS (visible in prefix_hit_rate), but no
        // prefill COMPUTE is skipped without the chunked lane —
        // `saved` counts FLOPs avoided, not capacity shared.
        assert_eq!(paged_saved, 0);
    }

    /// Paged gauges and prefill counters are refreshed from the pool at
    /// the end of every tick and rendered in the plain lkspec_ namespace.
    #[test]
    fn paged_gauges_and_prefill_counters() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(32));
        let prompt: Vec<i32> = (0..8).collect();
        for _ in 0..4 {
            s.submit(prompt.clone(), 8).unwrap();
        }
        let out = drain(&mut s, 10_000);
        assert_eq!(out.len(), 4);
        // 4 sessions x 8 prompt tokens, all prefilled whole-prompt (no
        // chunked lane): every token's compute ran, nothing saved —
        // the cache sharing shows up in prefix_hit_rate instead.
        assert_eq!(s.metrics.prefill_tokens, 32);
        assert_eq!(s.metrics.prefill_tokens_saved, 0);
        assert!(s.metrics.prefix_hit_rate > 0.5);
        // After the drain only the cache-resident prompt chunks remain
        // live (2 chunks of the shared prompt).
        assert_eq!(s.metrics.kv_blocks_live, 2);
        assert_eq!(s.metrics.kv_blocks_free, 30);
        assert_eq!(s.metrics.kv_sheds, 0);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_kv_blocks_live{engine=\"sim\"} 2"));
        assert!(text.contains("lkspec_kv_blocks_free{engine=\"sim\"} 30"));
        assert!(text.contains("lkspec_prefix_hit_rate"));
        assert!(text.contains("lkspec_sched_prefill_tokens_saved_total{engine=\"sim\"} 0"));
        assert!(text.contains("lkspec_sched_prefill_chunks_total{engine=\"sim\"} 0"));
        assert!(text.contains("lkspec_sched_prefill_lane_rounds{engine=\"sim\"} 0"));
    }

    /// Satellite edge case: free-list exhaustion under join pressure
    /// load-sheds the join back to the queue front — live block tables
    /// are never corrupted, and the shed session completes once a
    /// finishing session releases its reservation.
    #[test]
    fn kv_exhaustion_sheds_join_then_recovers() {
        // Distinct prompts (no sharing) at bs = 4: id 0 needs
        // blocks_for(4 + 2) = 2 blocks, ids 1..3 need
        // blocks_for(4 + 8) = 3 each. A pool of 8 admits ids 0..2
        // (8 blocks) and sheds id 3 at bootstrap. id 0 finishes on the
        // very first round (max_new = 2, >= 1 token per round), but its
        // release frees only 2 blocks (1 private + 1 evictable cache
        // chunk) — id 3's retry must evict the chunk, STILL come up one
        // block short, and roll back without touching the two live
        // tables; it succeeds only after id 1 or 2 finishes.
        let max_new = |i: u64| if i == 0 { 2 } else { 8 };
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(8));
        for i in 0..4u64 {
            s.submit(vec![50 * (i as i32 + 1), 2, 3, 4], max_new(i)).unwrap();
        }
        let out = drain(&mut s, 10_000);
        assert_eq!(out.len(), 4, "shed session must eventually complete");
        let mut ids: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(s.metrics.kv_sheds >= 2, "bootstrap shed + at least one join shed");
        assert!(s.metrics.kv_evictions >= 1, "retry must evict id 0's idle chunk");
        // Token streams are unaffected by the shed/retry (id-keyed RNG).
        let reference = {
            let mut s2 = Scheduler::new(sim(), cfg(64));
            for i in 0..4u64 {
                s2.submit(vec![50 * (i as i32 + 1), 2, 3, 4], max_new(i)).unwrap();
            }
            let mut got = BTreeMap::new();
            for (id, r) in drain(&mut s2, 10_000) {
                got.insert(id, r);
            }
            got
        };
        for (id, r) in &out {
            assert_eq!(r.tokens, reference[id].tokens, "shed changed tokens for id {id}");
        }
    }

    /// Regression: a join-path shed must requeue the shed request AND
    /// everything taken behind it. Take returns [B, C]; B's footprint
    /// sheds — C must go back to the queue (it used to be silently
    /// dropped, its reply channel lost) and be served later.
    #[test]
    fn join_shed_requeues_requests_taken_behind_it() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(16));
        // id 0: long tail, blocks_for(2 + 24) = 7 blocks at bs = 4.
        s.submit(vec![9, 4], 24).unwrap();
        // ids 1..3: short, 1 block each (2-token prompts publish no
        // cache chunks, so nothing is evictable later).
        for p in 0..3 {
            s.submit(vec![10 + p, 2], 2).unwrap();
        }
        // Run until the three shorts retire: 3 free slots, 9 free
        // blocks, id 0 still decoding.
        let mut done = Vec::new();
        let mut ticks = 0;
        while done.len() < 3 {
            done.extend(s.tick(Instant::now()).unwrap());
            ticks += 1;
            assert!(ticks < 1000);
        }
        // id 4 (B) needs blocks_for(4 + 40) = 11 > 9 free -> join shed;
        // id 5 (C) needs 1 block and is taken in the same batch.
        s.submit(vec![7, 7, 7, 7], 40).unwrap();
        s.submit(vec![8, 8], 2).unwrap();
        done.extend(drain(&mut s, 10_000));
        assert!(s.metrics.kv_sheds >= 1, "B must shed at least once");
        let mut ids: Vec<u64> = done.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5], "no taken request may be dropped");
    }

    /// A request whose worst-case footprint exceeds the WHOLE pool can
    /// never be admitted — it is rejected per-request at SUBMIT time
    /// (not surfaced from `tick` as an engine fault, which would abort
    /// every concurrent session), and the scheduler keeps serving.
    #[test]
    fn oversized_request_rejected_at_submit() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(2));
        // Needs blocks_for(40 + 40) = 20 blocks; the pool holds 2.
        let err = s.submit((0..40).collect(), 40).unwrap_err();
        assert_eq!(
            err,
            SubmitError::TooLarge {
                blocks_needed: 20,
                pool_blocks: 2
            }
        );
        assert!(err.to_string().contains("KV blocks"), "got: {err}");
        // Nothing was queued; a normal-sized request is unaffected.
        assert!(s.is_idle());
        s.submit(vec![1, 2], 4).unwrap();
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 1);
    }

    // --- ChaosCore: fault containment under deterministic injection ---

    fn fast_faults() -> FaultConfig {
        FaultConfig {
            transient_retries: 3,
            backoff: Duration::ZERO,
        }
    }

    /// Run `n` identical sessions to completion under `plan`, collecting
    /// results and typed failure verdicts.
    fn chaos_run(
        plan: FaultPlan,
        n: usize,
        max_new: usize,
    ) -> (
        BTreeMap<u64, RequestResult>,
        Vec<(u64, RequestError)>,
        Scheduler<SimCore>,
    ) {
        let core = sim().with_fault_plan(plan);
        let mut s = Scheduler::new(core, cfg(64))
            .with_paged_kv(paged_cfg(64))
            .with_fault_config(fast_faults());
        for i in 0..n {
            s.submit(vec![i as i32 + 1, 3, 9], max_new).unwrap();
        }
        let mut got = BTreeMap::new();
        let mut failures = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            failures.extend(s.take_failures());
            ticks += 1;
            assert!(ticks < 10_000, "chaos run did not converge");
        }
        (got, failures, s)
    }

    /// TENTPOLE acceptance: an injected transient fault loses ZERO
    /// sessions — the round retries and every session's tokens and
    /// acceptance stats are bit-equal to the unfaulted run.
    #[test]
    fn transient_fault_zero_sessions_lost_bit_equal() {
        let (clean, f0, _) = chaos_run(FaultPlan::default(), 4, 12);
        assert!(f0.is_empty());
        let (faulted, failures, s) =
            chaos_run(FaultPlan::default().transient_at(2, 2), 4, 12);
        assert!(failures.is_empty(), "transient fault must lose no session");
        assert_eq!(faulted.len(), 4);
        assert_eq!(s.core().faults_injected, 2, "plan must have fired");
        assert_eq!(s.metrics.transient_retries, 2);
        for id in 0..4u64 {
            assert_eq!(faulted[&id].tokens, clean[&id].tokens, "tokens diverge, id {id}");
            assert_eq!(faulted[&id].stats.accepted, clean[&id].stats.accepted, "id {id}");
            assert_eq!(
                faulted[&id].stats.prefix_hist, clean[&id].stats.prefix_hist,
                "id {id}"
            );
        }
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_transient_retries_total{engine=\"sim\"} 2"));
    }

    /// A transient STORM that outlives the retry budget escalates to
    /// engine-fatal — tick errors, the caller resets, service resumes.
    #[test]
    fn transient_storm_escalates_then_reset_recovers() {
        // times = 4 consumes the initial attempt + the whole 3-retry
        // budget, so escalation fires with the plan exactly spent.
        let core = sim().with_fault_plan(FaultPlan::default().transient_at(1, 4));
        let mut s = Scheduler::new(core, cfg(64))
            .with_paged_kv(paged_cfg(64))
            .with_fault_config(fast_faults());
        for i in 0..2 {
            s.submit(vec![i + 1, 5], 12).unwrap();
        }
        let mut err = None;
        for _ in 0..100 {
            match s.tick(Instant::now()) {
                Ok(_) => {}
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        let e = err.expect("storm must escalate to an engine-fatal tick error");
        // The escalated error still carries the typed fault for
        // diagnostics, but reaching the caller IS the engine-fatal path.
        assert_eq!(
            EngineError::of(&e).map(|ee| ee.kind),
            Some(FaultKind::Transient)
        );
        assert!(e.to_string().contains("retries"), "got: {e:#}");
        assert_eq!(s.metrics.transient_retries, 3);
        // Router-style recovery: reset, then serve fresh work.
        s.reset();
        assert_eq!(s.metrics.engine_resets, 1);
        assert_eq!(s.paged_kv().unwrap().sessions(), 0);
        s.submit(vec![8, 8], 4).unwrap();
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 1, "engine must keep serving after reset");
    }

    /// TENTPOLE acceptance: a session-fatal fault fails ONLY the named
    /// session; the survivors are bit-equal to the unfaulted run and the
    /// evicted session's slot + KV blocks are released.
    #[test]
    fn session_fatal_evicts_only_offender() {
        let (clean, _, _) = chaos_run(FaultPlan::default(), 4, 12);
        let (got, failures, s) =
            chaos_run(FaultPlan::default().session_fatal_at(1, 1), 4, 12);
        assert_eq!(failures.len(), 1, "exactly one session may fail");
        assert_eq!(failures[0].0, 1);
        assert!(
            matches!(&failures[0].1, RequestError::SessionFault(m) if m.contains("injected")),
            "got: {:?}",
            failures[0].1
        );
        let ids: Vec<u64> = got.keys().copied().collect();
        assert_eq!(ids, vec![0, 2, 3], "survivors must all complete");
        for id in [0u64, 2, 3] {
            assert_eq!(got[&id].tokens, clean[&id].tokens, "tokens diverge, id {id}");
            assert_eq!(got[&id].stats.accepted, clean[&id].stats.accepted, "id {id}");
        }
        assert_eq!(s.metrics.session_faults, 1);
        // The evicted session's reservation was released with it.
        assert_eq!(s.paged_kv().unwrap().sessions(), 0);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_session_faults_total{engine=\"sim\"} 1"));
    }

    /// Satellite: a queued request past its deadline is shed BEFORE any
    /// prefill or paged-KV reservation is spent on it.
    #[test]
    fn deadline_expired_queued_sheds_before_prefill() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(32));
        let past = Instant::now() - Duration::from_millis(5);
        let doomed = s.submit_with(vec![5, 5], 8, Some(past)).unwrap();
        let ok = s.submit(vec![1, 2], 4).unwrap();
        let out = drain(&mut s, 1000);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, ok);
        assert_eq!(
            s.take_failures(),
            vec![(doomed, RequestError::DeadlineExceeded)]
        );
        assert_eq!(s.metrics.deadline_expired_queued, 1);
        // No prefill was spent on the expired request (only the served
        // request's 2-token prompt was prefilled) and no blocks remain
        // reserved for it.
        assert_eq!(s.metrics.prefill_tokens, 2);
        assert_eq!(s.paged_kv().unwrap().sessions(), 0);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_deadline_expired_queued{engine=\"sim\"} 1"));
    }

    /// TENTPOLE acceptance: a mid-flight cancel frees its slot AND its
    /// paged-KV blocks, and the freed capacity is observably reused — a
    /// queued request that could not fit joins in the same tick.
    #[test]
    fn midflight_cancel_frees_slot_and_blocks_for_reuse() {
        // 4 sessions x blocks_for(2 + 30) = 8 blocks at bs = 4 fill the
        // 32-block pool exactly; the 5th (same footprint) must wait.
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(32));
        for i in 0..4 {
            s.submit(vec![10 * (i + 1), 2], 30).unwrap();
        }
        let _ = s.tick(Instant::now()).unwrap();
        assert_eq!(s.in_flight(), 4);
        let fifth = s.submit(vec![77, 2], 30).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        assert_eq!(s.pending(), 1, "no slot and no blocks: the 5th waits");
        // Cancel a long-running session: its slot + 8 blocks free up and
        // the 5th joins (2 rounds in, id 1 holds at most 11 < 30 tokens,
        // so it cannot have finished on its own).
        s.cancel(1);
        let mut got = BTreeMap::new();
        let mut failures = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            failures.extend(s.take_failures());
            ticks += 1;
            assert!(ticks < 10_000);
        }
        assert_eq!(failures, vec![(1, RequestError::Cancelled)]);
        assert_eq!(s.metrics.cancelled, 1);
        let ids: Vec<u64> = got.keys().copied().collect();
        assert_eq!(ids, vec![0, 2, 3, fifth], "freed capacity must serve the 5th");
        assert!(s.metrics.joins >= 1, "the 5th must JOIN the freed slot");
        assert_eq!(s.metrics.groups_formed, 1);
    }

    /// Satellite: a deadline that expires mid-flight evicts the row the
    /// same way (slot + blocks released, typed verdict).
    #[test]
    fn midflight_deadline_evicts_row() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(32));
        let deadline = Instant::now() + Duration::from_millis(20);
        let doomed = s.submit_with(vec![9, 9], 30, Some(deadline)).unwrap();
        let other = s.submit(vec![1, 2], 30).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        assert_eq!(s.in_flight(), 2, "both admitted before the deadline");
        std::thread::sleep(Duration::from_millis(25));
        let mut got = BTreeMap::new();
        let mut failures = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            for (id, r) in s.tick(Instant::now()).unwrap() {
                got.insert(id, r);
            }
            failures.extend(s.take_failures());
            ticks += 1;
            assert!(ticks < 10_000);
        }
        assert_eq!(failures, vec![(doomed, RequestError::DeadlineExceeded)]);
        assert_eq!(s.metrics.deadline_expired_inflight, 1);
        assert!(got.contains_key(&other) && got.len() == 1);
        assert_eq!(s.paged_kv().unwrap().sessions(), 0);
    }

    /// Satellite: graceful drain refuses new submits with a typed error,
    /// flushes the queue WITHOUT waiting out the batching window, and
    /// `is_idle` signals completion once all accepted work is answered.
    #[test]
    fn drain_flushes_accepted_and_rejects_new() {
        let hold = BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_secs(1000), // hold for a full bucket
            queue_cap: 64,
        };
        let mut s = Scheduler::new(sim(), hold);
        let a = s.submit(vec![1, 2], 6).unwrap();
        let b = s.submit(vec![3, 4], 6).unwrap();
        let out = s.tick(Instant::now()).unwrap();
        assert!(out.is_empty() && s.in_flight() == 0, "batcher is holding");
        s.drain();
        assert!(s.is_draining());
        assert_eq!(s.submit(vec![5, 6], 4), Err(SubmitError::Draining));
        let done = drain(&mut s, 1000);
        let mut ids: Vec<u64> = done.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![a, b], "drain must flush and finish accepted work");
        assert!(s.is_idle(), "is_idle doubles as the drain-complete signal");
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_draining{engine=\"sim\"} 1"));
    }

    /// Satellite (unwrap audit): a malformed request fails ITSELF at
    /// submit time with a typed verdict — never a panic, never a later
    /// group-level engine fault.
    #[test]
    fn empty_prompt_rejected_at_submit() {
        let mut s = Scheduler::new(sim(), cfg(64));
        match s.submit(vec![], 4) {
            Err(SubmitError::Invalid { reason }) => {
                assert!(reason.contains("empty prompt"), "got: {reason}")
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        assert!(s.is_idle(), "nothing may be queued");
        // Overflow probe: a huge max_new must not wrap the footprint
        // arithmetic into a small block count.
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(8));
        match s.submit(vec![1, 2], usize::MAX) {
            Err(SubmitError::TooLarge { .. }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    /// Streaming contract: per-session `take_token_events` deltas,
    /// concatenated in order, equal the terminal result's tokens
    /// EXACTLY — and mid-flight deltas arrive round by round, not as
    /// one terminal burst (the SSE edge is built on both halves).
    #[test]
    fn stream_deltas_concat_to_result() {
        let mut s = Scheduler::new(sim(), cfg(64));
        s.submit(vec![1, 2], 17).unwrap();
        s.submit(vec![3, 4, 5], 9).unwrap();
        let mut streamed: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
        let mut bursts: BTreeMap<u64, usize> = BTreeMap::new();
        let mut done: Vec<(u64, RequestResult)> = Vec::new();
        let mut ticks = 0;
        while !s.is_idle() {
            done.extend(s.tick(Instant::now()).unwrap());
            for (id, delta) in s.take_token_events() {
                assert!(!delta.is_empty(), "empty deltas are never emitted");
                streamed.entry(id).or_default().extend(delta);
                *bursts.entry(id).or_default() += 1;
            }
            ticks += 1;
            assert!(ticks < 1000, "scheduler did not converge");
        }
        assert_eq!(done.len(), 2);
        for (id, res) in done {
            assert_eq!(streamed[&id], res.tokens, "deltas must concat to the reply");
            assert!(bursts[&id] > 1, "session {id} streamed in one burst");
        }
    }

    /// An evicted (cancelled) session stops streaming and leaks no
    /// per-session stream state; survivors stream to completion.
    #[test]
    fn cancelled_session_stops_streaming() {
        let mut s = Scheduler::new(sim(), cfg(64));
        let keep = s.submit(vec![1, 2], 8).unwrap();
        let doomed = s.submit(vec![3, 4], 2000).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        let _ = s.take_token_events();
        s.cancel(doomed);
        let mut streamed: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
        let mut ticks = 0;
        while !s.is_idle() {
            let _ = s.tick(Instant::now()).unwrap();
            for (id, delta) in s.take_token_events() {
                streamed.entry(id).or_default().extend(delta);
            }
            ticks += 1;
            assert!(ticks < 1000, "scheduler did not converge");
        }
        assert!(
            !streamed.contains_key(&doomed),
            "cancelled session must not stream after the verdict"
        );
        assert!(!streamed[&keep].is_empty());
        assert_eq!(
            s.take_failures(),
            vec![(doomed, RequestError::Cancelled)],
            "cancel verdict still delivered"
        );
    }

    /// `reset` rebuilds the pool from the stored config: no stale block
    /// tables or cache nodes survive into the next run.
    #[test]
    fn reset_rebuilds_paged_pool() {
        let mut s = Scheduler::new(sim(), cfg(64)).with_paged_kv(paged_cfg(16));
        let prompt: Vec<i32> = (0..8).collect();
        for _ in 0..2 {
            s.submit(prompt.clone(), 8).unwrap();
        }
        let _ = drain(&mut s, 10_000);
        assert!(s.paged_kv().unwrap().blocks_live() > 0, "cache keeps chunks live");
        s.reset();
        let kv = s.paged_kv().unwrap();
        assert_eq!(kv.blocks_live(), 0);
        assert_eq!(kv.blocks_free(), 16);
        assert_eq!(kv.sessions(), 0);
    }

    // --- chunked prefill lane (DESIGN.md §11) --------------------------

    fn arb(chunk: usize, cap: usize) -> PrefillArbiter {
        use crate::spec::adaptive::{CostModel, PrefillArbiterCfg};
        PrefillArbiter::new(PrefillArbiterCfg {
            max_chunks_per_round: cap,
            ..PrefillArbiterCfg::for_chunk(chunk, 8, CostModel::chained(0.25), 4)
        })
    }

    /// Chunked-prefill keystone at the scheduler level: interleaving a
    /// joining long prompt chunk-by-chunk changes WHEN its first token
    /// appears, never WHAT any session emits — per-id tokens and
    /// acceptance stats are bit-equal to the whole-prompt-join run.
    #[test]
    fn chunked_prefill_join_bit_equal_to_whole_prompt() {
        let long: Vec<i32> = (200..248).collect(); // 48 tokens = 12 chunks at c=4
        let run = |chunked: bool| -> (BTreeMap<u64, RequestResult>, u64, u64) {
            let core = if chunked {
                sim().with_chunked_prefill(4)
            } else {
                sim()
            };
            let mut s = Scheduler::new(core, cfg(64));
            if chunked {
                s = s.with_chunked_prefill(arb(4, 2));
            }
            // id 0: a long-running keeper holds the group open; ids
            // 1..3 fill the b=4 bucket.
            s.submit(vec![1, 7], 40).unwrap();
            for i in 1..4 {
                s.submit(vec![i + 1, 7], 6).unwrap();
            }
            let _ = s.tick(Instant::now()).unwrap();
            // The long prompt arrives against a DECODING group and must
            // join through the lane (or whole-prompt, for the control).
            s.submit(long.clone(), 8).unwrap();
            let mut got = BTreeMap::new();
            for (id, r) in drain(&mut s, 10_000) {
                got.insert(id, r);
            }
            (got, s.metrics.joins, s.core().prefill_chunks_run)
        };
        let (whole, _, whole_chunks) = run(false);
        let (chunked, joins, lane_chunks) = run(true);
        assert_eq!(whole_chunks, 0);
        assert_eq!(lane_chunks, 12, "48-token prompt = 12 lane chunks");
        assert!(joins >= 1, "the long prompt must JOIN, not form a group");
        assert_eq!(whole.len(), 5);
        assert_eq!(chunked.len(), 5);
        for id in 0..5u64 {
            assert_eq!(chunked[&id].tokens, whole[&id].tokens, "tokens diverge, id {id}");
            assert_eq!(chunked[&id].stats.drafted, whole[&id].stats.drafted, "id {id}");
            assert_eq!(chunked[&id].stats.accepted, whole[&id].stats.accepted, "id {id}");
            assert_eq!(
                chunked[&id].stats.prefix_hist, whole[&id].stats.prefix_hist,
                "id {id}"
            );
        }
    }

    /// Under the chunked lane a radix prefix hit skips whole chunks of
    /// COMPUTE: `prefill_tokens_saved` counts exactly the chunk-aligned
    /// cached prefix (never the final chunk, whose logits seed the
    /// first sampled token), and the lane executes only the remainder.
    #[test]
    fn chunked_prefill_skips_cached_chunks_compute() {
        let shared: Vec<i32> = (300..316).collect(); // 16 tokens = 4 chunks
        let core = sim().with_chunked_prefill(4);
        let mut s = Scheduler::new(core, cfg(64))
            .with_paged_kv(paged_cfg(32))
            .with_chunked_prefill(arb(4, 4));
        // Bootstrap cohort (keeper + first shared-prompt session):
        // whole-prompt prefill, 2 + 16 tokens of compute, zero saved.
        s.submit(vec![1, 7], 40).unwrap();
        s.submit(shared.clone(), 6).unwrap();
        let _ = s.tick(Instant::now()).unwrap();
        assert_eq!(s.metrics.prefill_tokens, 18);
        assert_eq!(s.metrics.prefill_tokens_saved, 0);
        // The second shared-prompt session JOINS: its whole prompt is
        // cache-resident, but the final chunk still runs — 12 tokens of
        // compute skipped, exactly one chunk executed.
        s.submit(shared.clone(), 6).unwrap();
        let out = drain(&mut s, 10_000);
        assert_eq!(out.len(), 3);
        assert_eq!(s.metrics.prefill_tokens_saved, 12);
        assert_eq!(s.metrics.prefill_tokens, 18 + 4);
        assert_eq!(s.core().prefill_chunks_run, 1);
        assert_eq!(s.metrics.prefill_chunks, 1);
        assert!(s.metrics.prefill_lane_rounds >= 1);
        let text = s.metrics.render("sim");
        assert!(text.contains("lkspec_sched_prefill_chunks_total{engine=\"sim\"} 1"));
    }

    /// The arbiter's bound is HARD: no tick runs more prefill chunks
    /// than `max_chunks_per_round`, and the decode cadence advances
    /// every tick while the long prompt amortizes through the lane.
    #[test]
    fn prefill_lane_never_exceeds_chunk_budget_per_tick() {
        let core = sim().with_chunked_prefill(4);
        let mut s = Scheduler::new(core, cfg(64)).with_chunked_prefill(arb(4, 2));
        s.submit(vec![1, 7], 60).unwrap(); // keeper: decodes throughout
        let _ = s.tick(Instant::now()).unwrap();
        let id = s.submit((200..248).collect(), 4).unwrap(); // 12 chunks
        let mut done = Vec::new();
        let mut ticks = 0;
        while !done.iter().any(|(i, _)| *i == id) {
            let chunks0 = s.core().prefill_chunks_run;
            let rounds0 = s.core().rounds_run;
            done.extend(s.tick(Instant::now()).unwrap());
            assert!(
                s.core().prefill_chunks_run - chunks0 <= 2,
                "lane exceeded the per-round chunk budget"
            );
            assert!(
                s.core().rounds_run > rounds0,
                "decode round stalled behind the lane"
            );
            ticks += 1;
            assert!(ticks < 1000, "long prompt never completed");
        }
        assert_eq!(s.core().prefill_chunks_run, 12);
        assert!(s.metrics.prefill_lane_rounds >= 6, "12 chunks at <= 2/round");
    }

    /// ChaosCore for the lane: a session-fatal fault during a prefill
    /// chunk evicts ONLY the prefilling session — decoding rows are
    /// bit-equal to an unfaulted run, and the slot + paged blocks free.
    #[test]
    fn prefill_lane_fault_evicts_only_prefilling_session() {
        let run = |fail: Option<u64>| {
            let core = sim().with_chunked_prefill(4);
            let mut s = Scheduler::new(core, cfg(64))
                .with_paged_kv(paged_cfg(64))
                .with_chunked_prefill(arb(4, 2));
            s.submit(vec![1, 7], 30).unwrap();
            let _ = s.tick(Instant::now()).unwrap();
            s.core_mut().fail_prefill_at = fail;
            let long_id = s.submit((200..248).collect(), 8).unwrap();
            let mut got = BTreeMap::new();
            let mut failures = Vec::new();
            let mut ticks = 0;
            while !s.is_idle() {
                for (id, r) in s.tick(Instant::now()).unwrap() {
                    got.insert(id, r);
                }
                failures.extend(s.take_failures());
                ticks += 1;
                assert!(ticks < 10_000, "chaos run did not converge");
            }
            (got, failures, long_id, s)
        };
        let (clean, none, _, _) = run(None);
        assert!(none.is_empty());
        let (got, failures, long_id, s) = run(Some(3));
        assert_eq!(failures.len(), 1, "exactly the prefilling session fails");
        assert_eq!(failures[0].0, long_id);
        assert!(
            matches!(&failures[0].1, RequestError::SessionFault(m) if m.contains("prefill")),
            "got: {:?}",
            failures[0].1
        );
        assert!(!got.contains_key(&long_id));
        assert_eq!(got[&0].tokens, clean[&0].tokens, "survivor tokens shifted");
        assert_eq!(s.metrics.session_faults, 1);
        assert_eq!(s.core().faults_injected, 1);
        assert_eq!(s.paged_kv().unwrap().sessions(), 0);
    }
}
