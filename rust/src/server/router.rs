//! Request router: a thread-backed front-end around one scheduler
//! worker.
//!
//! The engine (and everything PJRT) is deliberately single-threaded and
//! !Send, so the router owns it inside a dedicated worker thread
//! (leader/worker shape). Clients submit requests through a bounded
//! channel (backpressure) and receive results on per-request reply
//! channels. The worker loop drives a `Scheduler` over the engine's
//! `SchedulerCore` face: drain the submit channel, tick the scheduler
//! (admit / join / decode round / harvest), reply per finished session —
//! results stream back as sessions finish, not when their group does.
//!
//! tokio is unavailable offline (DESIGN.md §2); std threads + mpsc
//! channels implement the same event-loop shape.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::BatcherConfig;
use super::engine::RequestResult;
use super::kv::PagedKvConfig;
use super::scheduler::{Scheduler, SchedulerCore};

pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub reply: mpsc::Sender<Result<RequestResult, String>>,
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
    /// Poll interval of the worker loop when idle.
    pub idle_poll: Duration,
    /// Paged-KV admission (block pool + radix prefix cache). `Some` —
    /// the default — bounds resident KV to the block budget and shares
    /// identical prompt prefixes across sessions; `None` keeps the
    /// legacy unbounded slot-mapped admission.
    pub paged_kv: Option<PagedKvConfig>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            batcher: BatcherConfig::default(),
            idle_poll: Duration::from_millis(1),
            paged_kv: Some(PagedKvConfig::default()),
        }
    }
}

enum Msg {
    Submit(Request),
    Shutdown,
}

/// Client handle; cheap to clone (multiple submitters).
pub struct Router {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Router {
    /// Spawn the worker. `make_core` runs INSIDE the worker thread and
    /// builds the decode core there (PJRT types never cross threads); a
    /// `Scheduler` wraps it for continuous batching. `SpecEngine`
    /// implements `SchedulerCore`, so the typical factory returns the
    /// engine directly.
    pub fn spawn<F, C>(cfg: RouterConfig, make_core: F) -> Result<Router>
    where
        F: FnOnce() -> Result<C> + Send + 'static,
        C: SchedulerCore + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.batcher.queue_cap);
        let worker = std::thread::Builder::new()
            .name("lkspec-engine".into())
            .spawn(move || {
                let core = match make_core() {
                    Ok(c) => c,
                    Err(e) => {
                        // Drain & fail every request until shutdown.
                        let msg = format!("engine init failed: {e:#}");
                        while let Ok(m) = rx.recv() {
                            match m {
                                Msg::Submit(req) => {
                                    let _ = req.reply.send(Err(msg.clone()));
                                }
                                Msg::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                let mut sched = Scheduler::new(core, cfg.batcher.clone());
                if let Some(kv) = cfg.paged_kv {
                    sched = sched.with_paged_kv(kv);
                }
                let mut replies: HashMap<u64, mpsc::Sender<Result<RequestResult, String>>> =
                    HashMap::new();
                let mut shutdown = false;
                loop {
                    // Admit what's queued (non-blocking drain).
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Submit(req)) => {
                                match sched.submit(req.prompt, req.max_new) {
                                    Ok(id) => {
                                        replies.insert(id, req.reply);
                                    }
                                    // Per-request verdicts (queue full /
                                    // oversized for the KV pool): fail
                                    // ONLY this request — every other
                                    // session keeps decoding.
                                    Err(e) => {
                                        let _ = req.reply.send(Err(e.to_string()));
                                    }
                                }
                            }
                            Ok(Msg::Shutdown) => {
                                shutdown = true;
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        }
                    }
                    match sched.tick(Instant::now()) {
                        Ok(done) => {
                            for (id, res) in done {
                                if let Some(reply) = replies.remove(&id) {
                                    let _ = reply.send(Ok(res));
                                }
                            }
                        }
                        Err(e) => {
                            // Engine fault: fail everything in flight or
                            // queued, reset, and keep serving — a fresh
                            // group may still succeed.
                            let msg = format!("engine error: {e:#}");
                            for (_, reply) in replies.drain() {
                                let _ = reply.send(Err(msg.clone()));
                            }
                            sched.reset();
                        }
                    }
                    if shutdown && sched.is_idle() {
                        break;
                    }
                    // Sleep whenever no group is decoding — idle, or
                    // queued requests waiting out the batching window —
                    // so partial-bucket waits don't busy-spin a core.
                    if sched.in_flight() == 0 {
                        std::thread::sleep(cfg.idle_poll);
                    }
                }
            })
            .context("spawning engine worker")?;
        Ok(Router {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<Result<RequestResult, String>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(Request {
                prompt,
                max_new,
                reply,
            }))
            .context("router worker gone")?;
        Ok(rx)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::scheduler::SimCore;

    fn cfg() -> RouterConfig {
        RouterConfig {
            batcher: BatcherConfig {
                buckets: vec![1, 4],
                max_wait: Duration::from_millis(1),
                queue_cap: 16,
            },
            idle_poll: Duration::from_micros(200),
            ..Default::default()
        }
    }

    /// Router logic is engine-agnostic: test with the simulated core.
    /// SimCore echoes `prompt[j % len] + 1000` as token j.
    #[test]
    fn routes_and_replies_per_session() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx1 = router.submit(vec![1, 2], 8).unwrap();
        let rx2 = router.submit(vec![3, 4], 8).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(r1.tokens[..2], [1001, 1002]);
        assert_eq!(r2.tokens[..2], [1003, 1004]);
        assert_eq!(r1.tokens.len(), 8);
        assert_eq!(r2.tokens.len(), 8);
        assert!(r1.latency_ms >= 0.0 && r1.ttft_ms >= 0.0);
        router.shutdown();
    }

    /// Sessions with different lengths come back as they finish, and a
    /// late request is still served by the same worker.
    #[test]
    fn streams_results_as_sessions_finish() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 11, vec![1, 4]))).unwrap();
        let rx_short = router.submit(vec![1, 2], 3).unwrap();
        let rx_long = router.submit(vec![5, 6], 48).unwrap();
        let short = rx_short
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(short.tokens.len(), 3);
        // Submit after the first result: joins or forms a new group.
        let rx_late = router.submit(vec![8, 9], 4).unwrap();
        let late = rx_late
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(late.tokens[..1], [1008]);
        let long = rx_long
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(long.tokens.len(), 48);
        router.shutdown();
    }

    /// An oversized request — worst-case KV footprint beyond the whole
    /// paged pool (default 256 blocks x 16 tokens) — fails ONLY itself:
    /// it is rejected at submit, never surfaced as a tick-level engine
    /// fault, so concurrent sessions run to completion and the worker
    /// keeps serving.
    #[test]
    fn oversized_request_fails_only_itself() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx_ok = router.submit(vec![1, 2], 8).unwrap();
        let rx_big = router.submit(vec![3, 4], 100_000).unwrap();
        let big = rx_big.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = big.unwrap_err();
        assert!(err.contains("KV blocks"), "got: {err}");
        let ok = rx_ok.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(ok.tokens.len(), 8, "concurrent session must survive");
        // The worker is still healthy: a later request is served too.
        let rx_late = router.submit(vec![5, 6], 4).unwrap();
        let late = rx_late.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(late.is_ok());
        router.shutdown();
    }

    #[test]
    fn engine_init_failure_propagates() {
        let router = Router::spawn(RouterConfig::default(), || {
            Err::<SimCore, _>(anyhow::anyhow!("boom"))
        })
        .unwrap();
        let rx = router.submit(vec![1, 2], 4).unwrap();
        let res = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(res.is_err());
        assert!(res.unwrap_err().contains("boom"));
        router.shutdown();
    }
}
