//! Request router: a thread-backed front-end around one scheduler
//! worker.
//!
//! The engine (and everything PJRT) is deliberately single-threaded and
//! !Send, so the router owns it inside a dedicated worker thread
//! (leader/worker shape). Clients submit requests through a bounded
//! channel (backpressure) and receive results on per-request reply
//! channels. The worker loop drives a `Scheduler` over the engine's
//! `SchedulerCore` face: drain the submit channel, tick the scheduler
//! (admit / join / decode round / harvest), reply per finished session —
//! results stream back as sessions finish, not when their group does.
//!
//! STREAMING. [`Router::submit_stream`] opens an incremental per-session
//! [`Event`] channel instead of the one-shot reply: `Queued` on
//! admission, a `Tokens` delta whenever the decode loop commits tokens
//! (fed by [`Scheduler::take_token_events`]), then exactly one terminal
//! `Done`/`Fault`. The HTTP edge (`server::http`) turns this into SSE.
//! A dropped event receiver reads as a vanished client: the worker
//! auto-cancels the session so its slot and paged-KV blocks free
//! mid-flight. The one-shot [`Router::submit`] path is kept as an
//! aggregating adapter over the same machinery — exactly one terminal
//! [`Reply`] per submission, as before.
//!
//! FAILURE MODEL (DESIGN.md §9). Every reply channel carries a typed
//! `Result<RequestResult, RequestError>`: per-request refusals
//! (backpressure, oversized, invalid, draining) and per-session faults
//! (session-fatal eviction, deadline expiry, cancellation) fail ONLY
//! their own request. A `tick` error is by contract ENGINE-FATAL — the
//! worker fails everything in flight with `RequestError::EngineFault`,
//! resets the scheduler (fresh paged-KV pool), and keeps serving.
//! `shutdown`/`drain` are graceful: accepted work finishes, new submits
//! get `RequestError::ShuttingDown`.
//!
//! tokio is unavailable offline (DESIGN.md §2); std threads + mpsc
//! channels implement the same event-loop shape.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::adapt::AdaptConfig;
use super::batcher::BatcherConfig;
use super::engine::RequestResult;
use super::fault::RequestError;
use super::kv::PagedKvConfig;
use super::scheduler::{FaultConfig, Scheduler, SchedulerCore};

/// One reply: exactly one message per accepted submission.
pub type Reply = std::result::Result<RequestResult, RequestError>;

/// One incremental event on a streaming submission's channel. The
/// grammar per session is `Queued (Tokens)* (Done | Fault)` — exactly
/// one terminal event, unless the submission was refused before
/// admission, in which case a lone `Fault` is the whole stream.
#[derive(Debug)]
pub enum Event {
    /// Accepted into the scheduler's bounded queue.
    Queued,
    /// Newly committed tokens — a delta. Per session, the concatenated
    /// deltas equal the terminal result's `tokens` exactly (the
    /// one-shot reply is byte-identical to the stream).
    Tokens(Vec<i32>),
    /// Terminal: the session completed; carries the same
    /// [`RequestResult`] the one-shot path returns.
    Done(RequestResult),
    /// Terminal: the session failed with a typed verdict.
    Fault(RequestError),
}

/// Where a submission's outcome goes: the legacy one-shot channel, or
/// an incremental [`Event`] stream.
pub enum ReplyTo {
    /// Exactly one terminal [`Reply`]; token deltas are aggregated into
    /// the final [`RequestResult`].
    OneShot(mpsc::Sender<Reply>),
    /// `Queued`, then token deltas as the decode loop commits them,
    /// then exactly one terminal event.
    Stream(mpsc::Sender<Event>),
}

impl ReplyTo {
    fn queued(&self) {
        if let ReplyTo::Stream(tx) = self {
            let _ = tx.send(Event::Queued);
        }
    }

    /// Forward a token delta. Returns false when the receiver is gone —
    /// a vanished streaming client; the worker auto-cancels the session
    /// so a dead connection cannot pin a slot. (One-shot receivers only
    /// take the terminal reply, so deltas are a no-op and "delivered".)
    fn tokens(&self, delta: Vec<i32>) -> bool {
        match self {
            ReplyTo::OneShot(_) => true,
            ReplyTo::Stream(tx) => tx.send(Event::Tokens(delta)).is_ok(),
        }
    }

    fn finish(&self, res: RequestResult) {
        match self {
            ReplyTo::OneShot(tx) => {
                let _ = tx.send(Ok(res));
            }
            ReplyTo::Stream(tx) => {
                let _ = tx.send(Event::Done(res));
            }
        }
    }

    fn fail(&self, err: RequestError) {
        match self {
            ReplyTo::OneShot(tx) => {
                let _ = tx.send(Err(err));
            }
            ReplyTo::Stream(tx) => {
                let _ = tx.send(Event::Fault(err));
            }
        }
    }
}

pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Absolute deadline; past it the request is shed (queued or
    /// mid-flight) with a `DeadlineExceeded` verdict.
    pub deadline: Option<Instant>,
    pub reply: ReplyTo,
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
    /// Poll interval of the worker loop when idle.
    pub idle_poll: Duration,
    /// Paged-KV admission (block pool + radix prefix cache). `Some` —
    /// the default — bounds resident KV to the block budget and shares
    /// identical prompt prefixes across sessions; `None` keeps the
    /// legacy unbounded slot-mapped admission.
    pub paged_kv: Option<PagedKvConfig>,
    /// Chunked-prefill lane (DESIGN.md §11). `None` = auto: the lane
    /// arms whenever the engine's artifacts carry the chunk entry.
    /// `Some(0)` turns it off (whole-prompt joins). `Some(n)` pins the
    /// expected chunk length: a mismatch with the lowered entry leaves
    /// the lane off rather than running with a wrong cost model.
    pub prefill_chunk: Option<usize>,
    /// Max prefill chunks the arbiter may spend per scheduler tick
    /// under queue pressure. 0 disables the lane.
    pub prefill_budget: usize,
    /// Transient-fault retry budget + backoff for the scheduler's
    /// containment ladder.
    pub fault: FaultConfig,
    /// Online-adaptation loop (DESIGN.md §12): harvest live acceptance
    /// verdicts, background LK fine-tunes, draft hot-swaps between
    /// rounds. `None` — the default — serves with fixed draft weights.
    pub adapt: Option<AdaptConfig>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            batcher: BatcherConfig::default(),
            idle_poll: Duration::from_millis(1),
            paged_kv: Some(PagedKvConfig::default()),
            prefill_chunk: None,
            prefill_budget: 4,
            fault: FaultConfig::default(),
            adapt: None,
        }
    }
}

enum Msg {
    /// Ticket (router-level id, the `cancel` handle) + request.
    Submit(u64, Request),
    Cancel(u64),
    /// Render the scheduler's metrics text into the given channel.
    Metrics(mpsc::Sender<String>),
    Shutdown,
}

/// Handle for one accepted one-shot submission.
pub struct Submission {
    /// Router-level ticket — pass to [`Router::cancel`].
    pub id: u64,
    /// Carries exactly one TERMINAL [`Reply`] — the aggregating adapter
    /// over the event stream: token deltas are folded into the final
    /// [`RequestResult`], so the channel yields a single message, then
    /// disconnects. (`one_shot_reply_is_exactly_one_message` pins this;
    /// use [`Router::submit_stream`] for per-token events.)
    pub rx: mpsc::Receiver<Reply>,
}

/// Handle for one accepted STREAMING submission.
pub struct StreamSubmission {
    /// Router-level ticket — pass to [`Router::cancel`].
    pub id: u64,
    /// Carries `Queued (Tokens)* (Done | Fault)` — see [`Event`].
    pub rx: mpsc::Receiver<Event>,
}

/// Client handle (multiple submitter threads may share it behind an Arc).
pub struct Router {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_ticket: AtomicU64,
}

impl Router {
    /// Spawn the worker. `make_core` runs INSIDE the worker thread and
    /// builds the decode core there (PJRT types never cross threads); a
    /// `Scheduler` wraps it for continuous batching. `SpecEngine`
    /// implements `SchedulerCore`, so the typical factory returns the
    /// engine directly.
    pub fn spawn<F, C>(cfg: RouterConfig, make_core: F) -> Result<Router>
    where
        F: FnOnce() -> Result<C> + Send + 'static,
        C: SchedulerCore + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.batcher.queue_cap);
        let worker = std::thread::Builder::new()
            .name("lkspec-engine".into())
            .spawn(move || {
                let core = match make_core() {
                    Ok(c) => c,
                    Err(e) => {
                        // Drain & fail every request until shutdown.
                        let err = RequestError::EngineInit(format!("{e:#}"));
                        while let Ok(m) = rx.recv() {
                            match m {
                                Msg::Submit(_, req) => req.reply.fail(err.clone()),
                                Msg::Cancel(_) => {}
                                Msg::Metrics(tx) => {
                                    let _ = tx.send(format!(
                                        "# engine init failed: {err}\n"
                                    ));
                                }
                                Msg::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                // Chunked-prefill lane: arbiter priced by the core's own
                // cost model; off when disabled, unsupported, or the
                // operator-pinned chunk length mismatches the artifacts.
                let arbiter = match (cfg.prefill_chunk, cfg.prefill_budget) {
                    (Some(0), _) | (_, 0) => None,
                    (want, budget) => core
                        .prefill_arbiter(budget)
                        .filter(|a| want.map_or(true, |w| a.cfg().chunk == w)),
                };
                let mut sched =
                    Scheduler::new(core, cfg.batcher.clone()).with_fault_config(cfg.fault);
                if let Some(kv) = cfg.paged_kv {
                    sched = sched.with_paged_kv(kv);
                }
                if let Some(arb) = arbiter {
                    sched = sched.with_chunked_prefill(arb);
                }
                if let Some(adapt) = cfg.adapt {
                    sched = sched.with_adaptation(adapt);
                }
                // ticket -> scheduler session id, and session id ->
                // (ticket, reply channel); both purge on the verdict.
                let mut tickets: HashMap<u64, u64> = HashMap::new();
                let mut replies: HashMap<u64, (u64, ReplyTo)> = HashMap::new();
                let mut shutdown = false;
                loop {
                    // Admit what's queued (non-blocking drain). Channel
                    // order is FIFO, so a client that submits and then
                    // cancels always finds its ticket mapped.
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Submit(ticket, req)) => {
                                match sched.submit_with(req.prompt, req.max_new, req.deadline) {
                                    Ok(id) => {
                                        req.reply.queued();
                                        tickets.insert(ticket, id);
                                        replies.insert(id, (ticket, req.reply));
                                    }
                                    // Per-request refusals (queue full /
                                    // oversized / invalid / draining):
                                    // fail ONLY this request — every
                                    // other session keeps decoding.
                                    Err(e) => req.reply.fail(e.into()),
                                }
                            }
                            Ok(Msg::Cancel(ticket)) => {
                                // Unknown / already-answered tickets are
                                // a no-op by design.
                                if let Some(&id) = tickets.get(&ticket) {
                                    sched.cancel(id);
                                }
                            }
                            Ok(Msg::Metrics(tx)) => {
                                let mut text = sched.metrics.render("router");
                                text.push_str(&format!(
                                    "lkspec_sched_queue_depth{{engine=\"router\"}} {}\n",
                                    sched.pending()
                                ));
                                if let Some(driver) = sched.adapt() {
                                    text.push_str(&driver.metrics.render("router"));
                                }
                                let _ = tx.send(text);
                            }
                            Ok(Msg::Shutdown) => {
                                // Graceful: refuse new work, flush the
                                // queue without waiting out the batching
                                // window, finish what is in flight. The
                                // channel stays open — post-drain
                                // submits get typed refusals.
                                sched.drain();
                                shutdown = true;
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                sched.drain();
                                shutdown = true;
                                break;
                            }
                        }
                    }
                    match sched.tick(Instant::now()) {
                        Ok(done) => {
                            // Token deltas BEFORE terminal events, so a
                            // stream's last delta precedes its Done. A
                            // failed send means the event receiver is
                            // gone — the streaming client vanished —
                            // and the session auto-cancels (slot +
                            // paged-KV blocks free on the next tick).
                            for (id, delta) in sched.take_token_events() {
                                if let Some((_, reply)) = replies.get(&id) {
                                    if !reply.tokens(delta) {
                                        sched.cancel(id);
                                    }
                                }
                            }
                            for (id, res) in done {
                                if let Some((ticket, reply)) = replies.remove(&id) {
                                    tickets.remove(&ticket);
                                    reply.finish(res);
                                }
                            }
                            // Typed per-session verdicts: session-fatal
                            // evictions, deadline expiries, cancels.
                            for (id, err) in sched.take_failures() {
                                if let Some((ticket, reply)) = replies.remove(&id) {
                                    tickets.remove(&ticket);
                                    reply.fail(err);
                                }
                            }
                        }
                        Err(e) => {
                            // `tick` errors are engine-fatal by
                            // contract: fail everything in flight or
                            // queued, reset (fresh paged-KV pool), and
                            // keep serving — a fresh group may still
                            // succeed.
                            let err = RequestError::EngineFault(format!("{e:#}"));
                            for (_, (_, reply)) in replies.drain() {
                                reply.fail(err.clone());
                            }
                            tickets.clear();
                            sched.reset();
                        }
                    }
                    if shutdown && sched.is_idle() {
                        break;
                    }
                    // Sleep whenever no group is decoding — idle, or
                    // queued requests waiting out the batching window —
                    // so partial-bucket waits don't busy-spin a core.
                    if sched.in_flight() == 0 {
                        std::thread::sleep(cfg.idle_poll);
                    }
                }
                // Stragglers racing the exit: answer anything still in
                // the channel instead of dropping it with the receiver.
                while let Ok(m) = rx.try_recv() {
                    if let Msg::Submit(_, req) = m {
                        req.reply.fail(RequestError::ShuttingDown);
                    }
                }
            })
            .context("spawning engine worker")?;
        Ok(Router {
            tx,
            worker: Some(worker),
            next_ticket: AtomicU64::new(0),
        })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> Result<mpsc::Receiver<Reply>> {
        self.submit_with(prompt, max_new, None).map(|s| s.rx)
    }

    /// Submit with an optional absolute deadline; the returned
    /// [`Submission`] carries the ticket [`Router::cancel`] takes.
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> Result<Submission> {
        let id = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                id,
                Request {
                    prompt,
                    max_new,
                    deadline,
                    reply: ReplyTo::OneShot(reply),
                },
            ))
            .context("router worker gone")?;
        Ok(Submission { id, rx })
    }

    /// Submit a STREAMING request: the returned channel carries
    /// [`Event`]s — `Queued` on admission, `Tokens` deltas as the
    /// decode loop commits them, then exactly one terminal
    /// `Done`/`Fault`. Dropping the receiver mid-stream cancels the
    /// session (the worker treats an undeliverable delta as a vanished
    /// client), freeing its slot and paged-KV blocks.
    pub fn submit_stream(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> Result<StreamSubmission> {
        let id = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(
                id,
                Request {
                    prompt,
                    max_new,
                    deadline,
                    reply: ReplyTo::Stream(reply),
                },
            ))
            .context("router worker gone")?;
        Ok(StreamSubmission { id, rx })
    }

    /// Scheduler metrics rendered in Prometheus text format (plus a
    /// live `lkspec_sched_queue_depth` gauge), fetched from the worker
    /// thread. Waits at most `timeout` — the worker answers between
    /// decode rounds — and errors if the worker is gone or busy past
    /// the deadline (the HTTP edge maps that to 503).
    pub fn metrics_text(&self, timeout: Duration) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Metrics(tx))
            .context("router worker gone")?;
        rx.recv_timeout(timeout)
            .context("router worker did not answer the metrics probe")
    }

    /// Cancel a submission by ticket. Best-effort and idempotent: a
    /// request that already finished (or was never accepted) ignores it;
    /// otherwise the reply channel yields `RequestError::Cancelled` and
    /// the session's slot + paged-KV blocks free for reuse.
    pub fn cancel(&self, id: u64) -> Result<()> {
        self.tx
            .send(Msg::Cancel(id))
            .context("router worker gone")?;
        Ok(())
    }

    /// Begin graceful drain WITHOUT blocking: accepted work keeps
    /// decoding to completion, new submits are refused with
    /// `RequestError::ShuttingDown`. Use [`Router::shutdown`] (or drop)
    /// to also join the worker.
    pub fn drain(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }

    /// Graceful shutdown: drain, then join the worker — returns once
    /// every accepted request has been answered.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::scheduler::{FaultPlan, SimCore};

    fn cfg() -> RouterConfig {
        RouterConfig {
            batcher: BatcherConfig {
                buckets: vec![1, 4],
                max_wait: Duration::from_millis(1),
                queue_cap: 16,
            },
            idle_poll: Duration::from_micros(200),
            ..Default::default()
        }
    }

    /// A fault config with zero backoff so injected transient storms
    /// don't slow the test suite down.
    fn fast_faults() -> FaultConfig {
        FaultConfig {
            transient_retries: 3,
            backoff: Duration::ZERO,
        }
    }

    /// Router logic is engine-agnostic: test with the simulated core.
    /// SimCore echoes `prompt[j % len] + 1000` as token j.
    #[test]
    fn routes_and_replies_per_session() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx1 = router.submit(vec![1, 2], 8).unwrap();
        let rx2 = router.submit(vec![3, 4], 8).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(r1.tokens[..2], [1001, 1002]);
        assert_eq!(r2.tokens[..2], [1003, 1004]);
        assert_eq!(r1.tokens.len(), 8);
        assert_eq!(r2.tokens.len(), 8);
        assert!(r1.latency_ms >= 0.0 && r1.ttft_ms >= 0.0);
        router.shutdown();
    }

    /// Sessions with different lengths come back as they finish, and a
    /// late request is still served by the same worker.
    #[test]
    fn streams_results_as_sessions_finish() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 11, vec![1, 4]))).unwrap();
        let rx_short = router.submit(vec![1, 2], 3).unwrap();
        let rx_long = router.submit(vec![5, 6], 48).unwrap();
        let short = rx_short
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(short.tokens.len(), 3);
        // Submit after the first result: joins or forms a new group.
        let rx_late = router.submit(vec![8, 9], 4).unwrap();
        let late = rx_late
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(late.tokens[..1], [1008]);
        let long = rx_long
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(long.tokens.len(), 48);
        router.shutdown();
    }

    /// An oversized request — worst-case KV footprint beyond the whole
    /// paged pool (default 256 blocks x 16 tokens) — fails ONLY itself:
    /// it is rejected at submit, never surfaced as a tick-level engine
    /// fault, so concurrent sessions run to completion and the worker
    /// keeps serving.
    #[test]
    fn oversized_request_fails_only_itself() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx_ok = router.submit(vec![1, 2], 8).unwrap();
        let rx_big = router.submit(vec![3, 4], 100_000).unwrap();
        let big = rx_big.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = big.unwrap_err();
        assert!(matches!(err, RequestError::TooLarge { .. }), "got: {err}");
        assert!(err.to_string().contains("KV blocks"), "got: {err}");
        let ok = rx_ok.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(ok.tokens.len(), 8, "concurrent session must survive");
        // The worker is still healthy: a later request is served too.
        let rx_late = router.submit(vec![5, 6], 4).unwrap();
        let late = rx_late.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(late.is_ok());
        router.shutdown();
    }

    #[test]
    fn engine_init_failure_propagates() {
        let router = Router::spawn(RouterConfig::default(), || {
            Err::<SimCore, _>(anyhow::anyhow!("boom"))
        })
        .unwrap();
        let rx = router.submit(vec![1, 2], 4).unwrap();
        let res = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = res.unwrap_err();
        assert!(matches!(err, RequestError::EngineInit(_)), "got: {err}");
        assert!(err.to_string().contains("boom"));
        router.shutdown();
    }

    /// Graceful drain: work accepted before shutdown completes; a
    /// submit racing in after it gets the typed refusal, not a dead
    /// channel. The long max_wait pins the order: the queued request
    /// only dispatches once the drain flush bypasses the batching
    /// window, so it is provably still in flight when the late submit
    /// arrives (channel order is FIFO).
    #[test]
    fn drain_completes_inflight_and_rejects_new() {
        let mut c = cfg();
        c.batcher.max_wait = Duration::from_secs(1000);
        let router = Router::spawn(c, || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx_a = router.submit(vec![1, 2], 48).unwrap();
        router.drain();
        let rx_late = router.submit(vec![3, 4], 4).unwrap();
        let late = rx_late.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(late.unwrap_err(), RequestError::ShuttingDown);
        let a = rx_a.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(a.tokens.len(), 48, "accepted work must finish under drain");
        router.shutdown();
    }

    /// A session-fatal fault fails ONLY the offending session; its
    /// group-mates complete with the exact tokens an unfaulted run
    /// yields (SimCore emissions are position-deterministic).
    #[test]
    fn session_fatal_fails_only_that_session() {
        let mut c = cfg();
        c.fault = fast_faults();
        let router = Router::spawn(c, || {
            Ok(SimCore::new(4, 7, vec![1, 4])
                .with_fault_plan(FaultPlan::default().session_fatal_at(1, 1)))
        })
        .unwrap();
        // Four submits -> scheduler session ids 0..4; the plan kills 1.
        let rxs: Vec<_> = (0..4)
            .map(|i| router.submit(vec![10 * (i + 1), 2], 8).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let res = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if i == 1 {
                let err = res.unwrap_err();
                assert!(matches!(err, RequestError::SessionFault(_)), "got: {err}");
                assert!(err.to_string().contains("injected"), "got: {err}");
            } else {
                let r = res.unwrap();
                assert_eq!(r.tokens.len(), 8, "survivor {i} must complete");
                assert_eq!(r.tokens[0], 10 * (i as i32 + 1) + 1000);
            }
        }
        router.shutdown();
    }

    /// An engine-fatal fault fails everything in flight with a typed
    /// verdict, then the worker resets — rebuilding the paged-KV pool —
    /// and serves fresh requests to completion.
    #[test]
    fn engine_fatal_fails_inflight_then_recovers() {
        let mut c = cfg();
        c.fault = fast_faults();
        let router = Router::spawn(c, || {
            Ok(SimCore::new(4, 7, vec![1, 4])
                .with_fault_plan(FaultPlan::default().engine_fatal_at(1)))
        })
        .unwrap();
        let rx1 = router.submit(vec![1, 2], 16).unwrap();
        let rx2 = router.submit(vec![3, 4], 16).unwrap();
        for rx in [rx1, rx2] {
            let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
            assert!(matches!(err, RequestError::EngineFault(_)), "got: {err}");
        }
        // The reset rebuilt the pool: a fresh request decodes fine.
        let rx = router.submit(vec![5, 6], 8).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(r.tokens[..2], [1005, 1006]);
        router.shutdown();
    }

    /// Cancel by ticket: the reply channel yields the typed verdict and
    /// the worker keeps serving other sessions.
    #[test]
    fn cancel_midflight_returns_cancelled() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let keep = router.submit(vec![1, 2], 8).unwrap();
        // Inside the default pool (256 blocks x 16 tokens) but far
        // beyond what could finish before the cancel lands right behind
        // it on the FIFO channel.
        let doomed = router.submit_with(vec![3, 4], 2000, None).unwrap();
        router.cancel(doomed.id).unwrap();
        let err = doomed
            .rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap_err();
        assert_eq!(err, RequestError::Cancelled);
        let ok = keep.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(ok.tokens.len(), 8);
        // Cancelling a finished ticket is a no-op, not an error.
        router.cancel(doomed.id).unwrap();
        router.shutdown();
    }

    /// A deadline in the past is shed with the typed verdict before any
    /// prefill is spent on it.
    #[test]
    fn expired_deadline_returns_typed_verdict() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let sub = router
            .submit_with(vec![1, 2], 8, Some(Instant::now() - Duration::from_millis(5)))
            .unwrap();
        let err = sub
            .rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap_err();
        assert_eq!(err, RequestError::DeadlineExceeded);
        // The worker is unharmed.
        let rx = router.submit(vec![5, 6], 4).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        router.shutdown();
    }

    /// An empty prompt bounces off the front door with a typed invalid
    /// verdict (core-level validation), never reaching the engine.
    #[test]
    fn invalid_prompt_rejected_at_submit() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx = router.submit(vec![], 4).unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(matches!(err, RequestError::Invalid(_)), "got: {err}");
        assert!(err.to_string().contains("empty prompt"), "got: {err}");
        router.shutdown();
    }

    /// Satellite regression (streaming refactor): the legacy one-shot
    /// path still delivers EXACTLY one terminal reply — the channel
    /// yields the result, then disconnects, so a second message can
    /// never arrive.
    #[test]
    fn one_shot_reply_is_exactly_one_message() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let rx = router.submit(vec![1, 2], 8).unwrap();
        let first = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.unwrap().tokens.len(), 8);
        // The worker dropped its sender with the terminal reply: the
        // channel is disconnected, not merely empty — no token deltas
        // leaked onto it and nothing else can ever arrive.
        assert_eq!(rx.recv(), Err(mpsc::RecvError));
        router.shutdown();
    }

    /// The streaming tentpole at the router layer: event grammar is
    /// `Queued (Tokens)+ Done`, deltas arrive incrementally (not one
    /// terminal burst), and their concatenation is bit-identical to the
    /// one-shot reply for the same prompt (SimCore emissions are
    /// position-deterministic, so the two submissions agree).
    #[test]
    fn stream_events_match_one_shot_reply() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let sub = router.submit_stream(vec![1, 2], 24, None).unwrap();
        let mut events = Vec::new();
        loop {
            let ev = sub.rx.recv_timeout(Duration::from_secs(5)).unwrap();
            let terminal = matches!(ev, Event::Done(_) | Event::Fault(_));
            events.push(ev);
            if terminal {
                break;
            }
        }
        assert!(matches!(events[0], Event::Queued), "first event is Queued");
        let deltas: Vec<&Vec<i32>> = events
            .iter()
            .filter_map(|e| match e {
                Event::Tokens(t) => Some(t),
                _ => None,
            })
            .collect();
        assert!(deltas.len() > 1, "tokens must stream, not burst");
        let streamed: Vec<i32> = deltas.into_iter().flatten().copied().collect();
        let done = match events.last().unwrap() {
            Event::Done(res) => res,
            other => panic!("expected Done, got {other:?}"),
        };
        assert_eq!(streamed, done.tokens, "deltas concat to the result");
        // After the terminal event the stream disconnects.
        assert!(sub.rx.recv().is_err());
        let oneshot = router
            .submit(vec![1, 2], 24)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(streamed, oneshot.tokens, "stream == one-shot, bit for bit");
        router.shutdown();
    }

    /// Dropping a stream's receiver mid-flight reads as a vanished
    /// client: the worker auto-cancels the session (slot + paged-KV
    /// blocks free) and keeps serving; the cancel shows in the metrics
    /// text fetched from the worker.
    #[test]
    fn dropped_stream_receiver_cancels_session() {
        let router = Router::spawn(cfg(), || Ok(SimCore::new(4, 7, vec![1, 4]))).unwrap();
        let sub = router.submit_stream(vec![3, 4], 2000, None).unwrap();
        // Wait for live streaming, then vanish.
        loop {
            match sub.rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Event::Tokens(_) => break,
                Event::Queued => {}
                other => panic!("unexpected event: {other:?}"),
            }
        }
        drop(sub.rx);
        // The auto-cancel lands within a tick or two; a fresh request
        // completing proves the worker is healthy either way.
        let ok = router
            .submit(vec![5, 6], 8)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(ok.tokens.len(), 8);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let text = router.metrics_text(Duration::from_secs(5)).unwrap();
            if text.contains("lkspec_sched_cancelled_total{engine=\"router\"} 1") {
                assert!(text.contains("lkspec_sched_queue_depth{engine=\"router\"} 0"));
                break;
            }
            assert!(
                Instant::now() < deadline,
                "dropped receiver never cancelled the session:\n{text}"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        router.shutdown();
    }

    /// A transient fault is retried inside the scheduler: no request
    /// observes it — all replies are Ok with full token streams.
    #[test]
    fn transient_fault_invisible_to_clients() {
        let mut c = cfg();
        c.fault = fast_faults();
        let router = Router::spawn(c, || {
            Ok(SimCore::new(4, 7, vec![1, 4])
                .with_fault_plan(FaultPlan::default().transient_at(1, 2)))
        })
        .unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|i| router.submit(vec![i + 1, 2], 8).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(r.tokens.len(), 8);
        }
        router.shutdown();
    }
}
