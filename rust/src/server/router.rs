//! Request router: a thread-backed front-end around one engine worker.
//!
//! The engine (and everything PJRT) is deliberately single-threaded and
//! !Send, so the router owns it inside a dedicated worker thread
//! (leader/worker shape). Clients submit requests through a bounded
//! channel (backpressure) and receive results on per-request reply
//! channels. The worker loop runs the batcher policy: drain the queue,
//! group by bucket, run lockstep groups, reply.
//!
//! tokio is unavailable offline (DESIGN.md §2); std threads + mpsc
//! channels implement the same event-loop shape.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherConfig};
use super::engine::RequestResult;

pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub reply: mpsc::Sender<Result<RequestResult, String>>,
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
    /// Poll interval of the worker loop when idle.
    pub idle_poll: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            batcher: BatcherConfig::default(),
            idle_poll: Duration::from_millis(1),
        }
    }
}

enum Msg {
    Submit(Request),
    Shutdown,
}

/// Client handle; cheap to clone (multiple submitters).
pub struct Router {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Router {
    /// Spawn the worker. `make_engine` runs INSIDE the worker thread and
    /// builds the engine there (PJRT types never cross threads). It
    /// receives nothing and returns a closure that executes one group:
    /// `run_group(prompts, max_new) -> Result<Vec<RequestResult>>`.
    pub fn spawn<F, G>(cfg: RouterConfig, make_engine: F) -> Result<Router>
    where
        F: FnOnce() -> Result<G> + Send + 'static,
        G: FnMut(&[Vec<i32>], usize) -> Result<Vec<RequestResult>>,
    {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.batcher.queue_cap);
        let worker = std::thread::Builder::new()
            .name("lkspec-engine".into())
            .spawn(move || {
                let mut run_group = match make_engine() {
                    Ok(g) => g,
                    Err(e) => {
                        // Drain & fail every request until shutdown.
                        let msg = format!("engine init failed: {e:#}");
                        while let Ok(m) = rx.recv() {
                            match m {
                                Msg::Submit(req) => {
                                    let _ = req.reply.send(Err(msg.clone()));
                                }
                                Msg::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                let mut batcher: Batcher<Request> = Batcher::new(cfg.batcher.clone());
                let mut shutdown = false;
                loop {
                    // Admit what's queued (non-blocking drain).
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Submit(req)) => {
                                if let Err(req) = batcher.push(req) {
                                    let _ = req
                                        .reply
                                        .send(Err("queue full (backpressure)".into()));
                                }
                            }
                            Ok(Msg::Shutdown) => {
                                shutdown = true;
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        }
                    }
                    if let Some(group) = batcher.next_group(Instant::now()) {
                        let prompts: Vec<Vec<i32>> =
                            group.iter().map(|r| r.prompt.clone()).collect();
                        let max_new =
                            group.iter().map(|r| r.max_new).max().unwrap_or(16);
                        match run_group(&prompts, max_new) {
                            Ok(results) => {
                                for (req, res) in group.into_iter().zip(results) {
                                    let _ = req.reply.send(Ok(res));
                                }
                            }
                            Err(e) => {
                                let msg = format!("engine error: {e:#}");
                                for req in group {
                                    let _ = req.reply.send(Err(msg.clone()));
                                }
                            }
                        }
                        continue; // check queue again immediately
                    }
                    if shutdown && batcher.is_empty() {
                        break;
                    }
                    std::thread::sleep(cfg.idle_poll);
                }
            })
            .context("spawning engine worker")?;
        Ok(Router {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<Result<RequestResult, String>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(Request {
                prompt,
                max_new,
                reply,
            }))
            .context("router worker gone")?;
        Ok(rx)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::accept::AcceptanceStats;

    /// Router logic is engine-agnostic: test with a stub group runner.
    #[test]
    fn routes_and_replies_in_order() {
        let cfg = RouterConfig {
            batcher: BatcherConfig {
                buckets: vec![1, 4],
                max_wait: Duration::from_millis(1),
                queue_cap: 16,
            },
            idle_poll: Duration::from_micros(200),
        };
        let router = Router::spawn(cfg, || {
            Ok(move |prompts: &[Vec<i32>], max_new: usize| {
                Ok(prompts
                    .iter()
                    .map(|p| RequestResult {
                        tokens: p.iter().map(|t| t + 1000).take(max_new).collect(),
                        stats: AcceptanceStats::new(4),
                        latency_ms: 0.1,
                        rounds: 1,
                    })
                    .collect())
            })
        })
        .unwrap();
        let rx1 = router.submit(vec![1, 2], 8).unwrap();
        let rx2 = router.submit(vec![3, 4], 8).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(r1.tokens, vec![1001, 1002]);
        assert_eq!(r2.tokens, vec![1003, 1004]);
        router.shutdown();
    }

    #[test]
    fn engine_init_failure_propagates() {
        let router = Router::spawn(RouterConfig::default(), || {
            Err::<fn(&[Vec<i32>], usize) -> Result<Vec<RequestResult>>, _>(anyhow::anyhow!(
                "boom"
            ))
        })
        .unwrap();
        let rx = router.submit(vec![1, 2], 4).unwrap();
        let res = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(res.is_err());
        assert!(res.unwrap_err().contains("boom"));
        router.shutdown();
    }
}
