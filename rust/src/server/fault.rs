//! Typed engine faults and per-request error verdicts — the failure
//! model of the serving stack (DESIGN.md §9).
//!
//! The old contract was stringly: any `anyhow` error a core surfaced
//! from a decode round aborted EVERY in-flight session and reset the
//! engine. This module types the blast radius instead:
//!
//!   * [`FaultKind::Transient`]  — the round failed but group state is
//!     intact (rounds are atomic on failure); retry with bounded
//!     backoff, degrading device verify to the host path if it is the
//!     device that keeps failing.
//!   * [`FaultKind::SessionFatal`] — one session's state is gone; evict
//!     only that row (slot + paged-KV blocks freed, typed reply).
//!   * [`FaultKind::EngineFatal`] — the engine itself (device, caches,
//!     artifacts) is unrecoverable; the router fails in-flight work,
//!     resets, and keeps serving fresh groups.
//!
//! Cores keep returning `anyhow::Error` — an [`EngineError`] rides
//! inside it and the scheduler recovers it by downcast. An error
//! WITHOUT a typed fault classifies as `EngineFatal`: an unknown blast
//! radius must be treated as the widest one.
//!
//! [`RequestError`] is the client-facing half: the typed verdict a
//! request's reply channel carries when the request fails for any
//! reason (backpressure, admission, faults, deadlines, cancellation,
//! shutdown).

use std::fmt;

use super::scheduler::SubmitError;

/// Blast radius of an engine fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The round failed but the group is intact; retry is safe.
    Transient,
    /// Exactly one session is unrecoverable; the rest of the group is
    /// untouched.
    SessionFatal,
    /// The engine is unrecoverable; only this kind may reach the
    /// router's fail-everything path.
    EngineFatal,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Transient => write!(f, "transient"),
            FaultKind::SessionFatal => write!(f, "session-fatal"),
            FaultKind::EngineFatal => write!(f, "engine-fatal"),
        }
    }
}

/// A typed engine fault, carried inside `anyhow::Error` so core
/// signatures stay unchanged; the scheduler recovers it with
/// [`EngineError::of`] / [`EngineError::classify`].
#[derive(Clone, Debug)]
pub struct EngineError {
    pub kind: FaultKind,
    /// The offending session for session-fatal faults. A session-fatal
    /// fault WITHOUT a live session id cannot be contained and is
    /// handled as engine-fatal.
    pub session: Option<u64>,
    pub msg: String,
}

impl EngineError {
    pub fn transient(msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(EngineError {
            kind: FaultKind::Transient,
            session: None,
            msg: msg.into(),
        })
    }

    pub fn session_fatal(session: u64, msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(EngineError {
            kind: FaultKind::SessionFatal,
            session: Some(session),
            msg: msg.into(),
        })
    }

    pub fn engine_fatal(msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(EngineError {
            kind: FaultKind::EngineFatal,
            session: None,
            msg: msg.into(),
        })
    }

    /// The typed fault inside `err`, if any (walks the context chain).
    pub fn of(err: &anyhow::Error) -> Option<&EngineError> {
        err.downcast_ref::<EngineError>()
    }

    /// Blast radius of `err`. Untyped errors classify as
    /// [`FaultKind::EngineFatal`]: an unknown radius is the widest one.
    pub fn classify(err: &anyhow::Error) -> FaultKind {
        Self::of(err).map_or(FaultKind::EngineFatal, |e| e.kind)
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.session {
            Some(id) => write!(f, "{} fault (session {id}): {}", self.kind, self.msg),
            None => write!(f, "{} fault: {}", self.kind, self.msg),
        }
    }
}

impl std::error::Error for EngineError {}

/// Client-facing request verdict: why a single request failed. This is
/// what a router reply channel carries instead of an opaque string —
/// callers can branch on the variant (retry on `QueueFull`, surface
/// `DeadlineExceeded` as HTTP 504, …).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// Backpressure: the bounded queue is full; retry later.
    QueueFull,
    /// Worst-case KV footprint exceeds the whole paged pool; the
    /// request can never be admitted at any load.
    TooLarge {
        blocks_needed: usize,
        pool_blocks: usize,
    },
    /// The core refused the request's shape (empty / overlong prompt).
    Invalid(String),
    /// The router is draining: accepted work is being finished, new
    /// work is refused.
    ShuttingDown,
    /// A session-fatal engine fault killed this session; every other
    /// session kept decoding.
    SessionFault(String),
    /// The request missed its deadline (shed queued or mid-flight).
    DeadlineExceeded,
    /// Cancelled via the router's `cancel` handle.
    Cancelled,
    /// An engine-fatal fault failed everything in flight; the engine
    /// reset and keeps serving new requests.
    EngineFault(String),
    /// The engine never came up.
    EngineInit(String),
}

impl RequestError {
    /// HTTP status code for this verdict — THE verdict→status table of
    /// the edge contract (DESIGN.md §10). Client-caused refusals map to
    /// 4xx, server conditions to 5xx; `Cancelled` uses 499 (client
    /// closed request, nginx convention) because the only way a live
    /// session cancels through the HTTP edge is its client vanishing.
    pub fn http_status(&self) -> u16 {
        match self {
            RequestError::QueueFull => 429,
            RequestError::TooLarge { .. } => 413,
            RequestError::Invalid(_) => 400,
            RequestError::ShuttingDown => 503,
            RequestError::DeadlineExceeded => 504,
            RequestError::Cancelled => 499,
            RequestError::SessionFault(_)
            | RequestError::EngineFault(_)
            | RequestError::EngineInit(_) => 500,
        }
    }
}

impl From<SubmitError> for RequestError {
    fn from(e: SubmitError) -> RequestError {
        match e {
            SubmitError::QueueFull(_) => RequestError::QueueFull,
            SubmitError::TooLarge {
                blocks_needed,
                pool_blocks,
            } => RequestError::TooLarge {
                blocks_needed,
                pool_blocks,
            },
            SubmitError::Invalid { reason } => RequestError::Invalid(reason),
            SubmitError::Draining => RequestError::ShuttingDown,
        }
    }
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RequestError::QueueFull => write!(f, "queue full (backpressure)"),
            RequestError::TooLarge {
                blocks_needed,
                pool_blocks,
            } => write!(
                f,
                "request needs {blocks_needed} KV blocks but the pool holds \
                 {pool_blocks} (raise --kv-blocks or shrink the prompt/max_new)"
            ),
            RequestError::Invalid(reason) => write!(f, "invalid request: {reason}"),
            RequestError::ShuttingDown => write!(f, "router shutting down (drain)"),
            RequestError::SessionFault(msg) => write!(f, "session fault: {msg}"),
            RequestError::DeadlineExceeded => write!(f, "deadline exceeded"),
            RequestError::Cancelled => write!(f, "cancelled"),
            RequestError::EngineFault(msg) => write!(f, "engine error: {msg}"),
            RequestError::EngineInit(msg) => write!(f, "engine init failed: {msg}"),
        }
    }
}

impl std::error::Error for RequestError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_walks_the_context_chain() {
        let e = EngineError::transient("watchdog reset");
        assert_eq!(EngineError::classify(&e), FaultKind::Transient);
        // Context wrapping must not erase the typed fault.
        let wrapped = e.context("while verifying group");
        assert_eq!(EngineError::classify(&wrapped), FaultKind::Transient);
        assert!(EngineError::of(&wrapped).is_some());
    }

    #[test]
    fn untyped_errors_are_engine_fatal() {
        let e = anyhow::anyhow!("somebody forgot to type this");
        assert_eq!(EngineError::classify(&e), FaultKind::EngineFatal);
        assert!(EngineError::of(&e).is_none());
    }

    #[test]
    fn session_fatal_names_the_session() {
        let e = EngineError::session_fatal(42, "row NaN'd");
        let ee = EngineError::of(&e).unwrap();
        assert_eq!(ee.kind, FaultKind::SessionFatal);
        assert_eq!(ee.session, Some(42));
        assert!(e.to_string().contains("session 42"), "got: {e}");
    }

    /// The verdict→status table (DESIGN.md §10): every variant maps,
    /// client refusals to 4xx, server conditions to 5xx.
    #[test]
    fn verdicts_map_to_http_statuses() {
        assert_eq!(RequestError::QueueFull.http_status(), 429);
        assert_eq!(
            RequestError::TooLarge {
                blocks_needed: 9,
                pool_blocks: 4
            }
            .http_status(),
            413
        );
        assert_eq!(RequestError::Invalid("empty".into()).http_status(), 400);
        assert_eq!(RequestError::ShuttingDown.http_status(), 503);
        assert_eq!(RequestError::DeadlineExceeded.http_status(), 504);
        assert_eq!(RequestError::Cancelled.http_status(), 499);
        assert_eq!(RequestError::SessionFault("x".into()).http_status(), 500);
        assert_eq!(RequestError::EngineFault("x".into()).http_status(), 500);
        assert_eq!(RequestError::EngineInit("x".into()).http_status(), 500);
    }

    #[test]
    fn request_error_from_submit_error() {
        assert_eq!(
            RequestError::from(SubmitError::QueueFull(vec![1])),
            RequestError::QueueFull
        );
        assert_eq!(
            RequestError::from(SubmitError::Draining),
            RequestError::ShuttingDown
        );
        let e = RequestError::from(SubmitError::TooLarge {
            blocks_needed: 9,
            pool_blocks: 4,
        });
        assert!(e.to_string().contains("KV blocks"));
    }
}
