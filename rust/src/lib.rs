//! # lk-spec
//!
//! Reproduction of "LK Losses: Direct Acceptance Rate Optimization for
//! Speculative Decoding" (ICML 2026) as a three-layer Rust + JAX + Pallas
//! system: a speculator **training framework** with the LK loss family as
//! first-class objectives, and a speculative-decoding **serving engine**
//! (pluggable `DraftBackend` architectures, continuous-batching
//! scheduler with mid-flight join/leave over slot-mapped KV rows and
//! long-tail bucket downshift, an online speculation controller picking
//! each round's draft budget from measured acceptance, exact rejection
//! sampling). Python/JAX only ever runs at build time
//! (`python3 -m compile.aot`); every runtime path is Rust driving
//! AOT-compiled XLA executables through PJRT.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

#[macro_use]
pub mod util;

pub mod tensor;

pub mod runtime;

/// Re-export for examples/benches.
pub use anyhow;

pub mod data;

pub mod spec;

pub mod config;

pub mod train;

pub mod server;

pub mod eval;

pub mod bench;
