//! Configuration system: loss specifications, training presets and the
//! experiment plan that maps every paper table/figure to concrete runs
//! (DESIGN.md §5). Benches and the CLI both consume this module so the
//! sweep definitions live in exactly one place.

use anyhow::{bail, Result};

/// Paper §5.3 defaults.
pub const LEARNING_RATE: f64 = 4e-4;
pub const WARMUP_STEPS: usize = 100;
pub const GAMMA: f64 = 0.8;
pub const DEFAULT_ETA: f64 = 3.0;

/// A draft-training objective: weights over (KL, TV, L_LK^α, L_LK^λ)
/// plus the adaptive-schedule temperature η (paper §4.2/4.3).
#[derive(Clone, Debug, PartialEq)]
pub struct LossSpec {
    /// short stable identifier used in file names and result tables
    pub tag: String,
    /// pretty name for reports (matches the paper's notation)
    pub label: String,
    pub weights: [f32; 4],
    pub eta: f32,
}

impl LossSpec {
    pub fn kl() -> LossSpec {
        LossSpec {
            tag: "kl".into(),
            label: "KL".into(),
            weights: [1.0, 0.0, 0.0, 0.0],
            eta: DEFAULT_ETA as f32,
        }
    }

    pub fn tv() -> LossSpec {
        LossSpec {
            tag: "tv".into(),
            label: "TV".into(),
            weights: [0.0, 1.0, 0.0, 0.0],
            eta: DEFAULT_ETA as f32,
        }
    }

    pub fn lk_alpha() -> LossSpec {
        LossSpec {
            tag: "lka".into(),
            label: "L_LK^alpha".into(),
            weights: [0.0, 0.0, 1.0, 0.0],
            eta: DEFAULT_ETA as f32,
        }
    }

    pub fn lk_lambda(eta: f64) -> LossSpec {
        LossSpec {
            tag: format!("lkl-eta{}", trim_num(eta)),
            label: format!("L_LK^lambda (eta={})", trim_num(eta)),
            weights: [0.0, 0.0, 0.0, 1.0],
            eta: eta as f32,
        }
    }

    /// Fixed-mixture ablation λ=const: λ·KL + (1−λ)·TV (§6.1).
    pub fn lk_fixed(lambda: f64) -> LossSpec {
        LossSpec {
            tag: format!("lkl-fixed{}", trim_num(lambda)),
            label: format!("L_LK^lambda (lambda={})", trim_num(lambda)),
            weights: [lambda as f32, 1.0 - lambda as f32, 0.0, 0.0],
            eta: DEFAULT_ETA as f32,
        }
    }

    pub fn parse(s: &str) -> Result<LossSpec> {
        if let Some(rest) = s.strip_prefix("lkl-eta") {
            return Ok(LossSpec::lk_lambda(rest.parse()?));
        }
        if let Some(rest) = s.strip_prefix("lkl-fixed") {
            return Ok(LossSpec::lk_fixed(rest.parse()?));
        }
        match s {
            "kl" => Ok(LossSpec::kl()),
            "tv" => Ok(LossSpec::tv()),
            "lka" => Ok(LossSpec::lk_alpha()),
            other => bail!(
                "unknown loss '{other}' (want kl | tv | lka | lkl-eta<η> | lkl-fixed<λ>)"
            ),
        }
    }
}

fn trim_num(x: f64) -> String {
    let s = format!("{x}");
    s
}

/// Training durations. Paper trains 10 epochs over 660K samples; our
/// single-core budget scales that down while keeping the LR schedule
/// shape (cosine + warmup).
#[derive(Clone, Debug)]
pub struct TrainPreset {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub gamma: f64,
    pub seed: u64,
}

impl TrainPreset {
    pub fn target(target: &str) -> TrainPreset {
        let steps = match target {
            "dense-s" | "moe-s" => 700,
            "dense-m" | "moe-m" => 500,
            _ => 400, // moe-l / mtp-l
        };
        TrainPreset {
            steps,
            lr: 1e-3, // LM pretraining takes a hotter schedule than drafts
            warmup: 60,
            gamma: GAMMA,
            seed: 7,
        }
    }

    pub fn draft(target: &str, arch: &str) -> TrainPreset {
        let steps = match (target, arch) {
            (_, "mtp") => 200, // fine-tuning a pretrained module (1 epoch)
            ("dense-s", _) | ("moe-s", _) => 350,
            _ => 240,
        };
        TrainPreset {
            steps,
            lr: LEARNING_RATE,
            warmup: WARMUP_STEPS.min(steps / 4),
            gamma: GAMMA,
            seed: 11,
        }
    }

    /// Cosine LR with linear warmup (paper §5.3).
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup {
            return self.lr * (step as f64 + 1.0) / self.warmup as f64;
        }
        let t = (step - self.warmup) as f64 / (self.steps - self.warmup).max(1) as f64;
        0.5 * self.lr * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// One experiment cell: which draft checkpoint to evaluate.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub draft: String, // manifest draft name, e.g. "eagle3@dense-s"
    pub loss: LossSpec,
}

impl RunSpec {
    pub fn new(draft: &str, loss: LossSpec) -> RunSpec {
        RunSpec {
            draft: draft.to_string(),
            loss,
        }
    }

    /// Stable checkpoint file stem.
    pub fn stem(&self) -> String {
        format!("{}__{}", self.draft.replace('@', "_"), self.loss.tag)
    }
}

/// MTP "original" pseudo-run: the module as it came out of target
/// pretraining, evaluated without fine-tuning (Table 2's "MTP original").
pub const MTP_ORIGINAL_TAG: &str = "original";

/// The full experiment plan (DESIGN.md §5). Every bench pulls its run
/// list from these functions, so the sweep is defined once.
pub mod plan {
    use super::*;

    /// Table 1: the full objective sweep on the Llama-3.1-8B analog.
    pub fn table1() -> Vec<RunSpec> {
        let mut runs = Vec::new();
        for loss in [
            LossSpec::kl(),
            LossSpec::tv(),
            LossSpec::lk_alpha(),
            LossSpec::lk_fixed(0.5),
            LossSpec::lk_lambda(0.7),
            LossSpec::lk_lambda(1.0),
            LossSpec::lk_lambda(3.0),
            LossSpec::lk_lambda(10.0),
        ] {
            runs.push(RunSpec::new("eagle3@dense-s", loss));
        }
        // Paper uses η=10 for MEDUSA (slower acceptance growth) and η=3
        // for the MLP speculator.
        for loss in [LossSpec::kl(), LossSpec::lk_alpha(), LossSpec::lk_lambda(10.0)] {
            runs.push(RunSpec::new("medusa@dense-s", loss));
        }
        for loss in [LossSpec::kl(), LossSpec::lk_alpha(), LossSpec::lk_lambda(3.0)] {
            runs.push(RunSpec::new("mlp@dense-s", loss));
        }
        runs
    }

    /// Table 2: KL vs LK^λ(η=3) across all six targets (+ MTP rows).
    pub fn table2() -> Vec<RunSpec> {
        let mut runs = Vec::new();
        for target in ["dense-s", "dense-m", "moe-s", "moe-m", "moe-l"] {
            for loss in [LossSpec::kl(), LossSpec::lk_lambda(3.0)] {
                runs.push(RunSpec::new(&format!("eagle3@{target}"), loss));
            }
        }
        for loss in [LossSpec::kl(), LossSpec::lk_lambda(3.0)] {
            runs.push(RunSpec::new("mtp@mtp-l", loss));
        }
        runs
    }

    /// Figure 1: τ vs K for four objectives on the Qwen3-235B analog.
    pub fn fig1() -> Vec<RunSpec> {
        [
            LossSpec::kl(),
            LossSpec::tv(),
            LossSpec::lk_alpha(),
            LossSpec::lk_lambda(3.0),
        ]
        .into_iter()
        .map(|l| RunSpec::new("eagle3@moe-l", l))
        .collect()
    }

    /// Everything that needs a trained checkpoint (deduplicated).
    pub fn all_runs() -> Vec<RunSpec> {
        let mut runs = table1();
        for r in table2().into_iter().chain(fig1()) {
            if !runs.iter().any(|e| e.stem() == r.stem()) {
                runs.push(r);
            }
        }
        runs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_parse_roundtrip() {
        for spec in [
            LossSpec::kl(),
            LossSpec::tv(),
            LossSpec::lk_alpha(),
            LossSpec::lk_lambda(3.0),
            LossSpec::lk_lambda(0.7),
            LossSpec::lk_fixed(0.5),
        ] {
            let re = LossSpec::parse(&spec.tag).unwrap();
            assert_eq!(re, spec);
        }
        assert!(LossSpec::parse("nope").is_err());
    }

    #[test]
    fn lr_schedule_shape() {
        let p = TrainPreset {
            steps: 200,
            lr: 1e-3,
            warmup: 20,
            gamma: 0.8,
            seed: 0,
        };
        assert!(p.lr_at(0) < p.lr_at(10));
        assert!((p.lr_at(19) - 1e-3).abs() < 1e-9);
        assert!(p.lr_at(100) < 1e-3);
        assert!(p.lr_at(199) < p.lr_at(100));
        assert!(p.lr_at(199) >= 0.0);
    }

    #[test]
    fn plan_covers_paper_sweeps() {
        assert_eq!(plan::table1().len(), 8 + 3 + 3);
        assert_eq!(plan::table2().len(), 12);
        assert_eq!(plan::fig1().len(), 4);
        let all = plan::all_runs();
        // dedup leaves: t1(14) + t2 unique(10: dense-s kl/lkl3 already in t1)
        // + fig1 unique(2: moe-l tv/lka)
        assert_eq!(all.len(), 14 + 10 + 2);
        let mut stems: Vec<String> = all.iter().map(|r| r.stem()).collect();
        stems.sort();
        stems.dedup();
        assert_eq!(stems.len(), all.len(), "stems unique");
    }
}
