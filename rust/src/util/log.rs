//! Leveled stderr logging with wall-clock timestamps and scoped timers.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=error 1=warn 2=info 3=debug

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn enabled(level: u8) -> bool {
    LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $tag:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($lvl) {
            eprintln!("[{:>8.2}s {}] {}", $crate::util::log::uptime(), $tag, format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!(2, "info ", $($arg)*) };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::log_at!(1, "warn ", $($arg)*) };
}

#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => { $crate::log_at!(3, "debug", $($arg)*) };
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn uptime() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// RAII timer that logs its scope's duration at debug level.
pub struct ScopeTimer {
    name: &'static str,
    start: Instant,
}

impl ScopeTimer {
    pub fn new(name: &'static str) -> Self {
        ScopeTimer {
            name,
            start: Instant::now(),
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        crate::log_at!(3, "timer", "{}: {:.1} ms", self.name, self.elapsed_ms());
    }
}
