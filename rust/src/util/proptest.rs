//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `forall` runs a property over N generated cases from a seeded Pcg64;
//! on failure it reruns the same case to print it (cases are pure
//! functions of the RNG) and panics with the case index + seed so the
//! exact failure is reproducible. No shrinking — generators are kept
//! small and structured instead.

use super::rng::Pcg64;

pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` generated inputs; panic on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for i in 0..cases {
        let mut rng = Pcg64::new(seed, i as u64);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed}):\n  {msg}\n  case: {case:?}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use super::Pcg64;

    /// Random probability distribution of size `v` with controllable
    /// sharpness (higher = more peaked).
    pub fn dist(rng: &mut Pcg64, v: usize, sharp: f64) -> Vec<f32> {
        let logits: Vec<f64> = (0..v).map(|_| rng.normal() * sharp).collect();
        let m = logits.iter().cloned().fold(f64::MIN, f64::max);
        let exps: Vec<f64> = logits.iter().map(|&z| (z - m).exp()).collect();
        let s: f64 = exps.iter().sum();
        exps.iter().map(|&e| (e / s) as f32).collect()
    }

    pub fn tokens(rng: &mut Pcg64, n: usize, vocab: usize) -> Vec<i32> {
        (0..n).map(|_| rng.below(vocab) as i32).collect()
    }

    pub fn f32s(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_props() {
        forall(
            "below in range",
            1,
            100,
            |rng| (rng.below(17), 17usize),
            |&(x, n)| {
                if x < n {
                    Ok(())
                } else {
                    Err(format!("{x} >= {n}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn forall_reports_failures() {
        forall(
            "must fail",
            2,
            10,
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn dist_generator_normalized() {
        let mut rng = Pcg64::new(3, 0);
        let d = gen::dist(&mut rng, 32, 2.0);
        let s: f32 = d.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(d.iter().all(|&p| p >= 0.0));
    }
}
