//! Streaming statistics used by the trainer, the serving engine's metric
//! registry and the benchmark harness.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Reservoir-free exact percentiles for bounded sample counts (latency
/// distributions in the engine; bench iterations).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// p in [0, 100]; linear interpolation between closest ranks.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = p / 100.0 * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 16.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut whole = OnlineStats::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            whole.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.pct(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(99.0) - 99.01).abs() < 0.02);
    }
}
