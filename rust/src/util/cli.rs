//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `lk-spec <subcommand> [--flag] [--key value]... [positional]...`
//! Flags and options are declared implicitly by access; `finish()` rejects
//! unconsumed arguments so typos fail loudly.

use std::collections::BTreeMap;

/// Boolean flags (never consume a following value). Anything else after
/// `--` is a key expecting a value (`--key value` or `--key=value`).
const KNOWN_FLAGS: &[&str] = &[
    "all",
    "verbose",
    "quiet",
    "greedy-draft",
    "no-spec",
    "no-adaptive",
    "no-prefix-cache",
    "adapt",
    "force",
    "help",
    "fresh",
];

#[derive(Debug)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Args {
        let mut subcommand = None;
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positionals = Vec::new();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&name) {
                    flags.push(name.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    options.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positionals.push(arg);
            }
        }
        Args {
            subcommand,
            options,
            flags,
            positionals,
            consumed: std::cell::RefCell::new(Vec::new()),
        }
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().push(name.to_string());
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.mark(name);
        self.flags.iter().any(|f| f == name)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated list option.
    pub fn opt_list(&self, name: &str) -> Vec<String> {
        self.opt(name)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).collect())
            .unwrap_or_default()
    }

    /// Error on any option/flag that was never consumed (typo guard).
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.options.keys() {
            if !consumed.iter().any(|c| c == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !consumed.iter().any(|c| c == f) {
                anyhow::bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = args("train-draft --arch eagle3 --steps 400 --verbose pos1");
        assert_eq!(a.subcommand.as_deref(), Some("train-draft"));
        assert_eq!(a.opt("arch"), Some("eagle3"));
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 400);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), ["pos1"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = args("x --lr=0.001 --list=a,b,c");
        assert_eq!(a.opt_f64("lr", 0.0).unwrap(), 0.001);
        assert_eq!(a.opt_list("list"), ["a", "b", "c"]);
    }

    /// The HTTP edge flags ride the implicit-declaration grammar:
    /// `--http 127.0.0.1:0` must parse as a value option (colons and
    /// port 0 included), not a flag.
    #[test]
    fn address_values_parse_as_options() {
        let a = args("serve --http 127.0.0.1:0 --max-conns 8 --stream-buffer 4");
        assert_eq!(a.opt("http"), Some("127.0.0.1:0"));
        assert_eq!(a.opt_usize("max-conns", 64).unwrap(), 8);
        assert_eq!(a.opt_usize("stream-buffer", 32).unwrap(), 4);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected() {
        let a = args("x --tpyo 3");
        assert!(a.finish().is_err());
    }
}
