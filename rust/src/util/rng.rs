//! PCG64 (PCG-XSL-RR 128/64) deterministic random number generator.
//!
//! Every stochastic decision in the system — corpus grammars, sampling
//! during serving, rejection draws, property-test case generation — flows
//! through this generator, keyed by explicit seeds, so entire experiment
//! pipelines are bit-reproducible (EXPERIMENTS.md records the seeds).

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xor-shift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create from a seed and a stream id (distinct streams are
    /// statistically independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive a child generator (for per-request / per-sequence streams).
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ salt.rotate_left(17), salt.wrapping_mul(2862933555777941757).wrapping_add(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent s (via rejection
    /// inversion; adequate for the corpus grammars' modest n).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Simple inverse-CDF on precomputable harmonic weights would need
        // state; use rejection sampling against the continuous envelope.
        loop {
            let u = self.uniform();
            let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                let ratio = (k as f64 / x).powf(s);
                if self.uniform() < ratio {
                    return k - 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(1, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Pcg64::new(3, 0);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_matches_weights() {
        let mut r = Pcg64::new(9, 0);
        let w = [1.0f32, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 50_000.0 - 0.6).abs() < 0.02, "{counts:?}");
    }
}
