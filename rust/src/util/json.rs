//! Minimal-but-complete JSON codec (RFC 8259) built from scratch.
//!
//! Used for the artifact manifest, run configs, experiment results and
//! metric logs. Supports full parse/serialize round-tripping, string
//! escapes (incl. \uXXXX surrogate pairs) and precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; returns Null out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Required-field helpers used by manifest/config loading.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    // ------------------------------------------------------------------
    // constructors
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ------------------------------------------------------------------
    // parse
    // ------------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    // ------------------------------------------------------------------
    // serialize
    // ------------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string_pretty())?;
        Ok(())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null (documented lossy behavior).
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let code = 0x10000
                                        + (((hi - 0xD800) as u32) << 10)
                                        + (lo as u32 - 0xDC00);
                                    char::from_u32(code)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi as u32)
                                    .ok_or_else(|| self.err("bad \\u escape"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let txt = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(txt, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("e").as_str(), Some("x\ny"));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn pretty_stable() {
        let v = Json::obj(vec![
            ("z", Json::Num(1.0)),
            ("a", Json::arr_f64(&[1.0, 2.0])),
        ]);
        let s = v.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), v);
        // BTreeMap => keys sorted deterministically
        assert!(s.find("\"a\"").unwrap() < s.find("\"z\"").unwrap());
    }
}
