//! Foundation substrates built from scratch for the offline environment
//! (no serde / clap / rand / criterion in the vendored registry — see
//! DESIGN.md §2): deterministic RNG, JSON codec, CLI parsing, logging
//! and simple streaming statistics.

pub mod cli;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use cli::Args;
pub use json::Json;
pub use rng::Pcg64;
pub use stats::{OnlineStats, Percentiles};
