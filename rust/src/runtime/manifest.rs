//! Parsed view of `artifacts/manifest.json` — the packing contract
//! between the AOT layer (python/compile/aot.py) and this runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::DType;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub group: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<TensorSpec>, // names empty (positional)
}

#[derive(Debug, Clone)]
pub struct TargetSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_experts: usize,
    pub has_mtp: bool,
    pub max_seq: usize,
    pub feat_dim: usize,
    pub params: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

#[derive(Debug, Clone)]
pub struct DraftSpec {
    pub name: String,
    pub arch: String,
    pub target: String,
    pub k_heads: usize,
    pub draft_vocab: usize,
    pub is_recurrent: bool,
    pub fuse_dim: usize,
    pub own_head: bool,
    pub params: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub k_heads: usize,
    pub span: usize,
    pub train_batch: usize,
    pub prompt_len: usize,
    pub verify_t: usize,
    pub serve_batches: Vec<usize>,
    pub draft_vocab: usize,
    pub targets: BTreeMap<String, TargetSpec>,
    pub drafts: BTreeMap<String, DraftSpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for item in j.as_arr().context("expected array of tensor specs")? {
        out.push(TensorSpec {
            name: item.req_str("name")?.to_string(),
            shape: item
                .get("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: DType::parse(item.req_str("dtype")?)?,
        });
    }
    Ok(out)
}

fn entry_spec(j: &Json) -> Result<EntrySpec> {
    let mut inputs = Vec::new();
    for item in j.get("inputs").as_arr().context("inputs")? {
        inputs.push(ArgSpec {
            group: item.req_str("group")?.to_string(),
            shape: item
                .get("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: DType::parse(item.req_str("dtype")?)?,
        });
    }
    let mut outputs = Vec::new();
    for item in j.get("outputs").as_arr().context("outputs")? {
        outputs.push(TensorSpec {
            name: String::new(),
            shape: item
                .get("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: DType::parse(item.req_str("dtype")?)?,
        });
    }
    Ok(EntrySpec {
        file: j.req_str("file")?.to_string(),
        inputs,
        outputs,
    })
}

fn entries_map(j: &Json) -> Result<BTreeMap<String, EntrySpec>> {
    let mut out = BTreeMap::new();
    for (name, e) in j.as_obj().context("entries")? {
        out.insert(name.clone(), entry_spec(e)?);
    }
    Ok(out)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        if j.req_usize("version")? != 1 {
            bail!("unsupported manifest version");
        }
        let mut targets = BTreeMap::new();
        for (name, t) in j.get("targets").as_obj().context("targets")? {
            targets.insert(
                name.clone(),
                TargetSpec {
                    name: name.clone(),
                    vocab: t.req_usize("vocab")?,
                    d_model: t.req_usize("d_model")?,
                    n_layers: t.req_usize("n_layers")?,
                    n_heads: t.req_usize("n_heads")?,
                    head_dim: t.req_usize("head_dim")?,
                    n_experts: t.req_usize("n_experts")?,
                    has_mtp: t.get("has_mtp").as_bool().unwrap_or(false),
                    max_seq: t.req_usize("max_seq")?,
                    feat_dim: t.req_usize("feat_dim")?,
                    params: tensor_specs(t.get("params"))?,
                    entries: entries_map(t.get("entries"))?,
                },
            );
        }
        let mut drafts = BTreeMap::new();
        for (name, d) in j.get("drafts").as_obj().context("drafts")? {
            drafts.insert(
                name.clone(),
                DraftSpec {
                    name: name.clone(),
                    arch: d.req_str("arch")?.to_string(),
                    target: d.req_str("target")?.to_string(),
                    k_heads: d.req_usize("k_heads")?,
                    draft_vocab: d.req_usize("draft_vocab")?,
                    is_recurrent: d.get("is_recurrent").as_bool().unwrap_or(false),
                    fuse_dim: d.req_usize("fuse_dim")?,
                    own_head: d.get("own_head").as_bool().unwrap_or(true),
                    params: tensor_specs(d.get("params"))?,
                    entries: entries_map(d.get("entries"))?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: j.req_usize("vocab")?,
            k_heads: j.req_usize("k_heads")?,
            span: j.req_usize("span")?,
            train_batch: j.req_usize("train_batch")?,
            prompt_len: j.req_usize("prompt_len")?,
            verify_t: j.req_usize("verify_t")?,
            serve_batches: j
                .get("serve_batches")
                .as_arr()
                .context("serve_batches")?
                .iter()
                .map(|b| b.as_usize().unwrap_or(0))
                .collect(),
            draft_vocab: j.req_usize("draft_vocab")?,
            targets,
            drafts,
        })
    }

    pub fn target(&self, name: &str) -> Result<&TargetSpec> {
        self.targets
            .get(name)
            .with_context(|| format!("unknown target '{name}'"))
    }

    pub fn draft(&self, name: &str) -> Result<&DraftSpec> {
        self.drafts
            .get(name)
            .with_context(|| format!("unknown draft '{name}'"))
    }
}
