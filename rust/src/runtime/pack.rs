//! HostTensor <-> xla::Literal packing.

use anyhow::{bail, Context, Result};

use super::TensorSpec;
use crate::tensor::{DType, HostTensor};

fn elem(d: DType) -> xla::ElementType {
    match d {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::U32 => xla::ElementType::U32,
    }
}

pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(elem(t.dtype), &t.shape, &t.data)
        .context("creating literal")
}

pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec, ctx: &str) -> Result<HostTensor> {
    let n: usize = spec.shape.iter().product();
    Ok(match spec.dtype {
        DType::F32 => {
            let mut buf = vec![0f32; n];
            lit.copy_raw_to(&mut buf)
                .with_context(|| format!("{ctx}: copying f32 output"))?;
            HostTensor::from_f32(&spec.shape, &buf)
        }
        DType::I32 => {
            let mut buf = vec![0i32; n];
            lit.copy_raw_to(&mut buf)
                .with_context(|| format!("{ctx}: copying i32 output"))?;
            HostTensor::from_i32(&spec.shape, &buf)
        }
        DType::U32 => {
            let mut buf = vec![0u32; n];
            lit.copy_raw_to(&mut buf)
                .with_context(|| format!("{ctx}: copying u32 output"))?;
            HostTensor::from_u32(&spec.shape, &buf)
        }
    })
}

/// Decompose a tuple literal into host tensors per the output spec.
pub fn from_tuple(
    tuple: xla::Literal,
    outputs: &[TensorSpec],
    ctx: &str,
) -> Result<Vec<HostTensor>> {
    let parts = tuple
        .to_tuple()
        .with_context(|| format!("{ctx}: untupling"))?;
    if parts.len() != outputs.len() {
        bail!(
            "{ctx}: expected {} outputs, tuple has {}",
            outputs.len(),
            parts.len()
        );
    }
    parts
        .iter()
        .zip(outputs)
        .map(|(lit, spec)| from_literal(lit, spec, ctx))
        .collect()
}
