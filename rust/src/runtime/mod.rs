//! XLA/PJRT runtime: loads AOT artifacts (HLO text) and executes them.
//!
//! One `Runtime` owns the PJRT CPU client and a lazy executable cache
//! keyed by artifact file. All I/O crosses the boundary as `HostTensor`
//! (packing in `pack.rs`); callers never touch `xla::Literal` directly.
//!
//! The pattern follows /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `compile` →
//! `execute`, with `return_tuple=True` lowering so every entry returns a
//! single tuple literal that is decomposed positionally against the
//! manifest's output spec.

pub mod manifest;
pub mod pack;

pub use manifest::{ArgSpec, DraftSpec, EntrySpec, Manifest, TargetSpec, TensorSpec};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::tensor::HostTensor;

/// A compiled entrypoint plus its manifest spec.
pub struct Executable {
    pub name: String,
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative execution stats (for the perf pass)
    pub calls: std::cell::Cell<u64>,
    pub exec_ns: std::cell::Cell<u64>,
    /// Bytes materialized for host-side processing through `output_host`
    /// — the architectural device→host transfer measure the serving
    /// metrics report (outputs that flow executable-to-executable as
    /// literals are device-resident by this runtime's convention).
    pub d2h_bytes: std::cell::Cell<u64>,
}

impl Executable {
    /// Execute with device buffers (the hot path). Parameters live as
    /// cached buffers across calls; only dynamic inputs are uploaded.
    ///
    /// Output handling is deliberately SYNCHRONOUS (`to_literal_sync` on
    /// the result tuple): the upstream `execute` entrypoint leaks every
    /// input device buffer (`buffer.release()` without a matching free —
    /// see xla_rs.cc), and un-awaited async executions additionally pile
    /// up retained state. Managing input buffers ourselves via
    /// `execute_b` and forcing completion before returning keeps the
    /// process at a flat RSS (verified by the §Perf leak probes).
    pub fn run_bufs(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.spec.inputs.len(),
            args.len()
        );
        let t0 = Instant::now();
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        self.calls.set(self.calls.get() + 1);
        self.exec_ns
            .set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        tuple
            .to_tuple()
            .with_context(|| format!("{}: untupling", self.name))
    }

    /// Upload a literal to a device buffer on this executable's client.
    pub fn buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.exe
            .client()
            .buffer_from_host_literal(None, lit)
            .with_context(|| format!("{}: uploading input", self.name))
    }

    /// Execute with literal inputs (uploads fresh buffers per call).
    pub fn run_lits(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> =
            args.iter().map(|l| self.buffer(l)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_bufs(&refs)
    }

    /// Fetch output `idx` of a `run_*` result as a host tensor.
    pub fn output_host(&self, outs: &[xla::Literal], idx: usize) -> Result<HostTensor> {
        let t = pack::from_literal(&outs[idx], &self.spec.outputs[idx], &self.name)?;
        self.d2h_bytes.set(self.d2h_bytes.get() + t.data.len() as u64);
        Ok(t)
    }

    /// Execute with host tensors; returns outputs per the manifest spec.
    /// (Training path — full shape validation, host round-trip.)
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = &self.spec;
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            spec.inputs.len(),
            args.len()
        );
        for (i, (a, s)) in args.iter().zip(&spec.inputs).enumerate() {
            anyhow::ensure!(
                a.shape == s.shape && a.dtype == s.dtype,
                "{}: input {i} ({}) mismatch: got {:?} {:?}, want {:?} {:?}",
                self.name,
                s.group,
                a.dtype,
                a.shape,
                s.dtype,
                s.shape
            );
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(pack::to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let outs = self.run_lits(&refs)?;
        outs.iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| pack::from_literal(lit, ospec, &self.name))
            .collect()
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
    pub compile_ns: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(BTreeMap::new()),
            compile_ns: std::cell::Cell::new(0),
        })
    }

    /// Load (compile) one entry, memoized by artifact file name.
    pub fn load(&self, spec: &EntrySpec, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(&spec.file) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.compile_ns
            .set(self.compile_ns.get() + t0.elapsed().as_nanos() as u64);
        crate::debug_log!(
            "compiled {} in {:.0} ms",
            spec.file,
            t0.elapsed().as_secs_f64() * 1e3
        );
        let e = Rc::new(Executable {
            name: name.to_string(),
            spec: spec.clone(),
            exe,
            calls: std::cell::Cell::new(0),
            exec_ns: std::cell::Cell::new(0),
            d2h_bytes: std::cell::Cell::new(0),
        });
        self.cache.borrow_mut().insert(spec.file.clone(), e.clone());
        Ok(e)
    }

    /// Upload a literal to a device buffer (engine state/param caching).
    pub fn to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .context("uploading buffer")
    }

    /// Convenience: load a target entry by (target, entry) names.
    pub fn target_entry(&self, target: &str, entry: &str) -> Result<Rc<Executable>> {
        let t = self.manifest.target(target)?;
        let spec = t
            .entries
            .get(entry)
            .with_context(|| format!("target {target} has no entry '{entry}'"))?;
        self.load(spec, &format!("tgt:{target}:{entry}"))
    }

    /// Convenience: load a draft entry by (draft, entry) names.
    pub fn draft_entry(&self, draft: &str, entry: &str) -> Result<Rc<Executable>> {
        let d = self.manifest.draft(draft)?;
        let spec = d
            .entries
            .get(entry)
            .with_context(|| format!("draft {draft} has no entry '{entry}'"))?;
        self.load(spec, &format!("dr:{draft}:{entry}"))
    }

    /// Does `target` carry an entry by this name? Artifact sets lowered
    /// before a feature existed simply lack its entries; callers gate
    /// optional device paths on this and fall back to the host path.
    pub fn has_target_entry(&self, target: &str, entry: &str) -> bool {
        self.manifest
            .targets
            .get(target)
            .is_some_and(|t| t.entries.contains_key(entry))
    }

    pub fn has_draft_entry(&self, draft: &str, entry: &str) -> bool {
        self.manifest
            .drafts
            .get(draft)
            .is_some_and(|d| d.entries.contains_key(entry))
    }

    /// Total bytes materialized host-side via `output_host` across all
    /// cached executables — the engine samples this around each decode
    /// round for the `bytes_to_host_per_round` metric.
    pub fn d2h_bytes_total(&self) -> u64 {
        self.cache
            .borrow()
            .values()
            .map(|e| e.d2h_bytes.get())
            .sum()
    }

    /// Execution-time accounting across all cached executables (perf pass).
    pub fn exec_report(&self) -> Vec<(String, u64, f64)> {
        let mut rows: Vec<(String, u64, f64)> = self
            .cache
            .borrow()
            .values()
            .map(|e| (e.name.clone(), e.calls.get(), e.exec_ns.get() as f64 / 1e6))
            .filter(|(_, c, _)| *c > 0)
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }
}
