//! Evaluation harness: computes the paper's metrics for trained
//! speculators — average acceptance length τ (§5.5), per-position
//! acceptance rates, and wall-clock speedup vs vanilla autoregressive
//! decoding (Table 4) — and caches every cell as JSON under
//! `runs/results/` so benches regenerate tables without re-running.

use std::path::Path;

use anyhow::{Context, Result};

use crate::data::corpus::Corpus;
use crate::data::grammar::Domain;
use crate::runtime::Runtime;
use crate::server::engine::{AdaptiveOpts, EngineOpts, SpecEngine};
use crate::spec::accept::AcceptanceStats;
use crate::spec::sampling::SamplingMode;
use crate::tensor::read_checkpoint;
use crate::train::RunDirs;
use crate::util::Json;

/// Evaluation temperature/sampling setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalMode {
    T0,
    T1,
    T1GreedyDraft, // Appendix D ablation
}

impl EvalMode {
    pub fn tag(&self) -> &'static str {
        match self {
            EvalMode::T0 => "t0",
            EvalMode::T1 => "t1",
            EvalMode::T1GreedyDraft => "t1gd",
        }
    }

    pub fn parse(s: &str) -> Result<EvalMode> {
        match s {
            "t0" => Ok(EvalMode::T0),
            "t1" => Ok(EvalMode::T1),
            "t1gd" => Ok(EvalMode::T1GreedyDraft),
            other => anyhow::bail!("unknown eval mode '{other}' (t0|t1|t1gd)"),
        }
    }

    pub fn sampling(&self) -> SamplingMode {
        match self {
            EvalMode::T0 => SamplingMode::Greedy,
            EvalMode::T1 => SamplingMode::Stochastic,
            EvalMode::T1GreedyDraft => SamplingMode::GreedyDraft,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvalSettings {
    pub n_prompts: usize,
    pub n_time_prompts: usize, // batch-1 timed subset (Table 4)
    pub prompt_len: usize,
    pub max_new: usize,
    pub seed: u64,
    pub measure_speedup: bool,
}

impl Default for EvalSettings {
    fn default() -> Self {
        EvalSettings {
            n_prompts: 16,
            n_time_prompts: 3,
            prompt_len: 16,
            max_new: 40,
            seed: 2024,
            measure_speedup: true,
        }
    }
}

/// One result cell (one draft × loss × domain × mode × K).
#[derive(Clone, Debug)]
pub struct Cell {
    pub tau: f64,
    pub alpha_pos: Vec<f64>,
    pub spec_tps: f64,
    pub vanilla_tps: f64,
    pub speedup: f64,
}

pub fn cell_name(stem: &str, domain: Domain, mode: EvalMode, k: usize) -> String {
    format!("{stem}__{}__{}__k{k}", domain.name(), mode.tag())
}

/// Evaluate one cell; reuses the cached JSON if present (pass
/// `force = true` to re-run).
#[allow(clippy::too_many_arguments)]
pub fn eval_cell(
    rt: &Runtime,
    dirs: &RunDirs,
    corpus: &Corpus,
    draft: &str,
    loss_tag: &str,
    domain: Domain,
    mode: EvalMode,
    k: usize,
    settings: &EvalSettings,
    force: bool,
) -> Result<Cell> {
    let stem = format!("{}__{}", draft.replace('@', "_"), loss_tag);
    let path = dirs.results(&cell_name(&stem, domain, mode, k));
    if path.exists() && !force {
        return read_cell(&path);
    }

    let dspec = rt.manifest.draft(draft)?.clone();
    let tckpt = read_checkpoint(&dirs.target_ckpt(&dspec.target))
        .with_context(|| format!("target checkpoint for {draft}"))?;
    let dckpt = read_checkpoint(&dirs.draft_ckpt(&stem))
        .with_context(|| format!("draft checkpoint {stem}"))?;
    let vocab_map = if dspec.arch == "eagle3" {
        let j = Json::parse_file(&dirs.vocab_map())?;
        Some(
            j.get("map")
                .as_arr()
                .context("vocab map")?
                .iter()
                .map(|x| x.as_i64().unwrap_or(0) as i32)
                .collect::<Vec<i32>>(),
        )
    } else {
        None
    };

    let opts = EngineOpts {
        k_draft: k,
        temperature: 1.0,
        mode: mode.sampling(),
        seed: settings.seed,
        // The paper protocol studies FIXED draft budgets: the cell is
        // parameterized by k, so the controller must not adapt it.
        adaptive: AdaptiveOpts::fixed(),
        ..Default::default()
    };
    let mut engine = SpecEngine::new(rt, draft, &tckpt, &dckpt, vocab_map, opts)?;

    let ds = corpus.load(domain, "eval")?;
    let prompts = ds.prompts(settings.n_prompts, settings.prompt_len);
    anyhow::ensure!(!prompts.is_empty(), "no eval prompts for {domain:?}");

    // --- τ over all prompts, batched in groups of 4 -----------------------
    let mut stats = AcceptanceStats::new(engine.k_draft());
    for chunk in prompts.chunks(4) {
        let results = engine.generate_batch(chunk, settings.max_new)?;
        for r in &results {
            stats.merge(&r.stats);
        }
    }

    // --- timed batch-1 subset (Table 4 low-latency setting) ---------------
    let (mut spec_tps, mut vanilla_tps) = (0.0, 0.0);
    if settings.measure_speedup {
        let timed = &prompts[..settings.n_time_prompts.min(prompts.len())];
        let mut spec_tokens = 0usize;
        let mut spec_secs = 0f64;
        for p in timed {
            let r = &engine.generate_batch(std::slice::from_ref(p), settings.max_new)?[0];
            spec_tokens += r.tokens.len();
            spec_secs += r.latency_ms / 1e3;
        }
        let mut van_tokens = 0usize;
        let mut van_secs = 0f64;
        for p in timed {
            let r = engine.generate_vanilla(p, settings.max_new)?;
            van_tokens += r.tokens.len();
            van_secs += r.latency_ms / 1e3;
        }
        spec_tps = spec_tokens as f64 / spec_secs.max(1e-9);
        vanilla_tps = van_tokens as f64 / van_secs.max(1e-9);
    }

    let cell = Cell {
        tau: stats.tau(),
        alpha_pos: stats.alpha_per_position(),
        spec_tps,
        vanilla_tps,
        speedup: if vanilla_tps > 0.0 {
            spec_tps / vanilla_tps
        } else {
            0.0
        },
    };
    write_cell(&path, &cell)?;
    crate::info!(
        "cell {stem} {} {} k{k}: tau={:.3} speedup={:.2}",
        domain.name(),
        mode.tag(),
        cell.tau,
        cell.speedup
    );
    Ok(cell)
}

fn write_cell(path: &Path, c: &Cell) -> Result<()> {
    Json::obj(vec![
        ("tau", Json::Num(c.tau)),
        ("alpha_pos", Json::arr_f64(&c.alpha_pos)),
        ("spec_tps", Json::Num(c.spec_tps)),
        ("vanilla_tps", Json::Num(c.vanilla_tps)),
        ("speedup", Json::Num(c.speedup)),
    ])
    .write_file(path)
}

pub fn read_cell(path: &Path) -> Result<Cell> {
    let j = Json::parse_file(path)?;
    Ok(Cell {
        tau: j.req_f64("tau")?,
        alpha_pos: j
            .get("alpha_pos")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0))
            .collect(),
        spec_tps: j.req_f64("spec_tps")?,
        vanilla_tps: j.req_f64("vanilla_tps")?,
        speedup: j.req_f64("speedup")?,
    })
}

/// Try to read a cached cell without recomputing (for benches).
pub fn cached_cell(
    dirs: &RunDirs,
    draft: &str,
    loss_tag: &str,
    domain: Domain,
    mode: EvalMode,
    k: usize,
) -> Option<Cell> {
    let stem = format!("{}__{}", draft.replace('@', "_"), loss_tag);
    let path = dirs.results(&cell_name(&stem, domain, mode, k));
    read_cell(&path).ok()
}
