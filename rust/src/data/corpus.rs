//! Corpus files and training/eval datasets.
//!
//! On-disk format per (domain, split): `<dir>/<domain>.<split>.tok` —
//! header "LKC1" + u32 count + count × i32 LE tokens (documents are
//! EOS-terminated, concatenated back-to-back). Deliberately flat so the
//! batcher can sample windows with zero parsing.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::grammar::{Domain, DOMAINS};
use super::{BOS, EOS};
use crate::util::Pcg64;

const MAGIC: &[u8; 4] = b"LKC1";

/// Generation settings for one corpus build.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub seed: u64,
    pub train_tokens: usize,
    pub eval_docs: usize,
    pub doc_len: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            seed: 0xC0FFEE,
            train_tokens: 400_000,
            eval_docs: 64,
            doc_len: 160,
        }
    }
}

/// A generated corpus directory.
pub struct Corpus {
    pub dir: PathBuf,
}

impl Corpus {
    /// Generate all (domain × {train, eval}) files under `dir`.
    pub fn generate(dir: &Path, spec: &CorpusSpec) -> Result<Corpus> {
        std::fs::create_dir_all(dir)?;
        for (di, domain) in DOMAINS.iter().enumerate() {
            // independent streams per (domain, split)
            let mut rng = Pcg64::new(spec.seed, (di as u64) * 2 + 1);
            let mut train = Vec::with_capacity(spec.train_tokens + spec.doc_len);
            while train.len() < spec.train_tokens {
                train.extend(domain.generate(&mut rng, spec.doc_len));
            }
            write_tokens(&dir.join(format!("{}.train.tok", domain.name())), &train)?;

            let mut rng = Pcg64::new(spec.seed, (di as u64) * 2 + 2);
            let mut eval = Vec::new();
            for _ in 0..spec.eval_docs {
                eval.extend(domain.generate(&mut rng, spec.doc_len));
            }
            write_tokens(&dir.join(format!("{}.eval.tok", domain.name())), &eval)?;
        }
        crate::info!("generated corpus at {}", dir.display());
        Ok(Corpus { dir: dir.to_path_buf() })
    }

    pub fn open(dir: &Path) -> Result<Corpus> {
        for d in DOMAINS {
            let p = dir.join(format!("{}.train.tok", d.name()));
            if !p.exists() {
                bail!(
                    "corpus file {} missing — run `lk-spec gen-data` first",
                    p.display()
                );
            }
        }
        Ok(Corpus { dir: dir.to_path_buf() })
    }

    pub fn load(&self, domain: Domain, split: &str) -> Result<Dataset> {
        let path = self.dir.join(format!("{}.{split}.tok", domain.name()));
        Ok(Dataset {
            domain,
            tokens: read_tokens(&path)?,
        })
    }

    /// Equal-parts mixture of all domains' training streams.
    pub fn load_mixture(&self, split: &str) -> Result<Vec<Dataset>> {
        DOMAINS.iter().map(|&d| self.load(d, split)).collect()
    }
}

fn write_tokens(path: &Path, tokens: &[i32]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(tokens.len() as u32).to_le_bytes())?;
    for &t in tokens {
        f.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

fn read_tokens(path: &Path) -> Result<Vec<i32>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an LKC1 corpus", path.display());
    }
    let mut cnt = [0u8; 4];
    f.read_exact(&mut cnt)?;
    let n = u32::from_le_bytes(cnt) as usize;
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// One domain's token stream with window/prompt sampling.
pub struct Dataset {
    pub domain: Domain,
    pub tokens: Vec<i32>,
}

impl Dataset {
    /// Sample a [b, w] batch of training windows (flattened row-major).
    /// Windows are uniform random offsets into the stream; a BOS is
    /// prepended so every window starts from a defined state.
    pub fn sample_batch(&self, rng: &mut Pcg64, b: usize, w: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * w);
        for _ in 0..b {
            let start = rng.below(self.tokens.len().saturating_sub(w));
            out.push(BOS);
            out.extend_from_slice(&self.tokens[start..start + w - 1]);
        }
        out
    }

    /// Extract up to `n` evaluation prompts of exactly `len` tokens (BOS +
    /// the first len-1 tokens of each document).
    pub fn prompts(&self, n: usize, len: usize) -> Vec<Vec<i32>> {
        let mut prompts = Vec::new();
        let mut start = 0usize;
        for (i, &t) in self.tokens.iter().enumerate() {
            if t == EOS {
                if i - start >= len {
                    let mut p = Vec::with_capacity(len);
                    p.push(BOS);
                    p.extend_from_slice(&self.tokens[start..start + len - 1]);
                    prompts.push(p);
                    if prompts.len() == n {
                        break;
                    }
                }
                start = i + 1;
            }
        }
        prompts
    }
}

/// Round-robin mixture batcher over several datasets (target pretraining).
pub struct MixtureBatcher<'a> {
    pub datasets: &'a [Dataset],
    next: usize,
}

impl<'a> MixtureBatcher<'a> {
    pub fn new(datasets: &'a [Dataset]) -> Self {
        MixtureBatcher { datasets, next: 0 }
    }

    /// Rows alternate across domains so every batch sees the mixture.
    pub fn sample_batch(&mut self, rng: &mut Pcg64, b: usize, w: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * w);
        for _ in 0..b {
            let ds = &self.datasets[self.next % self.datasets.len()];
            self.next += 1;
            let start = rng.below(ds.tokens.len().saturating_sub(w));
            out.push(BOS);
            out.extend_from_slice(&ds.tokens[start..start + w - 1]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> PathBuf {
        let d = std::env::temp_dir().join(format!("lkc_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generate_open_load_roundtrip() {
        let dir = tmp();
        let spec = CorpusSpec {
            train_tokens: 5_000,
            eval_docs: 8,
            ..Default::default()
        };
        Corpus::generate(&dir, &spec).unwrap();
        let c = Corpus::open(&dir).unwrap();
        for d in DOMAINS {
            let train = c.load(d, "train").unwrap();
            assert!(train.tokens.len() >= 5_000);
            let eval = c.load(d, "eval").unwrap();
            let prompts = eval.prompts(4, 16);
            assert_eq!(prompts.len(), 4);
            for p in &prompts {
                assert_eq!(p.len(), 16);
                assert_eq!(p[0], BOS);
            }
        }
    }

    #[test]
    fn batches_shaped_and_deterministic() {
        let dir = tmp().join("b");
        Corpus::generate(
            &dir,
            &CorpusSpec {
                train_tokens: 4_000,
                eval_docs: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let c = Corpus::open(&dir).unwrap();
        let ds = c.load(Domain::Math, "train").unwrap();
        let a = ds.sample_batch(&mut Pcg64::new(5, 1), 4, 56);
        let b = ds.sample_batch(&mut Pcg64::new(5, 1), 4, 56);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4 * 56);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
    }
}
