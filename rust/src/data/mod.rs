//! Data substrate: synthetic domain corpora, datasets and batching.
//!
//! The paper trains on Infinity-Instruct prompts with target-generated
//! responses and evaluates on MT-Bench / HumanEval / GSM8K. None are
//! available offline, so we build three seeded token-grammar *domains*
//! whose entropy profiles mirror those benchmarks (DESIGN.md §2):
//!
//!   * `chat` — topic-Markov chains with Zipfian emission (conversational,
//!     moderate entropy → MT-Bench analog)
//!   * `code` — balanced-bracket CFG with a small reused identifier pool
//!     (low entropy, long predictable stretches → HumanEval analog)
//!   * `math` — arithmetic problems whose answer digits are deterministic
//!     given the prefix (spiky entropy → GSM8K analog)
//!
//! Targets pretrain on the mixture; drafts distill on the same streams;
//! evaluation prompts come from held-out documents of each domain.

pub mod corpus;
pub mod grammar;
pub mod vocab;

pub use corpus::{Corpus, Dataset};
pub use grammar::{Domain, DOMAINS};
pub use vocab::build_vocab_map;

/// Reserved token ids (grammars emit ids in [FIRST_CONTENT, VOCAB)).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const FIRST_CONTENT: i32 = 3;
pub const VOCAB: usize = 512;
