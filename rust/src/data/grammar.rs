//! The three synthetic domain grammars.
//!
//! Requirements that matter for reproducing the paper's phenomenology:
//!  * the induced next-token distributions must be PEAKED on few tokens
//!    (the concentrated-p regime of Appendix A.5) with domain-dependent
//!    entropy: code < math-answer < chat;
//!  * long-range structure (topics, balanced brackets, carries) so a
//!    4-6 layer target genuinely outperforms the 1-layer draft — the
//!    capacity gap that motivates LK losses;
//!  * pure functions of a `Pcg64` so corpora are bit-reproducible.

use crate::data::{EOS, FIRST_CONTENT, VOCAB};
use crate::util::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    Chat,
    Code,
    Math,
}

pub const DOMAINS: [Domain; 3] = [Domain::Chat, Domain::Code, Domain::Math];

impl Domain {
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Chat => "chat",
            Domain::Code => "code",
            Domain::Math => "math",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Domain> {
        match s {
            "chat" => Ok(Domain::Chat),
            "code" => Ok(Domain::Code),
            "math" => Ok(Domain::Math),
            other => anyhow::bail!("unknown domain '{other}'"),
        }
    }

    /// Generate one document of roughly `target_len` tokens (EOS-terminated).
    pub fn generate(&self, rng: &mut Pcg64, target_len: usize) -> Vec<i32> {
        let mut out = match self {
            Domain::Chat => chat_doc(rng, target_len),
            Domain::Code => code_doc(rng, target_len),
            Domain::Math => math_doc(rng, target_len),
        };
        out.push(EOS);
        out
    }
}

// ---------------------------------------------------------------------------
// chat: topic-state Markov chain with Zipfian emissions
// ---------------------------------------------------------------------------
//
// 8 topics × 48-token bands (overlapping), sticky topic transitions, and a
// per-topic bigram kernel: next token depends on (topic, prev token bucket),
// giving an LM-learnable but non-trivial conditional with entropy ≈ 3-4 bits.

const N_TOPICS: usize = 8;
const TOPIC_BAND: usize = 48;

fn chat_token(rng: &mut Pcg64, topic: usize, prev: i32) -> i32 {
    let base = FIRST_CONTENT as usize + topic * 56; // overlapping bands
    // Bigram structure: half the time continue an arithmetic-progression
    // "phrase" from prev, otherwise draw a fresh Zipf rank in the band.
    if prev >= FIRST_CONTENT && rng.uniform() < 0.55 {
        let step = 1 + (prev as usize * 7 + topic) % 5;
        let tok = base + ((prev as usize - base).wrapping_add(step)) % TOPIC_BAND;
        return tok.min(VOCAB - 1) as i32;
    }
    let rank = rng.zipf(TOPIC_BAND, 1.3);
    (base + rank).min(VOCAB - 1) as i32
}

fn chat_doc(rng: &mut Pcg64, target_len: usize) -> Vec<i32> {
    let mut topic = rng.below(N_TOPICS);
    let mut out = Vec::with_capacity(target_len + 1);
    let mut prev = -1;
    while out.len() < target_len {
        // sticky topic switches (~7% per token)
        if rng.uniform() < 0.07 {
            topic = rng.below(N_TOPICS);
        }
        let tok = chat_token(rng, topic, prev);
        out.push(tok);
        prev = tok;
    }
    out
}

// ---------------------------------------------------------------------------
// code: balanced-bracket CFG with a reused identifier pool
// ---------------------------------------------------------------------------
//
// Token map (fixed):
//   keywords   [430..446)   (fn, let, if, ret, loop, ...)
//   operators  [446..462)
//   brackets   462 '(' 463 ')' 464 '{' 465 '}'
//   separators 466 ';' 467 ',' 468 '\n'
//   identifiers: small per-document pool drawn from [FIRST..128)
//   numbers: digit tokens 470..480

const KW: i32 = 430;
const OP: i32 = 446;
const LPAR: i32 = 462;
const RPAR: i32 = 463;
const LBRACE: i32 = 464;
const RBRACE: i32 = 465;
const SEMI: i32 = 466;
const COMMA: i32 = 467;
const NL: i32 = 468;
const DIGIT0: i32 = 470;

struct CodeGen<'a> {
    rng: &'a mut Pcg64,
    idents: Vec<i32>,
    out: Vec<i32>,
    depth: usize,
}

impl<'a> CodeGen<'a> {
    fn ident(&mut self) -> i32 {
        // Heavy reuse: Zipf over the pool, exactly like real code.
        let rank = self.rng.zipf(self.idents.len(), 1.4);
        self.idents[rank]
    }

    fn number(&mut self) {
        let n = 1 + self.rng.below(2);
        for _ in 0..n {
            let d = self.rng.below(10) as i32;
            self.out.push(DIGIT0 + d);
        }
    }

    fn expr(&mut self, budget: usize) {
        // term (op term)*
        if self.rng.uniform() < 0.3 {
            self.number();
        } else {
            let id = self.ident();
            self.out.push(id);
        }
        if budget > 0 && self.rng.uniform() < 0.5 {
            self.out.push(OP + self.rng.below(16) as i32);
            self.expr(budget - 1);
        }
    }

    fn call(&mut self) {
        let id = self.ident();
        self.out.push(id);
        self.out.push(LPAR);
        let n_args = self.rng.below(3);
        for i in 0..n_args {
            if i > 0 {
                self.out.push(COMMA);
            }
            self.expr(1);
        }
        self.out.push(RPAR);
    }

    fn stmt(&mut self, limit: usize) {
        if self.out.len() >= limit {
            return;
        }
        let choice = self.rng.uniform();
        if choice < 0.18 && self.depth < 3 {
            // block: kw expr { stmts }
            self.out.push(KW + self.rng.below(8) as i32);
            self.expr(1);
            self.out.push(LBRACE);
            self.out.push(NL);
            self.depth += 1;
            let n = 1 + self.rng.below(3);
            for _ in 0..n {
                self.stmt(limit);
            }
            self.depth -= 1;
            self.out.push(RBRACE);
            self.out.push(NL);
        } else if choice < 0.55 {
            // let ident = expr ;
            self.out.push(KW + 8);
            let id = self.ident();
            self.out.push(id);
            self.out.push(OP); // '='
            self.expr(2);
            self.out.push(SEMI);
            self.out.push(NL);
        } else {
            self.call();
            self.out.push(SEMI);
            self.out.push(NL);
        }
    }
}

fn code_doc(rng: &mut Pcg64, target_len: usize) -> Vec<i32> {
    let pool = 4 + rng.below(8);
    let idents: Vec<i32> = (0..pool)
        .map(|_| FIRST_CONTENT + rng.below(125) as i32)
        .collect();
    let mut g = CodeGen {
        rng,
        idents,
        out: Vec::with_capacity(target_len + 16),
        depth: 0,
    };
    while g.out.len() < target_len {
        g.stmt(target_len);
    }
    g.out
}

// ---------------------------------------------------------------------------
// math: arithmetic with deterministic answers
// ---------------------------------------------------------------------------
//
// Problems "a OP b = result ;" with multi-digit numbers as digit-token
// sequences; the result digits are fully determined by the prefix, giving
// the GSM8K-like pattern of uncertain problem statements followed by
// highly-predictable answer spans.

const EQ: i32 = 480;
const PLUS: i32 = 481;
const TIMES: i32 = 482;
const MINUS: i32 = 483;

fn push_number(out: &mut Vec<i32>, mut n: u32) {
    let mut digits = Vec::new();
    loop {
        digits.push(DIGIT0 + (n % 10) as i32);
        n /= 10;
        if n == 0 {
            break;
        }
    }
    digits.reverse();
    out.extend(digits);
}

fn math_doc(rng: &mut Pcg64, target_len: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(target_len + 12);
    while out.len() < target_len {
        let a = rng.range(2, 100) as u32;
        let b = rng.range(2, 100) as u32;
        let (op, r) = match rng.below(3) {
            0 => (PLUS, a + b),
            1 => (TIMES, a * b),
            _ => (MINUS, a.max(b) - a.min(b)),
        };
        push_number(&mut out, a);
        out.push(op);
        push_number(&mut out, b);
        out.push(EQ);
        push_number(&mut out, r);
        out.push(SEMI);
        out.push(NL);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        for d in DOMAINS {
            let a = d.generate(&mut Pcg64::new(1, 2), 200);
            let b = d.generate(&mut Pcg64::new(1, 2), 200);
            assert_eq!(a, b, "{d:?}");
        }
    }

    #[test]
    fn tokens_in_range_and_terminated() {
        let mut rng = Pcg64::new(3, 0);
        for d in DOMAINS {
            for _ in 0..20 {
                let doc = d.generate(&mut rng, 150);
                assert_eq!(*doc.last().unwrap(), EOS);
                for &t in &doc[..doc.len() - 1] {
                    assert!(
                        (FIRST_CONTENT..VOCAB as i32).contains(&t),
                        "{d:?} token {t} out of range"
                    );
                }
                assert!(doc.len() >= 150);
            }
        }
    }

    #[test]
    fn code_brackets_balanced() {
        let mut rng = Pcg64::new(7, 0);
        for _ in 0..10 {
            let doc = code_doc(&mut rng, 300);
            let mut paren = 0i32;
            let mut brace = 0i32;
            for &t in &doc {
                match t {
                    LPAR => paren += 1,
                    RPAR => paren -= 1,
                    LBRACE => brace += 1,
                    RBRACE => brace -= 1,
                    _ => {}
                }
                assert!(paren >= 0 && brace >= 0);
            }
            assert_eq!(paren, 0);
            assert_eq!(brace, 0);
        }
    }

    #[test]
    fn math_answers_correct() {
        let mut rng = Pcg64::new(11, 0);
        let doc = math_doc(&mut rng, 400);
        // Parse back "a op b = r ;" groups and check arithmetic.
        let mut i = 0;
        let read_num = |doc: &[i32], i: &mut usize| -> u32 {
            let mut n = 0u32;
            while *i < doc.len() && (DIGIT0..DIGIT0 + 10).contains(&doc[*i]) {
                n = n * 10 + (doc[*i] - DIGIT0) as u32;
                *i += 1;
            }
            n
        };
        let mut checked = 0;
        while i < doc.len() {
            let a = read_num(&doc, &mut i);
            if i >= doc.len() {
                break;
            }
            let op = doc[i];
            i += 1;
            let b = read_num(&doc, &mut i);
            assert_eq!(doc[i], EQ);
            i += 1;
            let r = read_num(&doc, &mut i);
            let want = match op {
                PLUS => a + b,
                TIMES => a * b,
                MINUS => a.max(b) - a.min(b),
                _ => panic!("bad op {op}"),
            };
            assert_eq!(r, want);
            checked += 1;
            assert_eq!(doc[i], SEMI);
            i += 2; // SEMI NL
        }
        assert!(checked > 10);
    }

    #[test]
    fn entropy_ordering_code_below_chat() {
        // Rough unigram-entropy sanity: code should be far more repetitive.
        let mut rng = Pcg64::new(5, 0);
        let ent = |d: Domain, rng: &mut Pcg64| {
            let mut counts = vec![0f64; VOCAB];
            let mut total = 0f64;
            for _ in 0..30 {
                for t in d.generate(rng, 300) {
                    counts[t as usize] += 1.0;
                    total += 1.0;
                }
            }
            counts
                .iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| {
                    let p = c / total;
                    -p * p.log2()
                })
                .sum::<f64>()
        };
        let e_code = ent(Domain::Code, &mut rng);
        let e_chat = ent(Domain::Chat, &mut rng);
        assert!(
            e_code < e_chat,
            "code entropy {e_code:.2} should be < chat {e_chat:.2}"
        );
    }
}
