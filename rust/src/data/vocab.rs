//! Truncated draft vocabulary (FR-Spec style, paper §4.4 / §5.2).
//!
//! The EAGLE-3 drafts emit logits over the `draft_vocab` most frequent
//! tokens of the training mixture. `build_vocab_map` computes that subset
//! and returns it sorted ascending (a stable, test-friendly order); the
//! inverse map lets the engine translate full-vocab ids when scattering
//! draft probabilities during verification.

use super::corpus::Dataset;

/// Returns (vocab_map, coverage): `vocab_map[i]` is the full-vocab id of
/// truncated id `i`; coverage is the fraction of corpus mass retained.
pub fn build_vocab_map(datasets: &[Dataset], vocab: usize, draft_vocab: usize) -> (Vec<i32>, f64) {
    let mut counts = vec![0u64; vocab];
    let mut total = 0u64;
    for ds in datasets {
        for &t in &ds.tokens {
            counts[t as usize] += 1;
            total += 1;
        }
    }
    // Reserved tokens (PAD/BOS/EOS) are always included so the draft can
    // terminate sequences.
    let mut order: Vec<usize> = (0..vocab).collect();
    order.sort_by_key(|&i| std::cmp::Reverse((i < 3) as u64 * u64::MAX / 2 + counts[i]));
    let mut keep: Vec<i32> = order[..draft_vocab].iter().map(|&i| i as i32).collect();
    keep.sort_unstable();
    let kept_mass: u64 = keep.iter().map(|&i| counts[i as usize]).sum();
    (keep, kept_mass as f64 / total.max(1) as f64)
}

/// Inverse of the vocab map: full id -> truncated id (or None).
pub fn invert_vocab_map(vocab_map: &[i32], vocab: usize) -> Vec<Option<u16>> {
    let mut inv = vec![None; vocab];
    for (i, &full) in vocab_map.iter().enumerate() {
        inv[full as usize] = Some(i as u16);
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::grammar::Domain;
    use crate::util::Pcg64;

    fn dataset() -> Dataset {
        let mut rng = Pcg64::new(1, 0);
        let mut tokens = Vec::new();
        for _ in 0..50 {
            tokens.extend(Domain::Chat.generate(&mut rng, 200));
        }
        Dataset {
            domain: Domain::Chat,
            tokens,
        }
    }

    #[test]
    fn map_sorted_reserved_kept_high_coverage() {
        let ds = dataset();
        let (map, coverage) = build_vocab_map(std::slice::from_ref(&ds), 512, 320);
        assert_eq!(map.len(), 320);
        assert!(map.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        for r in 0..3 {
            assert!(map.contains(&r), "reserved token {r} kept");
        }
        assert!(coverage > 0.8, "coverage {coverage}");
        let inv = invert_vocab_map(&map, 512);
        for (i, &full) in map.iter().enumerate() {
            assert_eq!(inv[full as usize], Some(i as u16));
        }
        assert_eq!(inv.iter().filter(|x| x.is_some()).count(), 320);
    }
}
