//! Sampling & the speculative rejection rule.
//!
//! The serving engine receives LOGITS from the XLA executables; every
//! distributional decision (temperature, greedy-vs-stochastic, the accept
//! draw, residual resampling) is made here, in one audited place. This is
//! the piece the paper had to patch vLLM for (§5.4 / Appendix D): vLLM
//! sampled drafts greedily while verifying against temperature-scaled
//! targets, silently deflating acceptance at T=1. Both behaviours are
//! implemented; `SamplingMode::GreedyDraft` reproduces the bug for the
//! Appendix D ablation.

use crate::util::Pcg64;

/// How drafts are sampled and verified.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingMode {
    /// T=0 everywhere: draft argmax, accept iff target argmax agrees.
    Greedy,
    /// Exact lossless speculative sampling at the given temperature:
    /// draft x ~ q, accept w.p. min(1, p(x)/q(x)), resample from
    /// normalized max(p-q, 0) on rejection. Preserves the target
    /// distribution exactly (property-tested).
    Stochastic,
    /// Appendix D: draft argmax (q(x) treated as 1) but stochastic accept
    /// against temperature-scaled p — the upstream-vLLM bug.
    GreedyDraft,
}

impl SamplingMode {
    pub fn parse(s: &str) -> anyhow::Result<SamplingMode> {
        match s {
            "greedy" | "t0" => Ok(SamplingMode::Greedy),
            "stochastic" | "t1" => Ok(SamplingMode::Stochastic),
            "greedy-draft" => Ok(SamplingMode::GreedyDraft),
            other => anyhow::bail!("unknown sampling mode '{other}'"),
        }
    }

    /// Mode scalar fed to the fused device-verify entrypoints (must stay
    /// in lockstep with `python/compile/verify_device.py` MODE_*).
    pub fn device_code(self) -> i32 {
        match self {
            SamplingMode::Greedy => 0,
            SamplingMode::Stochastic => 1,
            SamplingMode::GreedyDraft => 2,
        }
    }

    /// Whether draft/verify decisions consume uniforms at all.
    pub fn is_stochastic(self) -> bool {
        !matches!(self, SamplingMode::Greedy)
    }
}

/// Temperature softmax. T=0 is handled by callers via argmax.
pub fn softmax_t(logits: &[f32], temp: f32) -> Vec<f32> {
    let mut out = vec![0f32; logits.len()];
    softmax_t_into(logits, temp, &mut out);
    out
}

/// Allocation-free temperature softmax into a caller-owned slice (the
/// serving hot path reuses flat scratch buffers across rounds).
pub fn softmax_t_into(logits: &[f32], temp: f32, out: &mut [f32]) {
    debug_assert!(temp > 0.0);
    debug_assert_eq!(logits.len(), out.len());
    let inv = 1.0 / temp;
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0f32;
    for (o, &z) in out.iter_mut().zip(logits) {
        *o = ((z - m) * inv).exp();
        sum += *o;
    }
    let norm = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= norm;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample an index from a normalized distribution via inverse CDF.
pub fn sample_categorical(rng: &mut Pcg64, probs: &[f32]) -> usize {
    let mut u = rng.uniform() as f32;
    for (i, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    // Floating-point slack: return the last token with nonzero mass.
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Outcome of verifying one drafted token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Accept,
    /// Rejected; the replacement token sampled from the residual.
    Reject { replacement: i32 },
}

/// The exact speculative rejection rule for one position.
///
/// * `p` — target distribution at this position (full vocab, normalized)
/// * `q` — draft distribution over the full vocab (zeros outside the
///   truncated draft vocabulary are fine: drafted x always has q(x) > 0)
/// * `x` — the drafted token id
pub fn verify_token(
    rng: &mut Pcg64,
    p: &[f32],
    q: &[f32],
    x: usize,
    mode: SamplingMode,
) -> Verdict {
    match mode {
        SamplingMode::Greedy => {
            if argmax(p) == x {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: argmax(p) as i32,
                }
            }
        }
        SamplingMode::Stochastic => {
            let beta = if q[x] > 0.0 { (p[x] / q[x]).min(1.0) } else { 0.0 };
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
        SamplingMode::GreedyDraft => {
            // Upstream-vLLM bug: x is argmax(q), acceptance prob becomes
            // min(1, p(x)/1) = p(x); on rejection upstream resamples from
            // max(p - q, 0) with the REAL q — keep that to match.
            let beta = p[x].min(1.0);
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// explicit-uniform verification (the host/device-shared contract)
// ---------------------------------------------------------------------------
//
// The device-resident verify pipeline keeps randomness host-owned: the
// engine draws uniforms from each request's PCG64 stream and feeds them
// to the fused kernel as plain f32 inputs. So that the host fallback
// makes the same decisions from the same draws, BOTH paths consume a
// FIXED number of draws per round and use the same selection rules,
// with identical per-element formulations (mirrored in
// python/compile/verify_device.py). The only residual divergence is
// f32 reduction ordering (XLA's vectorized sums/cumsums vs the serial
// loops here), which can flip a verdict only when a uniform lands
// within ~1 ulp of a CDF or acceptance boundary:
//
//   * per round a live row draws exactly `k` accept uniforms plus ONE
//     sample uniform (residual or bonus — only one is consumed) in the
//     stochastic modes, and nothing in greedy mode;
//   * inverse-CDF selection returns the FIRST index with cumsum >= u,
//     falling back to the LAST index with positive mass (fp slack);
//   * the residual draw thresholds the unnormalized residual cumsum at
//     u·Z_res, which is the same selection as normalizing first.
//
// The fixed draw count is what keeps a request's sample path a pure
// function of (seed, request id) on either path — the scheduler's
// composition-independence and continuous-vs-lockstep tests rely on it.

/// Per-round verify uniforms drawn up-front from a request's stream.
#[derive(Clone, Debug, Default)]
pub struct RoundUniforms {
    /// One accept draw per drafted position (empty in greedy mode).
    pub accept: Vec<f32>,
    /// The round's single residual-or-bonus draw.
    pub sample: f32,
}

impl RoundUniforms {
    pub fn draw(rng: &mut Pcg64, k: usize, mode: SamplingMode) -> RoundUniforms {
        let mut u = RoundUniforms::default();
        u.draw_into(rng, k, mode);
        u
    }

    /// Reusable-buffer variant for the per-row hot loop.
    pub fn draw_into(&mut self, rng: &mut Pcg64, k: usize, mode: SamplingMode) {
        self.accept.clear();
        self.sample = 0.0;
        if mode.is_stochastic() {
            self.accept.extend((0..k).map(|_| rng.uniform() as f32));
            self.sample = rng.uniform() as f32;
        }
    }
}

/// Inverse-CDF sample at an explicit uniform: first index with
/// cumsum(probs) >= u, else the last index with positive mass.
pub fn categorical_from_uniform(probs: &[f32], u: f32) -> usize {
    let mut c = 0f32;
    for (i, &p) in probs.iter().enumerate() {
        c += p;
        if c >= u {
            return i;
        }
    }
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Residual sample at an explicit uniform: inverse CDF over the
/// unnormalized max(p - q, 0) thresholded at u·Z_res; falls back to
/// sampling from p when the residual is empty (p == q).
pub fn residual_from_uniform(p: &[f32], q: &[f32], u: f32) -> usize {
    let mut z = 0f32;
    for i in 0..p.len() {
        z += (p[i] - q[i]).max(0.0);
    }
    if z <= 0.0 {
        return categorical_from_uniform(p, u);
    }
    let t = u * z;
    let mut c = 0f32;
    let mut last = None;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = Some(i);
        }
        c += r;
        if c >= t {
            return i;
        }
    }
    last.unwrap_or(p.len() - 1)
}

/// Outcome of one fused verify round for one sequence row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowVerdict {
    /// Accepted draft-prefix length (0..=k).
    pub n_accepted: usize,
    /// The round's non-draft emission: the residual replacement at the
    /// first rejection, or the bonus token after a clean sweep.
    pub token: i32,
}

/// One verify round for one row under the fixed-uniform contract. This
/// is the single audited definition both serving paths share: the host
/// engine calls it (via `verify_round_lazy`); the device kernel
/// implements the identical arithmetic in-graph
/// (python/compile/verify_device.py, pinned by the golden-uniform
/// parity tests).
///
/// `fill_p(j, out)` writes the temperature-softmaxed target row `j`
/// into `out` — called LAZILY, only for rows the acceptance walk
/// actually reaches (rows 0..=n_accepted), so a rejection at position 2
/// never pays for softmaxing rows 3..k. `p` is the caller's
/// [(k+1)·vocab] scratch the rows are materialized into.
///
/// * `q` — [k·vocab] full-vocab draft distributions
/// * `drafted` — k drafted token ids (full vocab)
pub fn verify_round_lazy(
    k: usize,
    vocab: usize,
    p: &mut [f32],
    mut fill_p: impl FnMut(usize, &mut [f32]),
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> RowVerdict {
    debug_assert!(p.len() >= (k + 1) * vocab && q.len() >= k * vocab);
    let mut j = 0usize;
    while j < k {
        let x = drafted[j] as usize;
        fill_p(j, &mut p[j * vocab..(j + 1) * vocab]);
        let pj = &p[j * vocab..(j + 1) * vocab];
        let qj = &q[j * vocab..(j + 1) * vocab];
        let ok = match mode {
            SamplingMode::Greedy => argmax(pj) == x,
            SamplingMode::Stochastic => {
                let beta = if qj[x] > 0.0 { (pj[x] / qj[x]).min(1.0) } else { 0.0 };
                u.accept[j] < beta
            }
            SamplingMode::GreedyDraft => u.accept[j] < pj[x].min(1.0),
        };
        if !ok {
            break;
        }
        j += 1;
    }
    if j >= k {
        // Clean sweep: the bonus row is the only one the walk never
        // materialized (a rejection row was filled on entry above).
        fill_p(j, &mut p[j * vocab..(j + 1) * vocab]);
    }
    let pj = &p[j * vocab..(j + 1) * vocab];
    let token = match mode {
        SamplingMode::Greedy => argmax(pj) as i32,
        _ if j >= k => categorical_from_uniform(pj, u.sample) as i32,
        _ => residual_from_uniform(pj, &q[j * vocab..(j + 1) * vocab], u.sample) as i32,
    };
    RowVerdict {
        n_accepted: j,
        token,
    }
}

/// Eager convenience wrapper over `verify_round_lazy` for callers that
/// already hold all k+1 softmaxed rows (tests, fixtures, simulations).
pub fn verify_round(
    k: usize,
    vocab: usize,
    p: &[f32],
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> RowVerdict {
    let mut scratch = vec![0f32; (k + 1) * vocab];
    verify_round_lazy(
        k,
        vocab,
        &mut scratch,
        |j, out| out.copy_from_slice(&p[j * vocab..(j + 1) * vocab]),
        q,
        drafted,
        mode,
        u,
    )
}

/// Sample from normalized max(p - q, 0); falls back to p when p == q.
pub fn sample_residual(rng: &mut Pcg64, p: &[f32], q: &[f32]) -> usize {
    let mut total = 0f64;
    for i in 0..p.len() {
        let r = p[i] - q[i];
        if r > 0.0 {
            total += r as f64;
        }
    }
    if total <= 0.0 {
        return sample_categorical(rng, p);
    }
    let mut u = rng.uniform() * total;
    let mut last = 0;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = i;
            u -= r as f64;
            if u <= 0.0 {
                return i;
            }
        }
    }
    last
}

/// Host-side acceptance-rate computation α = Σ min(p, q) (paper eq. 1).
pub fn acceptance_rate(p: &[f32], q: &[f32]) -> f64 {
    p.iter()
        .zip(q)
        .map(|(&a, &b)| a.min(b) as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(rng: &mut Pcg64, v: usize, sharp: f32) -> Vec<f32> {
        let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * sharp).collect();
        softmax_t(&logits, 1.0)
    }

    #[test]
    fn softmax_t_normalizes_and_sharpens() {
        let logits = [1.0f32, 2.0, 3.0];
        let p1 = softmax_t(&logits, 1.0);
        let p01 = softmax_t(&logits, 0.1);
        assert!((p1.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p01[2] > p1[2]); // lower temperature concentrates
    }

    /// THE core invariant (Leviathan Thm. 1): speculative sampling with an
    /// arbitrary q preserves the target distribution exactly.
    #[test]
    fn rejection_sampling_preserves_target() {
        let mut rng = Pcg64::new(42, 0);
        let v = 16;
        let p = dist(&mut rng, v, 2.0);
        let q = dist(&mut rng, v, 2.0);
        let n = 300_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            match verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic) {
                Verdict::Accept => counts[x] += 1.0,
                Verdict::Reject { replacement } => counts[replacement as usize] += 1.0,
            }
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p[i] as f64).abs() < 0.005,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p[i]
            );
        }
    }

    #[test]
    fn acceptance_matches_alpha() {
        // E[accept] over x~q must equal alpha = sum min(p, q).
        let mut rng = Pcg64::new(7, 0);
        let v = 12;
        let p = dist(&mut rng, v, 1.5);
        let q = dist(&mut rng, v, 1.5);
        let alpha = acceptance_rate(&p, &q);
        let n = 200_000;
        let mut acc = 0f64;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc += 1.0;
            }
        }
        assert!(
            (acc / n as f64 - alpha).abs() < 0.005,
            "empirical {} vs alpha {alpha}",
            acc / n as f64
        );
    }

    #[test]
    fn greedy_draft_depresses_acceptance_on_diffuse_targets() {
        // Appendix D: with diffuse p and q = p, exact rejection accepts at
        // rate 1 but greedy-draft accepts at only p(argmax).
        let v = 32;
        let p = vec![1.0 / v as f32; v];
        let q = p.clone();
        let mut rng = Pcg64::new(9, 0);
        let n = 50_000;
        let mut acc_exact = 0;
        let mut acc_greedy = 0;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc_exact += 1;
            }
            let xg = argmax(&q);
            if matches!(
                verify_token(&mut rng, &p, &q, xg, SamplingMode::GreedyDraft),
                Verdict::Accept
            ) {
                acc_greedy += 1;
            }
        }
        assert_eq!(acc_exact, n);
        let rate = acc_greedy as f64 / n as f64;
        assert!(rate < 0.1, "greedy-draft rate {rate} should be ~1/32");
    }

    #[test]
    fn categorical_from_uniform_boundaries() {
        let p = [0.3f32, 0.0, 0.2, 0.0];
        assert_eq!(categorical_from_uniform(&p, 0.1), 0);
        assert_eq!(categorical_from_uniform(&p, 0.35), 2);
        // fp slack past the total mass: last index with positive mass
        assert_eq!(categorical_from_uniform(&p, 0.9), 2);
        // all-zero row degenerates to the last index
        assert_eq!(categorical_from_uniform(&[0.0, 0.0], 0.5), 1);
    }

    #[test]
    fn round_uniforms_fixed_draw_count() {
        // Stochastic modes consume exactly k+1 draws; greedy consumes
        // none. This is the host/device stream contract.
        let mut a = Pcg64::new(3, 9);
        let mut b = a.clone();
        let u = RoundUniforms::draw(&mut a, 4, SamplingMode::Stochastic);
        assert_eq!(u.accept.len(), 4);
        for _ in 0..5 {
            b.uniform();
        }
        assert_eq!(a.next_u64(), b.next_u64(), "draw count != k+1");

        let mut c = Pcg64::new(3, 9);
        let mut d = c.clone();
        let u = RoundUniforms::draw(&mut c, 4, SamplingMode::Greedy);
        assert!(u.accept.is_empty());
        assert_eq!(c.next_u64(), d.next_u64(), "greedy must not draw");
    }

    /// Golden-uniform fixture: hand-checkable verdicts for the fused
    /// round (the same vectors back the python three-way parity test).
    #[test]
    fn verify_round_golden_uniforms() {
        let v = 4;
        let k = 2;
        // p rows: position 0 and 1 identical to q -> beta = 1; bonus row.
        let p = [
            0.1f32, 0.2, 0.3, 0.4, // pos 0
            0.25, 0.25, 0.25, 0.25, // pos 1
            0.7, 0.1, 0.1, 0.1, // bonus
        ];
        let q = [
            0.1f32, 0.2, 0.3, 0.4, //
            0.25, 0.25, 0.25, 0.25,
        ];
        let drafted = [3i32, 0];
        // q == p accepts regardless of the accept draws; bonus at
        // u=0.75 lands on the first index with cumsum >= 0.75 (id 1).
        let u = RoundUniforms {
            accept: vec![0.999, 0.999],
            sample: 0.75,
        };
        let rv = verify_round(k, v, &p, &q, &drafted, SamplingMode::Stochastic, &u);
        assert_eq!(
            rv,
            RowVerdict {
                n_accepted: 2,
                token: 1
            }
        );

        // Disjoint supports: q(x) > 0, p(x) = 0 -> beta = 0, reject at 0;
        // the residual equals p so the replacement is its inverse CDF.
        let p2 = [
            0.0f32, 0.5, 0.5, 0.0, //
            0.25, 0.25, 0.25, 0.25,
            0.25, 0.25, 0.25, 0.25,
        ];
        let q2 = [
            1.0f32, 0.0, 0.0, 0.0, //
            0.25, 0.25, 0.25, 0.25,
        ];
        let u2 = RoundUniforms {
            accept: vec![0.0, 0.0],
            sample: 0.6,
        };
        let rv2 = verify_round(k, v, &p2, &q2, &[0, 1], SamplingMode::Stochastic, &u2);
        assert_eq!(
            rv2,
            RowVerdict {
                n_accepted: 0,
                token: 2
            }
        );

        // Greedy: argmax agreement decides, argmax replaces.
        let rv3 = verify_round(k, v, &p, &q, &[3, 2], SamplingMode::Greedy, &u);
        assert_eq!(rv3.n_accepted, 1); // pos 1 argmax is 0 (ties -> first)
        assert_eq!(rv3.token, 0);
    }

    /// The fused fixed-uniform round preserves the target distribution
    /// exactly (the Leviathan invariant on the new contract), reusing
    /// the `rejection_sampling_preserves_target` machinery.
    #[test]
    fn fused_verify_round_preserves_target() {
        let mut rng = Pcg64::new(77, 0);
        let v = 16;
        let p0 = dist(&mut rng, v, 2.0);
        let q0 = dist(&mut rng, v, 2.0);
        let bonus = dist(&mut rng, v, 2.0);
        let mut p = p0.clone();
        p.extend_from_slice(&bonus);
        let n = 300_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let x = categorical_from_uniform(&q0, rng.uniform() as f32) as i32;
            let u = RoundUniforms::draw(&mut rng, 1, SamplingMode::Stochastic);
            let rv = verify_round(1, v, &p, &q0, &[x], SamplingMode::Stochastic, &u);
            let emitted = if rv.n_accepted == 1 { x } else { rv.token };
            counts[emitted as usize] += 1.0;
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p0[i] as f64).abs() < 0.005,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p0[i]
            );
        }
    }

    #[test]
    fn greedy_mode_accepts_iff_argmax_agrees() {
        let p = vec![0.1f32, 0.7, 0.2];
        let q = vec![0.3f32, 0.4, 0.3];
        let mut rng = Pcg64::new(1, 0);
        assert_eq!(
            verify_token(&mut rng, &p, &q, 1, SamplingMode::Greedy),
            Verdict::Accept
        );
        assert_eq!(
            verify_token(&mut rng, &p, &q, 0, SamplingMode::Greedy),
            Verdict::Reject { replacement: 1 }
        );
    }
}
