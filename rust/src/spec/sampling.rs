//! Sampling & the speculative rejection rules (chain and tree).
//!
//! The serving engine receives LOGITS from the XLA executables; every
//! distributional decision (temperature, greedy-vs-stochastic, the accept
//! draw, residual resampling) is made here, in one audited place. This is
//! the piece the paper had to patch vLLM for (§5.4 / Appendix D): vLLM
//! sampled drafts greedily while verifying against temperature-scaled
//! targets, silently deflating acceptance at T=1. Both behaviours are
//! implemented; [`SamplingMode::GreedyDraft`] reproduces the bug for the
//! Appendix D ablation.
//!
//! # The fixed-uniform contract
//!
//! The device-resident verify pipeline keeps randomness host-owned: per
//! round, a live row draws a FIXED number of uniforms from its
//! request-keyed PCG64 stream, up-front, in a fixed order — and both the
//! host fallback and the in-graph kernels consume those same draws with
//! identical per-element arithmetic. Concretely, in the stochastic modes
//! one draft draw per drafted position/node (consumed during `propose`),
//! then one accept draw per position/node plus ONE residual-or-bonus
//! draw (a [`RoundUniforms`]), and nothing at all in greedy mode. The
//! fixed count makes a request's sample path a pure function of
//! `(seed, request id)` on either verify path; [`verify_round`] (chain)
//! and [`verify_tree`] (multi-candidate) are the host-side definitions
//! of the shared arithmetic, pinned against the device graphs by
//! golden-uniform parity tests.
//!
//! # Tree verification
//!
//! [`TreeSpec`] describes a candidate tree (Yang et al. 2024 /
//! SpecInfer-style multi-candidate drafts) and [`verify_tree`] runs the
//! canonical multi-draft rejection rule over it: walk from the root,
//! judging each child of the current node in sibling order with
//! `min(1, r(x)/ (z·q(x)))` against its per-node accept uniform, where
//! `r/z` is the target distribution with every previously-rejected
//! sibling's draft distribution subtracted out (the residual update that
//! keeps the output distribution exactly `p` — Khisti et al. 2024). A
//! degenerate single-chain topology reproduces [`verify_round`] verdicts
//! bit-for-bit from the same uniforms (property-tested).

use crate::util::Pcg64;

/// How drafts are sampled and verified.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingMode {
    /// T=0 everywhere: draft argmax, accept iff target argmax agrees.
    Greedy,
    /// Exact lossless speculative sampling at the given temperature:
    /// draft x ~ q, accept w.p. min(1, p(x)/q(x)), resample from
    /// normalized max(p-q, 0) on rejection. Preserves the target
    /// distribution exactly (property-tested).
    Stochastic,
    /// Appendix D: draft argmax (q(x) treated as 1) but stochastic accept
    /// against temperature-scaled p — the upstream-vLLM bug.
    GreedyDraft,
}

impl SamplingMode {
    pub fn parse(s: &str) -> anyhow::Result<SamplingMode> {
        match s {
            "greedy" | "t0" => Ok(SamplingMode::Greedy),
            "stochastic" | "t1" => Ok(SamplingMode::Stochastic),
            "greedy-draft" => Ok(SamplingMode::GreedyDraft),
            other => anyhow::bail!("unknown sampling mode '{other}'"),
        }
    }

    /// Mode scalar fed to the fused device-verify entrypoints (must stay
    /// in lockstep with `python/compile/verify_device.py` MODE_*).
    pub fn device_code(self) -> i32 {
        match self {
            SamplingMode::Greedy => 0,
            SamplingMode::Stochastic => 1,
            SamplingMode::GreedyDraft => 2,
        }
    }

    /// Whether draft/verify decisions consume uniforms at all.
    pub fn is_stochastic(self) -> bool {
        !matches!(self, SamplingMode::Greedy)
    }
}

/// Temperature softmax. T=0 is handled by callers via argmax.
pub fn softmax_t(logits: &[f32], temp: f32) -> Vec<f32> {
    let mut out = vec![0f32; logits.len()];
    softmax_t_into(logits, temp, &mut out);
    out
}

/// Allocation-free temperature softmax into a caller-owned slice (the
/// serving hot path reuses flat scratch buffers across rounds).
pub fn softmax_t_into(logits: &[f32], temp: f32, out: &mut [f32]) {
    debug_assert!(temp > 0.0);
    debug_assert_eq!(logits.len(), out.len());
    let inv = 1.0 / temp;
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0f32;
    for (o, &z) in out.iter_mut().zip(logits) {
        *o = ((z - m) * inv).exp();
        sum += *o;
    }
    let norm = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= norm;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample an index from a normalized distribution via inverse CDF.
pub fn sample_categorical(rng: &mut Pcg64, probs: &[f32]) -> usize {
    let mut u = rng.uniform() as f32;
    for (i, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    // Floating-point slack: return the last token with nonzero mass.
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Outcome of verifying one drafted token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Accept,
    /// Rejected; the replacement token sampled from the residual.
    Reject { replacement: i32 },
}

/// The exact speculative rejection rule for one position.
///
/// * `p` — target distribution at this position (full vocab, normalized)
/// * `q` — draft distribution over the full vocab (zeros outside the
///   truncated draft vocabulary are fine: drafted x always has q(x) > 0)
/// * `x` — the drafted token id
pub fn verify_token(
    rng: &mut Pcg64,
    p: &[f32],
    q: &[f32],
    x: usize,
    mode: SamplingMode,
) -> Verdict {
    match mode {
        SamplingMode::Greedy => {
            if argmax(p) == x {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: argmax(p) as i32,
                }
            }
        }
        SamplingMode::Stochastic => {
            let beta = if q[x] > 0.0 { (p[x] / q[x]).min(1.0) } else { 0.0 };
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
        SamplingMode::GreedyDraft => {
            // Upstream-vLLM bug: x is argmax(q), acceptance prob becomes
            // min(1, p(x)/1) = p(x); on rejection upstream resamples from
            // max(p - q, 0) with the REAL q — keep that to match.
            let beta = p[x].min(1.0);
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// explicit-uniform verification (the host/device-shared contract)
// ---------------------------------------------------------------------------
//
// The device-resident verify pipeline keeps randomness host-owned: the
// engine draws uniforms from each request's PCG64 stream and feeds them
// to the fused kernel as plain f32 inputs. So that the host fallback
// makes the same decisions from the same draws, BOTH paths consume a
// FIXED number of draws per round and use the same selection rules,
// with identical per-element formulations (mirrored in
// python/compile/verify_device.py). The only residual divergence is
// f32 reduction ordering (XLA's vectorized sums/cumsums vs the serial
// loops here), which can flip a verdict only when a uniform lands
// within ~1 ulp of a CDF or acceptance boundary:
//
//   * per round a live row draws exactly `k` accept uniforms plus ONE
//     sample uniform (residual or bonus — only one is consumed) in the
//     stochastic modes, and nothing in greedy mode;
//   * inverse-CDF selection returns the FIRST index with cumsum >= u,
//     falling back to the LAST index with positive mass (fp slack);
//   * the residual draw thresholds the unnormalized residual cumsum at
//     u·Z_res, which is the same selection as normalizing first.
//
// The fixed draw count is what keeps a request's sample path a pure
// function of (seed, request id) on either path — the scheduler's
// composition-independence and continuous-vs-lockstep tests rely on it.

/// Per-round verify uniforms drawn up-front from a request's stream.
#[derive(Clone, Debug, Default)]
pub struct RoundUniforms {
    /// One accept draw per drafted position (empty in greedy mode).
    pub accept: Vec<f32>,
    /// The round's single residual-or-bonus draw.
    pub sample: f32,
}

impl RoundUniforms {
    pub fn draw(rng: &mut Pcg64, k: usize, mode: SamplingMode) -> RoundUniforms {
        let mut u = RoundUniforms::default();
        u.draw_into(rng, k, mode);
        u
    }

    /// Reusable-buffer variant for the per-row hot loop.
    pub fn draw_into(&mut self, rng: &mut Pcg64, k: usize, mode: SamplingMode) {
        self.accept.clear();
        self.sample = 0.0;
        if mode.is_stochastic() {
            self.accept.extend((0..k).map(|_| rng.uniform() as f32));
            self.sample = rng.uniform() as f32;
        }
    }
}

/// Inverse-CDF sample at an explicit uniform: first index with
/// cumsum(probs) >= u, else the last index with positive mass.
pub fn categorical_from_uniform(probs: &[f32], u: f32) -> usize {
    let mut c = 0f32;
    for (i, &p) in probs.iter().enumerate() {
        c += p;
        if c >= u {
            return i;
        }
    }
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Residual sample at an explicit uniform: inverse CDF over the
/// unnormalized max(p - q, 0) thresholded at u·Z_res; falls back to
/// sampling from p when the residual is empty (p == q).
pub fn residual_from_uniform(p: &[f32], q: &[f32], u: f32) -> usize {
    let mut z = 0f32;
    for i in 0..p.len() {
        z += (p[i] - q[i]).max(0.0);
    }
    if z <= 0.0 {
        return categorical_from_uniform(p, u);
    }
    let t = u * z;
    let mut c = 0f32;
    let mut last = None;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = Some(i);
        }
        c += r;
        if c >= t {
            return i;
        }
    }
    last.unwrap_or(p.len() - 1)
}

/// Outcome of one fused verify round for one sequence row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowVerdict {
    /// Accepted draft-prefix length (0..=k).
    pub n_accepted: usize,
    /// The round's non-draft emission: the residual replacement at the
    /// first rejection, or the bonus token after a clean sweep.
    pub token: i32,
}

/// One verify round for one row under the fixed-uniform contract. This
/// is the single audited definition both serving paths share: the host
/// engine calls it (via `verify_round_lazy`); the device kernel
/// implements the identical arithmetic in-graph
/// (python/compile/verify_device.py, pinned by the golden-uniform
/// parity tests).
///
/// `fill_p(j, out)` writes the temperature-softmaxed target row `j`
/// into `out` — called LAZILY, only for rows the acceptance walk
/// actually reaches (rows 0..=n_accepted), so a rejection at position 2
/// never pays for softmaxing rows 3..k. `p` is the caller's
/// [(k+1)·vocab] scratch the rows are materialized into.
///
/// * `q` — [k·vocab] full-vocab draft distributions
/// * `drafted` — k drafted token ids (full vocab)
pub fn verify_round_lazy(
    k: usize,
    vocab: usize,
    p: &mut [f32],
    mut fill_p: impl FnMut(usize, &mut [f32]),
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> RowVerdict {
    debug_assert!(p.len() >= (k + 1) * vocab && q.len() >= k * vocab);
    let mut j = 0usize;
    while j < k {
        let x = drafted[j] as usize;
        fill_p(j, &mut p[j * vocab..(j + 1) * vocab]);
        let pj = &p[j * vocab..(j + 1) * vocab];
        let qj = &q[j * vocab..(j + 1) * vocab];
        let ok = match mode {
            SamplingMode::Greedy => argmax(pj) == x,
            SamplingMode::Stochastic => {
                let beta = if qj[x] > 0.0 { (pj[x] / qj[x]).min(1.0) } else { 0.0 };
                u.accept[j] < beta
            }
            SamplingMode::GreedyDraft => u.accept[j] < pj[x].min(1.0),
        };
        if !ok {
            break;
        }
        j += 1;
    }
    if j >= k {
        // Clean sweep: the bonus row is the only one the walk never
        // materialized (a rejection row was filled on entry above).
        fill_p(j, &mut p[j * vocab..(j + 1) * vocab]);
    }
    let pj = &p[j * vocab..(j + 1) * vocab];
    let token = match mode {
        SamplingMode::Greedy => argmax(pj) as i32,
        _ if j >= k => categorical_from_uniform(pj, u.sample) as i32,
        _ => residual_from_uniform(pj, &q[j * vocab..(j + 1) * vocab], u.sample) as i32,
    };
    RowVerdict {
        n_accepted: j,
        token,
    }
}

/// Eager convenience wrapper over `verify_round_lazy` for callers that
/// already hold all k+1 softmaxed rows (tests, fixtures, simulations).
pub fn verify_round(
    k: usize,
    vocab: usize,
    p: &[f32],
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> RowVerdict {
    let mut scratch = vec![0f32; (k + 1) * vocab];
    verify_round_lazy(
        k,
        vocab,
        &mut scratch,
        |j, out| out.copy_from_slice(&p[j * vocab..(j + 1) * vocab]),
        q,
        drafted,
        mode,
        u,
    )
}

// ---------------------------------------------------------------------------
// multi-candidate (tree) verification
// ---------------------------------------------------------------------------

/// Topology of one candidate tree (Yang et al. 2024 multi-candidate
/// drafts). Nodes are indexed `0..n` in BFS order; `parents[i]` is the
/// node index of `i`'s parent, `-1` for children of the root (the last
/// accepted token). BFS order makes `parents` non-decreasing with
/// `parents[i] < i`, which is what lets both the host walk and the
/// in-graph kernel verify the whole tree in ONE forward scan — the
/// validation in [`TreeSpec::from_parents`] enforces it.
///
/// The verify block layout extends the chain contract: block position 0
/// is the root (`last_token`), node `i` sits at block position `i + 1`,
/// and the target row judging node `i` is the logits row of its parent's
/// block position. Node `i`'s level (root children = level 0) selects
/// the draft head that proposed it; its sibling rank orders greedy-mode
/// top-k candidates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TreeSpec {
    parents: Vec<i32>,
    levels: Vec<usize>,
    ranks: Vec<usize>,
}

impl TreeSpec {
    /// Validated construction from a parent array (BFS order: `parents`
    /// non-decreasing, `-1 <= parents[i] < i`).
    pub fn from_parents(parents: Vec<i32>) -> anyhow::Result<TreeSpec> {
        anyhow::ensure!(!parents.is_empty(), "tree needs at least one node");
        let mut levels = Vec::with_capacity(parents.len());
        let mut ranks = Vec::with_capacity(parents.len());
        let mut last_parent = i32::MIN;
        let mut rank = 0usize;
        for (i, &p) in parents.iter().enumerate() {
            anyhow::ensure!(
                (-1..i as i32).contains(&p),
                "node {i}: parent {p} out of range -1..{i}"
            );
            anyhow::ensure!(
                p >= last_parent,
                "node {i}: parents must be non-decreasing (BFS order)"
            );
            rank = if p == last_parent { rank + 1 } else { 0 };
            last_parent = p;
            levels.push(if p < 0 { 0 } else { levels[p as usize] + 1 });
            ranks.push(rank);
        }
        Ok(TreeSpec {
            parents,
            levels,
            ranks,
        })
    }

    /// The degenerate single-chain topology of length `k` (node `i`'s
    /// parent is `i - 1`): [`verify_tree`] over it reproduces
    /// [`verify_round`] exactly.
    pub fn chain(k: usize) -> TreeSpec {
        TreeSpec::from_parents((0..k).map(|i| i as i32 - 1).collect()).unwrap()
    }

    /// Full tree from per-level fanouts: `fanout[l]` children under every
    /// level-`l - 1` node (level 0 under the root). `[2, 2]` is 2 root
    /// children with 2 children each — 6 nodes, depth 2.
    pub fn from_fanout(fanout: &[usize]) -> anyhow::Result<TreeSpec> {
        anyhow::ensure!(
            !fanout.is_empty() && fanout.iter().all(|&f| f >= 1),
            "fanout must be a non-empty list of counts >= 1"
        );
        let mut parents = Vec::new();
        let mut prev_level: Vec<i32> = vec![-1];
        for &f in fanout {
            let mut level = Vec::new();
            for &p in &prev_level {
                for _ in 0..f {
                    level.push(parents.len() as i32);
                    parents.push(p);
                }
            }
            prev_level = level;
        }
        TreeSpec::from_parents(parents)
    }

    /// Parse a fanout string: `"2x2"` (or `"2,2"`) -> `from_fanout(&[2, 2])`.
    pub fn parse(s: &str) -> anyhow::Result<TreeSpec> {
        let fanout: Vec<usize> = s
            .split(|c| c == 'x' || c == ',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad fanout component '{t}' in '{s}'"))
            })
            .collect::<anyhow::Result<_>>()?;
        TreeSpec::from_fanout(&fanout)
    }

    /// Number of candidate nodes.
    pub fn len(&self) -> usize {
        self.parents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parents.is_empty()
    }

    /// Parent node index of node `i` (`-1` = root).
    pub fn parent(&self, i: usize) -> i32 {
        self.parents[i]
    }

    /// Level of node `i` (root children are level 0) — the draft head
    /// that proposes it.
    pub fn level(&self, i: usize) -> usize {
        self.levels[i]
    }

    /// Rank of node `i` among its siblings — the greedy-mode top-k index
    /// of its candidate token.
    pub fn rank(&self, i: usize) -> usize {
        self.ranks[i]
    }

    /// Maximum accepted-path length (deepest level + 1) — the tree
    /// analog of the chain length K.
    pub fn depth(&self) -> usize {
        self.levels.iter().map(|&l| l + 1).max().unwrap_or(0)
    }

    /// True for the degenerate single-chain topology.
    pub fn is_chain(&self) -> bool {
        self.parents.iter().enumerate().all(|(i, &p)| p == i as i32 - 1)
    }

    /// Node-parent array padded to `n` slots with the self-index, the
    /// form the lowered device entries take: a self-parent can never
    /// satisfy `parent == cur`, and `parent > cur` stops the scan, so
    /// padding slots are inert by construction.
    pub fn parents_padded(&self, n: usize) -> Vec<i32> {
        let mut out = self.parents.clone();
        for i in out.len()..n {
            out.push(i as i32);
        }
        out
    }

    /// Block-position parent array for the verify block (`t` slots):
    /// entry 0 is the root (its own parent, terminating ancestor walks),
    /// entry `i + 1` maps node `i`'s parent to block coordinates, and
    /// padding slots are self-parents (depth 0, attend only to
    /// themselves plus the committed prefix).
    pub fn block_parents(&self, t: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(t);
        out.push(0);
        for &p in &self.parents {
            out.push(p + 1);
        }
        for i in out.len()..t {
            out.push(i as i32);
        }
        out.truncate(t);
        out
    }
}

/// Outcome of one tree-verify round for one sequence row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TreeVerdict {
    /// Accepted node indices, root-to-leaf (one per level walked).
    pub path: Vec<usize>,
    /// The round's non-draft emission: the residual replacement where
    /// the walk stopped, or the bonus token past an accepted leaf.
    pub token: i32,
}

/// First index whose serial cumulative sum of `r` reaches `t`, else the
/// last index with positive mass (fp slack), else `len - 1`. With a
/// normalized `r` and `t = u` this is exactly
/// [`categorical_from_uniform`]; with an unnormalized residual and
/// `t = u·z` it is exactly the [`residual_from_uniform`] selection.
fn threshold_select(r: &[f32], t: f32) -> usize {
    let mut c = 0f32;
    let mut last = None;
    for (i, &v) in r.iter().enumerate() {
        if v > 0.0 {
            last = Some(i);
        }
        c += v;
        if c >= t {
            return i;
        }
    }
    last.unwrap_or(r.len() - 1)
}

/// One multi-candidate verify round under the fixed-uniform contract —
/// the single audited definition shared by the host tree path and the
/// device graphs (`python/compile/verify_device.py::tree_verify`, pinned
/// by golden-uniform parity tests).
///
/// The walk keeps the current target distribution as an UNNORMALIZED
/// residual `r` with mass `z` (`z` is exactly 1.0 while `r` is a pristine
/// softmax row). For each scanned node `i` (BFS order, one forward
/// scan):
///
///   * `parent(i) < cur` — stale sibling group, skip;
///   * `parent(i) > cur` — no children of `cur` remain (BFS order), the
///     walk stops;
///   * `parent(i) == cur` — judge candidate `i`: accept when
///     `u.accept[i] < min(1, r(x)/(z·q_i(x)))` (stochastic; the
///     greedy-draft bug uses `min(1, r(x)/z)`, greedy mode argmax
///     agreement against the PRISTINE row). Acceptance descends:
///     `cur = i`, `r` resets to the pristine row after node `i`.
///     Rejection folds the candidate out: `r = max(r - z·q_i, 0)`,
///     `z = Σr` — so the next sibling is judged against the exact
///     residual, which is what keeps the emitted distribution exactly
///     `p` (Khisti et al. 2024).
///
/// The emission consumes the round's single sample uniform: the
/// inverse-CDF selection over `r` thresholded at `u.sample·z` — which is
/// the bonus draw from `p` when the walk ran past a leaf (`z == 1`,
/// `r` pristine) and the residual replacement otherwise — falling back
/// to the pristine row when the residual emptied (`p == q`).
///
/// `fill_p(j, out)` materializes the temperature-softmaxed target row at
/// BLOCK position `j` (0 = root) — called lazily, only for the root and
/// each accepted node. `p` is the caller's `(n + 1)·vocab` scratch those
/// rows land in; `r` a `vocab`-sized residual scratch.
///
/// One accept uniform per NODE (`u.accept.len() == tree.len()`) plus the
/// single sample draw — drawn up-front whether or not the walk reaches
/// the node, so the stream position stays a pure function of the round.
#[allow(clippy::too_many_arguments)]
pub fn verify_tree_lazy(
    tree: &TreeSpec,
    vocab: usize,
    p: &mut [f32],
    mut fill_p: impl FnMut(usize, &mut [f32]),
    r: &mut [f32],
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> TreeVerdict {
    let n = tree.len();
    debug_assert!(p.len() >= (n + 1) * vocab && q.len() >= n * vocab && r.len() >= vocab);
    debug_assert!(!mode.is_stochastic() || u.accept.len() >= n);
    let mut path = Vec::new();
    let mut cur: i32 = -1;
    fill_p(0, &mut p[0..vocab]);
    r[..vocab].copy_from_slice(&p[0..vocab]);
    let mut z = 1.0f32;
    let mut z_isone = true;
    let mut i = 0usize;
    while i < n {
        let par = tree.parent(i);
        if par > cur {
            break; // BFS order: no children of `cur` remain
        }
        if par < cur {
            i += 1;
            continue; // sibling group of an already-passed node
        }
        let x = drafted[i] as usize;
        let z_eff = if z_isone { 1.0 } else { z };
        let qi = &q[i * vocab..(i + 1) * vocab];
        let prow = &p[(cur + 1) as usize * vocab..][..vocab];
        // An emptied residual (z == 0: previous siblings covered all of
        // the target's mass) rejects every remaining candidate — guards
        // the 0/0 = NaN that f32::min would otherwise turn into an
        // accept; the device graphs reject here too (clamped
        // denominator / NaN comparing false). Chain topologies always
        // judge with z_eff == 1, so degeneracy is unaffected.
        let ok = match mode {
            SamplingMode::Greedy => argmax(prow) == x,
            SamplingMode::Stochastic => {
                let beta = if qi[x] > 0.0 && z_eff > 0.0 {
                    (r[x] / (z_eff * qi[x])).min(1.0)
                } else {
                    0.0
                };
                u.accept[i] < beta
            }
            SamplingMode::GreedyDraft => {
                z_eff > 0.0 && u.accept[i] < (r[x] / z_eff).min(1.0)
            }
        };
        if ok {
            cur = i as i32;
            path.push(i);
            let row = &mut p[(i + 1) * vocab..(i + 2) * vocab];
            fill_p(i + 1, row);
            r[..vocab].copy_from_slice(&p[(i + 1) * vocab..(i + 2) * vocab]);
            z_isone = true;
        } else {
            let mut znew = 0f32;
            for (rv, &qv) in r[..vocab].iter_mut().zip(qi) {
                *rv = (*rv - z_eff * qv).max(0.0);
                znew += *rv;
            }
            z = znew;
            z_isone = false;
        }
        i += 1;
    }
    let prow = &p[(cur + 1) as usize * vocab..][..vocab];
    let token = match mode {
        SamplingMode::Greedy => argmax(prow) as i32,
        _ => {
            let z_eff = if z_isone { 1.0 } else { z };
            if z_eff > 0.0 {
                threshold_select(&r[..vocab], u.sample * z_eff) as i32
            } else {
                categorical_from_uniform(prow, u.sample) as i32
            }
        }
    };
    TreeVerdict { path, token }
}

/// Eager convenience wrapper over [`verify_tree_lazy`] for callers that
/// already hold all `n + 1` softmaxed block rows (tests, fixtures).
pub fn verify_tree(
    tree: &TreeSpec,
    vocab: usize,
    p: &[f32],
    q: &[f32],
    drafted: &[i32],
    mode: SamplingMode,
    u: &RoundUniforms,
) -> TreeVerdict {
    let n = tree.len();
    let mut scratch = vec![0f32; (n + 1) * vocab];
    let mut r = vec![0f32; vocab];
    verify_tree_lazy(
        tree,
        vocab,
        &mut scratch,
        |j, out| out.copy_from_slice(&p[j * vocab..(j + 1) * vocab]),
        &mut r,
        q,
        drafted,
        mode,
        u,
    )
}

/// The `rank`-th-largest index of `probs` by repeated first-occurrence
/// argmax-and-mask — the greedy-mode candidate for sibling rank `rank`,
/// formulated identically to the in-graph `kth_argmax`
/// (`verify_device.py`) so host and device propose the same tokens.
pub fn argmax_rank(probs: &[f32], rank: usize, scratch: &mut Vec<f32>) -> usize {
    scratch.clear();
    scratch.extend_from_slice(probs);
    let mut best = argmax(scratch);
    for _ in 0..rank {
        scratch[best] = f32::NEG_INFINITY;
        best = argmax(scratch);
    }
    best
}

/// Sample from normalized max(p - q, 0); falls back to p when p == q.
pub fn sample_residual(rng: &mut Pcg64, p: &[f32], q: &[f32]) -> usize {
    let mut total = 0f64;
    for i in 0..p.len() {
        let r = p[i] - q[i];
        if r > 0.0 {
            total += r as f64;
        }
    }
    if total <= 0.0 {
        return sample_categorical(rng, p);
    }
    let mut u = rng.uniform() * total;
    let mut last = 0;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = i;
            u -= r as f64;
            if u <= 0.0 {
                return i;
            }
        }
    }
    last
}

/// Host-side acceptance-rate computation α = Σ min(p, q) (paper eq. 1).
pub fn acceptance_rate(p: &[f32], q: &[f32]) -> f64 {
    p.iter()
        .zip(q)
        .map(|(&a, &b)| a.min(b) as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(rng: &mut Pcg64, v: usize, sharp: f32) -> Vec<f32> {
        let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * sharp).collect();
        softmax_t(&logits, 1.0)
    }

    #[test]
    fn softmax_t_normalizes_and_sharpens() {
        let logits = [1.0f32, 2.0, 3.0];
        let p1 = softmax_t(&logits, 1.0);
        let p01 = softmax_t(&logits, 0.1);
        assert!((p1.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p01[2] > p1[2]); // lower temperature concentrates
    }

    /// THE core invariant (Leviathan Thm. 1): speculative sampling with an
    /// arbitrary q preserves the target distribution exactly.
    #[test]
    fn rejection_sampling_preserves_target() {
        let mut rng = Pcg64::new(42, 0);
        let v = 16;
        let p = dist(&mut rng, v, 2.0);
        let q = dist(&mut rng, v, 2.0);
        let n = 300_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            match verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic) {
                Verdict::Accept => counts[x] += 1.0,
                Verdict::Reject { replacement } => counts[replacement as usize] += 1.0,
            }
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p[i] as f64).abs() < 0.005,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p[i]
            );
        }
    }

    #[test]
    fn acceptance_matches_alpha() {
        // E[accept] over x~q must equal alpha = sum min(p, q).
        let mut rng = Pcg64::new(7, 0);
        let v = 12;
        let p = dist(&mut rng, v, 1.5);
        let q = dist(&mut rng, v, 1.5);
        let alpha = acceptance_rate(&p, &q);
        let n = 200_000;
        let mut acc = 0f64;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc += 1.0;
            }
        }
        assert!(
            (acc / n as f64 - alpha).abs() < 0.005,
            "empirical {} vs alpha {alpha}",
            acc / n as f64
        );
    }

    #[test]
    fn greedy_draft_depresses_acceptance_on_diffuse_targets() {
        // Appendix D: with diffuse p and q = p, exact rejection accepts at
        // rate 1 but greedy-draft accepts at only p(argmax).
        let v = 32;
        let p = vec![1.0 / v as f32; v];
        let q = p.clone();
        let mut rng = Pcg64::new(9, 0);
        let n = 50_000;
        let mut acc_exact = 0;
        let mut acc_greedy = 0;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc_exact += 1;
            }
            let xg = argmax(&q);
            if matches!(
                verify_token(&mut rng, &p, &q, xg, SamplingMode::GreedyDraft),
                Verdict::Accept
            ) {
                acc_greedy += 1;
            }
        }
        assert_eq!(acc_exact, n);
        let rate = acc_greedy as f64 / n as f64;
        assert!(rate < 0.1, "greedy-draft rate {rate} should be ~1/32");
    }

    #[test]
    fn categorical_from_uniform_boundaries() {
        let p = [0.3f32, 0.0, 0.2, 0.0];
        assert_eq!(categorical_from_uniform(&p, 0.1), 0);
        assert_eq!(categorical_from_uniform(&p, 0.35), 2);
        // fp slack past the total mass: last index with positive mass
        assert_eq!(categorical_from_uniform(&p, 0.9), 2);
        // all-zero row degenerates to the last index
        assert_eq!(categorical_from_uniform(&[0.0, 0.0], 0.5), 1);
    }

    #[test]
    fn round_uniforms_fixed_draw_count() {
        // Stochastic modes consume exactly k+1 draws; greedy consumes
        // none. This is the host/device stream contract.
        let mut a = Pcg64::new(3, 9);
        let mut b = a.clone();
        let u = RoundUniforms::draw(&mut a, 4, SamplingMode::Stochastic);
        assert_eq!(u.accept.len(), 4);
        for _ in 0..5 {
            b.uniform();
        }
        assert_eq!(a.next_u64(), b.next_u64(), "draw count != k+1");

        let mut c = Pcg64::new(3, 9);
        let mut d = c.clone();
        let u = RoundUniforms::draw(&mut c, 4, SamplingMode::Greedy);
        assert!(u.accept.is_empty());
        assert_eq!(c.next_u64(), d.next_u64(), "greedy must not draw");
    }

    /// Golden-uniform fixture: hand-checkable verdicts for the fused
    /// round (the same vectors back the python three-way parity test).
    #[test]
    fn verify_round_golden_uniforms() {
        let v = 4;
        let k = 2;
        // p rows: position 0 and 1 identical to q -> beta = 1; bonus row.
        let p = [
            0.1f32, 0.2, 0.3, 0.4, // pos 0
            0.25, 0.25, 0.25, 0.25, // pos 1
            0.7, 0.1, 0.1, 0.1, // bonus
        ];
        let q = [
            0.1f32, 0.2, 0.3, 0.4, //
            0.25, 0.25, 0.25, 0.25,
        ];
        let drafted = [3i32, 0];
        // q == p accepts regardless of the accept draws; bonus at
        // u=0.75 lands on the first index with cumsum >= 0.75 (id 1).
        let u = RoundUniforms {
            accept: vec![0.999, 0.999],
            sample: 0.75,
        };
        let rv = verify_round(k, v, &p, &q, &drafted, SamplingMode::Stochastic, &u);
        assert_eq!(
            rv,
            RowVerdict {
                n_accepted: 2,
                token: 1
            }
        );

        // Disjoint supports: q(x) > 0, p(x) = 0 -> beta = 0, reject at 0;
        // the residual equals p so the replacement is its inverse CDF.
        let p2 = [
            0.0f32, 0.5, 0.5, 0.0, //
            0.25, 0.25, 0.25, 0.25,
            0.25, 0.25, 0.25, 0.25,
        ];
        let q2 = [
            1.0f32, 0.0, 0.0, 0.0, //
            0.25, 0.25, 0.25, 0.25,
        ];
        let u2 = RoundUniforms {
            accept: vec![0.0, 0.0],
            sample: 0.6,
        };
        let rv2 = verify_round(k, v, &p2, &q2, &[0, 1], SamplingMode::Stochastic, &u2);
        assert_eq!(
            rv2,
            RowVerdict {
                n_accepted: 0,
                token: 2
            }
        );

        // Greedy: argmax agreement decides, argmax replaces.
        let rv3 = verify_round(k, v, &p, &q, &[3, 2], SamplingMode::Greedy, &u);
        assert_eq!(rv3.n_accepted, 1); // pos 1 argmax is 0 (ties -> first)
        assert_eq!(rv3.token, 0);
    }

    /// The fused fixed-uniform round preserves the target distribution
    /// exactly (the Leviathan invariant on the new contract), reusing
    /// the `rejection_sampling_preserves_target` machinery.
    #[test]
    fn fused_verify_round_preserves_target() {
        let mut rng = Pcg64::new(77, 0);
        let v = 16;
        let p0 = dist(&mut rng, v, 2.0);
        let q0 = dist(&mut rng, v, 2.0);
        let bonus = dist(&mut rng, v, 2.0);
        let mut p = p0.clone();
        p.extend_from_slice(&bonus);
        let n = 300_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let x = categorical_from_uniform(&q0, rng.uniform() as f32) as i32;
            let u = RoundUniforms::draw(&mut rng, 1, SamplingMode::Stochastic);
            let rv = verify_round(1, v, &p, &q0, &[x], SamplingMode::Stochastic, &u);
            let emitted = if rv.n_accepted == 1 { x } else { rv.token };
            counts[emitted as usize] += 1.0;
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p0[i] as f64).abs() < 0.005,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p0[i]
            );
        }
    }

    #[test]
    fn tree_spec_construction_and_validation() {
        let t = TreeSpec::from_fanout(&[2, 2]).unwrap();
        assert_eq!(t.len(), 6);
        assert_eq!(t.depth(), 2);
        assert_eq!((0..6).map(|i| t.parent(i)).collect::<Vec<_>>(), vec![-1, -1, 0, 0, 1, 1]);
        assert_eq!((0..6).map(|i| t.level(i)).collect::<Vec<_>>(), vec![0, 0, 1, 1, 1, 1]);
        assert_eq!((0..6).map(|i| t.rank(i)).collect::<Vec<_>>(), vec![0, 1, 0, 1, 0, 1]);
        assert!(!t.is_chain());
        assert_eq!(t.parents_padded(7), vec![-1, -1, 0, 0, 1, 1, 6]);
        assert_eq!(t.block_parents(8), vec![0, 0, 0, 1, 1, 2, 2, 7]);

        let c = TreeSpec::chain(3);
        assert!(c.is_chain());
        assert_eq!(c.depth(), 3);
        assert_eq!(c.block_parents(5), vec![0, 0, 1, 2, 4]);
        assert_eq!(TreeSpec::parse("2x2").unwrap(), t);
        assert_eq!(TreeSpec::parse("2,2").unwrap(), t);

        // forward references, decreasing parents and empty trees reject
        assert!(TreeSpec::from_parents(vec![0]).is_err());
        assert!(TreeSpec::from_parents(vec![-1, 0, -1]).is_err());
        assert!(TreeSpec::from_parents(vec![-2]).is_err());
        assert!(TreeSpec::from_parents(vec![]).is_err());
        assert!(TreeSpec::parse("2x0").is_err());
    }

    /// THE degeneration guarantee: a single-chain topology reproduces
    /// `verify_round` verdicts bit-for-bit from the same uniforms. (The
    /// randomized sweep lives in tests/properties.rs; this pins the
    /// golden fixture vectors shared with the python parity suite.)
    #[test]
    fn tree_chain_matches_verify_round_golden() {
        let v = 4;
        let k = 2;
        let p = [
            0.1f32, 0.2, 0.3, 0.4, //
            0.25, 0.25, 0.25, 0.25, //
            0.7, 0.1, 0.1, 0.1,
        ];
        let q = [
            0.1f32, 0.2, 0.3, 0.4, //
            0.25, 0.25, 0.25, 0.25,
        ];
        let chain = TreeSpec::chain(k);
        for (drafted, u, mode) in [
            (
                [3i32, 0],
                RoundUniforms { accept: vec![0.999, 0.999], sample: 0.75 },
                SamplingMode::Stochastic,
            ),
            (
                [0i32, 1],
                RoundUniforms { accept: vec![0.0, 0.0], sample: 0.6 },
                SamplingMode::Stochastic,
            ),
            (
                [3i32, 2],
                RoundUniforms { accept: vec![0.999, 0.999], sample: 0.75 },
                SamplingMode::Greedy,
            ),
            (
                [1i32, 0],
                RoundUniforms { accept: vec![0.2, 0.9], sample: 0.3 },
                SamplingMode::GreedyDraft,
            ),
        ] {
            let rv = verify_round(k, v, &p, &q, &drafted, mode, &u);
            let tv = verify_tree(&chain, v, &p, &q, &drafted, mode, &u);
            assert_eq!(tv.path.len(), rv.n_accepted, "{mode:?} {drafted:?}");
            assert_eq!(tv.token, rv.token, "{mode:?} {drafted:?}");
            assert_eq!(tv.path, (0..rv.n_accepted).collect::<Vec<_>>());
        }
    }

    /// Hand-checkable branching fixture: sibling 0 rejected, sibling 1
    /// judged against the RESIDUAL (not the pristine p), then its child
    /// accepted and the bonus drawn past the leaf.
    #[test]
    fn tree_verify_branching_golden() {
        let v = 4;
        // topology: two root children (nodes 0, 1), node 1 has one child
        // (node 2).
        let tree = TreeSpec::from_parents(vec![-1, -1, 1]).unwrap();
        let p = [
            0.4f32, 0.4, 0.1, 0.1, // root row: judges nodes 0 and 1
            0.25, 0.25, 0.25, 0.25, // after node 0 (never reached)
            0.1, 0.1, 0.1, 0.7, // after node 1: judges node 2
            0.5, 0.5, 0.0, 0.0, // after node 2: the bonus row
        ];
        let q = [
            0.8f32, 0.2, 0.0, 0.0, // q for node 0 (drafted 0)
            0.0, 1.0, 0.0, 0.0, // q for node 1 (drafted 1)
            0.0, 0.0, 0.0, 1.0, // q for node 2 (drafted 3)
        ];
        let drafted = [0i32, 1, 3];
        // node 0: beta = min(1, 0.4/0.8) = 0.5 -> u=0.6 rejects.
        // residual r = max(p - q, 0) = [0, 0.2, 0.1, 0.1], z = 0.4.
        // node 1: beta = min(1, r(1)/(z*q(1))) = min(1, 0.2/0.4) = 0.5
        //         -> u=0.3 accepts; r resets to p-row after node 1.
        // node 2: beta = min(1, 0.7/1.0) -> u=0.55 accepts (leaf).
        // bonus from [0.5, 0.5, 0, 0] at u=0.6 -> cumsum hits at id 1.
        let u = RoundUniforms {
            accept: vec![0.6, 0.3, 0.55],
            sample: 0.6,
        };
        let tv = verify_tree(&tree, v, &p, &q, &drafted, SamplingMode::Stochastic, &u);
        assert_eq!(tv.path, vec![1, 2]);
        assert_eq!(tv.token, 1);

        // Same draws but u_acc[1] = 0.51 > 0.5: node 1 also rejected;
        // the replacement comes from the twice-folded residual
        // r = [0, 0, 0.1, 0.1] (node 1's q removed 0.2 of mass at id 1).
        let u2 = RoundUniforms {
            accept: vec![0.6, 0.51, 0.55],
            sample: 0.4,
        };
        let tv2 = verify_tree(&tree, v, &p, &q, &drafted, SamplingMode::Stochastic, &u2);
        assert!(tv2.path.is_empty());
        // threshold 0.4 * 0.2 = 0.08 -> first cumsum >= 0.08 is id 2.
        assert_eq!(tv2.token, 2);
    }

    /// Greedy tree: the child matching the pristine row's argmax is
    /// accepted regardless of uniforms; no match emits the argmax.
    #[test]
    fn tree_verify_greedy_picks_argmax_child() {
        let v = 4;
        let tree = TreeSpec::from_fanout(&[2]).unwrap();
        let p = [
            0.1f32, 0.6, 0.2, 0.1, // root row: argmax = 1
            0.25, 0.25, 0.25, 0.25, //
            0.7, 0.1, 0.1, 0.1, // after node 1: bonus row, argmax = 0
        ];
        let q = [
            0.5f32, 0.5, 0.0, 0.0, //
            0.5, 0.5, 0.0, 0.0,
        ];
        let u = RoundUniforms::default();
        // second sibling holds the argmax token
        let tv = verify_tree(&tree, v, &p, &q, &[0, 1], SamplingMode::Greedy, &u);
        assert_eq!(tv.path, vec![1]);
        assert_eq!(tv.token, 0); // bonus = argmax of the leaf row
        // no sibling matches -> reject, emit argmax of the root row
        let tv2 = verify_tree(&tree, v, &p, &q, &[0, 2], SamplingMode::Greedy, &u);
        assert!(tv2.path.is_empty());
        assert_eq!(tv2.token, 1);
    }

    /// The tree rule preserves the target distribution exactly for a
    /// one-level two-candidate tree with i.i.d. candidates (the
    /// SpecInfer/MCSD recursive-rejection invariant).
    #[test]
    fn tree_verify_two_candidates_preserves_target() {
        let mut rng = Pcg64::new(91, 0);
        let v = 12;
        let p0 = dist(&mut rng, v, 2.0);
        let q0 = dist(&mut rng, v, 2.0);
        let bonus = dist(&mut rng, v, 2.0);
        let tree = TreeSpec::from_fanout(&[2]).unwrap();
        // block rows: root, after-node-0, after-node-1 (both bonus)
        let mut p = p0.clone();
        p.extend_from_slice(&bonus);
        p.extend_from_slice(&bonus);
        let mut q = q0.clone();
        q.extend_from_slice(&q0);
        let n = 200_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let drafted = [
                categorical_from_uniform(&q0, rng.uniform() as f32) as i32,
                categorical_from_uniform(&q0, rng.uniform() as f32) as i32,
            ];
            let u = RoundUniforms::draw(&mut rng, 2, SamplingMode::Stochastic);
            let tv = verify_tree(&tree, v, &p, &q, &drafted, SamplingMode::Stochastic, &u);
            let first = match tv.path.first() {
                Some(&node) => drafted[node],
                None => tv.token,
            };
            counts[first as usize] += 1.0;
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p0[i] as f64).abs() < 0.006,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p0[i]
            );
        }
    }

    /// Emptied residual: once rejected siblings cover ALL of the target
    /// row's mass (z == 0 — reachable when a candidate lands outside
    /// the residual's support while its q covers it, or through fp
    /// rounding), every remaining candidate must be rejected (no 0/0
    /// NaN acceptance) and the emission falls back to the pristine row
    /// — matching the device graphs' clamped arithmetic.
    #[test]
    fn tree_verify_empty_residual_rejects_remaining_siblings() {
        let v = 4;
        let tree = TreeSpec::from_fanout(&[3]).unwrap();
        let p = [
            0.5f32, 0.25, 0.25, 0.0, // root row
            0.25, 0.25, 0.25, 0.25, // unreached bonus rows
            0.25, 0.25, 0.25, 0.25, //
            0.25, 0.25, 0.25, 0.25,
        ];
        // sibling 0 rejects (beta 0.5), leaving r = [0, .25, .25, 0];
        // sibling 1's q covers r exactly but its candidate sits outside
        // the support (q1(3) = 0 -> beta 0), so the rejection folds the
        // residual to exactly zero; sibling 2 then faces z == 0.
        let q = [
            1.0f32, 0.0, 0.0, 0.0, //
            0.0, 0.5, 0.5, 0.0, //
            0.0, 1.0, 0.0, 0.0,
        ];
        let drafted = [0i32, 3, 1];
        for mode in [SamplingMode::Stochastic, SamplingMode::GreedyDraft] {
            let u = RoundUniforms {
                accept: vec![0.9, 0.999, 0.0], // sibling 2 would "accept" on NaN
                sample: 0.6,
            };
            let tv = verify_tree(&tree, v, &p, &q, &drafted, mode, &u);
            assert!(tv.path.is_empty(), "{mode:?}: accepted from an empty residual");
            // fallback samples the pristine root row: cumsum hits id 1.
            assert_eq!(tv.token, 1, "{mode:?}");
        }
    }

    #[test]
    fn argmax_rank_orders_candidates() {
        let probs = [0.1f32, 0.5, 0.3, 0.1];
        let mut scratch = Vec::new();
        assert_eq!(argmax_rank(&probs, 0, &mut scratch), 1);
        assert_eq!(argmax_rank(&probs, 1, &mut scratch), 2);
        assert_eq!(argmax_rank(&probs, 2, &mut scratch), 0); // tie -> first
        assert_eq!(argmax_rank(&probs, 3, &mut scratch), 3);
    }

    #[test]
    fn greedy_mode_accepts_iff_argmax_agrees() {
        let p = vec![0.1f32, 0.7, 0.2];
        let q = vec![0.3f32, 0.4, 0.3];
        let mut rng = Pcg64::new(1, 0);
        assert_eq!(
            verify_token(&mut rng, &p, &q, 1, SamplingMode::Greedy),
            Verdict::Accept
        );
        assert_eq!(
            verify_token(&mut rng, &p, &q, 0, SamplingMode::Greedy),
            Verdict::Reject { replacement: 1 }
        );
    }
}
