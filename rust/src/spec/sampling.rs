//! Sampling & the speculative rejection rule.
//!
//! The serving engine receives LOGITS from the XLA executables; every
//! distributional decision (temperature, greedy-vs-stochastic, the accept
//! draw, residual resampling) is made here, in one audited place. This is
//! the piece the paper had to patch vLLM for (§5.4 / Appendix D): vLLM
//! sampled drafts greedily while verifying against temperature-scaled
//! targets, silently deflating acceptance at T=1. Both behaviours are
//! implemented; `SamplingMode::GreedyDraft` reproduces the bug for the
//! Appendix D ablation.

use crate::util::Pcg64;

/// How drafts are sampled and verified.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingMode {
    /// T=0 everywhere: draft argmax, accept iff target argmax agrees.
    Greedy,
    /// Exact lossless speculative sampling at the given temperature:
    /// draft x ~ q, accept w.p. min(1, p(x)/q(x)), resample from
    /// normalized max(p-q, 0) on rejection. Preserves the target
    /// distribution exactly (property-tested).
    Stochastic,
    /// Appendix D: draft argmax (q(x) treated as 1) but stochastic accept
    /// against temperature-scaled p — the upstream-vLLM bug.
    GreedyDraft,
}

impl SamplingMode {
    pub fn parse(s: &str) -> anyhow::Result<SamplingMode> {
        match s {
            "greedy" | "t0" => Ok(SamplingMode::Greedy),
            "stochastic" | "t1" => Ok(SamplingMode::Stochastic),
            "greedy-draft" => Ok(SamplingMode::GreedyDraft),
            other => anyhow::bail!("unknown sampling mode '{other}'"),
        }
    }
}

/// Temperature softmax. T=0 is handled by callers via argmax.
pub fn softmax_t(logits: &[f32], temp: f32) -> Vec<f32> {
    debug_assert!(temp > 0.0);
    let inv = 1.0 / temp;
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut out: Vec<f32> = logits.iter().map(|&z| ((z - m) * inv).exp()).collect();
    let sum: f32 = out.iter().sum();
    let norm = 1.0 / sum;
    for p in &mut out {
        *p *= norm;
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample an index from a normalized distribution via inverse CDF.
pub fn sample_categorical(rng: &mut Pcg64, probs: &[f32]) -> usize {
    let mut u = rng.uniform() as f32;
    for (i, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    // Floating-point slack: return the last token with nonzero mass.
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Outcome of verifying one drafted token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Accept,
    /// Rejected; the replacement token sampled from the residual.
    Reject { replacement: i32 },
}

/// The exact speculative rejection rule for one position.
///
/// * `p` — target distribution at this position (full vocab, normalized)
/// * `q` — draft distribution over the full vocab (zeros outside the
///   truncated draft vocabulary are fine: drafted x always has q(x) > 0)
/// * `x` — the drafted token id
pub fn verify_token(
    rng: &mut Pcg64,
    p: &[f32],
    q: &[f32],
    x: usize,
    mode: SamplingMode,
) -> Verdict {
    match mode {
        SamplingMode::Greedy => {
            if argmax(p) == x {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: argmax(p) as i32,
                }
            }
        }
        SamplingMode::Stochastic => {
            let beta = if q[x] > 0.0 { (p[x] / q[x]).min(1.0) } else { 0.0 };
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
        SamplingMode::GreedyDraft => {
            // Upstream-vLLM bug: x is argmax(q), acceptance prob becomes
            // min(1, p(x)/1) = p(x); on rejection upstream resamples from
            // max(p - q, 0) with the REAL q — keep that to match.
            let beta = p[x].min(1.0);
            if (rng.uniform() as f32) < beta {
                Verdict::Accept
            } else {
                Verdict::Reject {
                    replacement: sample_residual(rng, p, q) as i32,
                }
            }
        }
    }
}

/// Sample from normalized max(p - q, 0); falls back to p when p == q.
pub fn sample_residual(rng: &mut Pcg64, p: &[f32], q: &[f32]) -> usize {
    let mut total = 0f64;
    for i in 0..p.len() {
        let r = p[i] - q[i];
        if r > 0.0 {
            total += r as f64;
        }
    }
    if total <= 0.0 {
        return sample_categorical(rng, p);
    }
    let mut u = rng.uniform() * total;
    let mut last = 0;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = i;
            u -= r as f64;
            if u <= 0.0 {
                return i;
            }
        }
    }
    last
}

/// Host-side acceptance-rate computation α = Σ min(p, q) (paper eq. 1).
pub fn acceptance_rate(p: &[f32], q: &[f32]) -> f64 {
    p.iter()
        .zip(q)
        .map(|(&a, &b)| a.min(b) as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(rng: &mut Pcg64, v: usize, sharp: f32) -> Vec<f32> {
        let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * sharp).collect();
        softmax_t(&logits, 1.0)
    }

    #[test]
    fn softmax_t_normalizes_and_sharpens() {
        let logits = [1.0f32, 2.0, 3.0];
        let p1 = softmax_t(&logits, 1.0);
        let p01 = softmax_t(&logits, 0.1);
        assert!((p1.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p01[2] > p1[2]); // lower temperature concentrates
    }

    /// THE core invariant (Leviathan Thm. 1): speculative sampling with an
    /// arbitrary q preserves the target distribution exactly.
    #[test]
    fn rejection_sampling_preserves_target() {
        let mut rng = Pcg64::new(42, 0);
        let v = 16;
        let p = dist(&mut rng, v, 2.0);
        let q = dist(&mut rng, v, 2.0);
        let n = 300_000;
        let mut counts = vec![0f64; v];
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            match verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic) {
                Verdict::Accept => counts[x] += 1.0,
                Verdict::Reject { replacement } => counts[replacement as usize] += 1.0,
            }
        }
        for i in 0..v {
            let emp = counts[i] / n as f64;
            assert!(
                (emp - p[i] as f64).abs() < 0.005,
                "token {i}: empirical {emp:.4} vs target {:.4}",
                p[i]
            );
        }
    }

    #[test]
    fn acceptance_matches_alpha() {
        // E[accept] over x~q must equal alpha = sum min(p, q).
        let mut rng = Pcg64::new(7, 0);
        let v = 12;
        let p = dist(&mut rng, v, 1.5);
        let q = dist(&mut rng, v, 1.5);
        let alpha = acceptance_rate(&p, &q);
        let n = 200_000;
        let mut acc = 0f64;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc += 1.0;
            }
        }
        assert!(
            (acc / n as f64 - alpha).abs() < 0.005,
            "empirical {} vs alpha {alpha}",
            acc / n as f64
        );
    }

    #[test]
    fn greedy_draft_depresses_acceptance_on_diffuse_targets() {
        // Appendix D: with diffuse p and q = p, exact rejection accepts at
        // rate 1 but greedy-draft accepts at only p(argmax).
        let v = 32;
        let p = vec![1.0 / v as f32; v];
        let q = p.clone();
        let mut rng = Pcg64::new(9, 0);
        let n = 50_000;
        let mut acc_exact = 0;
        let mut acc_greedy = 0;
        for _ in 0..n {
            let x = sample_categorical(&mut rng, &q);
            if matches!(
                verify_token(&mut rng, &p, &q, x, SamplingMode::Stochastic),
                Verdict::Accept
            ) {
                acc_exact += 1;
            }
            let xg = argmax(&q);
            if matches!(
                verify_token(&mut rng, &p, &q, xg, SamplingMode::GreedyDraft),
                Verdict::Accept
            ) {
                acc_greedy += 1;
            }
        }
        assert_eq!(acc_exact, n);
        let rate = acc_greedy as f64 / n as f64;
        assert!(rate < 0.1, "greedy-draft rate {rate} should be ~1/32");
    }

    #[test]
    fn greedy_mode_accepts_iff_argmax_agrees() {
        let p = vec![0.1f32, 0.7, 0.2];
        let q = vec![0.3f32, 0.4, 0.3];
        let mut rng = Pcg64::new(1, 0);
        assert_eq!(
            verify_token(&mut rng, &p, &q, 1, SamplingMode::Greedy),
            Verdict::Accept
        );
        assert_eq!(
            verify_token(&mut rng, &p, &q, 0, SamplingMode::Greedy),
            Verdict::Reject { replacement: 1 }
        );
    }
}
