//! Online speculation controller: per-round draft budgets from measured
//! acceptance.
//!
//! The serving stack spends a draft budget every round — K chained draft
//! tokens, or an N-node candidate tree — and the right budget depends on
//! the acceptance the deployment actually achieves (SpecDec++, arXiv
//! 2405.19715: adaptive candidate lengths recover 10–20% throughput over
//! any fixed K; the acceptance-theory analysis in arXiv 2606.30265 shows
//! per-position acceptance is predictable enough online to drive the
//! choice). This module closes that measure→act loop:
//!
//!   * [`AlphaEwma`] — per-position/per-level EWMA estimators of the
//!     conditional acceptance rate `alpha_hat[i]` (chain position i, or
//!     tree level i), fed by every live row-round;
//!   * [`CostModel`] — a round's cost in verify-call units
//!     (`verify + fixed_draft + k·per_token_draft`; parallel-head archs
//!     have `per_token = 0` — one propose pass prices every head);
//!   * [`SpecController`] — picks `k_active` each round as the argmax of
//!     expected emitted tokens per unit cost (with hysteresis so the
//!     choice doesn't flap on estimator noise), and plans per-round
//!     tree topologies ([`SpecController::plan_tree`]): fanout per level
//!     chosen from measured per-level alpha by greedy marginal
//!     throughput-gain allocation under the lowered node budget AND the
//!     backend's cost model — chained drafters pay one draft dispatch
//!     per tree LEVEL, so depth is priced and breadth is near-free,
//!     while parallel heads price the whole tree in one propose pass.
//!
//! # Cost-model units convention
//!
//! Every [`CostModel`] figure is in VERIFY-CALL UNITS: the target's
//! verify pass defines 1.0, and draft-side work is priced as a fraction
//! of it. `fixed` is the per-round draft overhead that does not scale
//! with the budget (a parallel-head propose pass, bootstrap/extend
//! amortization); `per_token` is the marginal cost of one more CHAINED
//! draft dispatch — one more chain position, or (for trees) one more
//! LEVEL of the level-parallel expansion, since a recurrent drafter
//! dispatches once per level regardless of fanout. So
//! `round_cost(k) = 1 + fixed + per_token·k` prices a k-chain and,
//! with `k = depth`, a depth-k candidate tree. Throughput comparisons
//! (`choose_k`, `plan_tree`) are `(1 + E[accepted]) / round_cost` —
//! expected emitted tokens per verify-equivalent of work. The
//! `--draft-cost` CLI override replaces `per_token` only.
//!
//! One path-dependence: the DEVICE tree proposal runs its whole
//! level-parallel expansion in one lowered graph with a fixed number
//! of level passes, so its draft cost is depth-INVARIANT — the engine
//! folds the chained per-level price into `fixed` (`per_token = 0`)
//! when it resolves to that path, and the planner correctly reduces to
//! pure accepted-length allocation there; the host tree path keeps the
//! per-level price (one `tree_step` dispatch per level).
//!
//! # Exactness contract
//!
//! The controller changes HOW MANY candidates a round spends, never the
//! acceptance arithmetic. The fused verify entries take `k_active` /
//! `n_active` as runtime scalars and topology as runtime tensors, so no
//! re-lowering happens, and every round still consumes the fixed-uniform
//! draw count *for its chosen k* (k draft + k accept + 1 sample draws).
//! Consequences, pinned by tests/properties.rs:
//!
//!   * **greedy modes**: the emitted sequence is the target's greedy
//!     path at every position, so ANY k/topology schedule emits
//!     bit-identical tokens — the controller changes round counts only;
//!   * **stochastic mode**: every schedule preserves the target
//!     distribution exactly (the Leviathan invariant holds round by
//!     round), and a constant schedule k* is bit-identical to a fixed
//!     `--spec-k k*` run (same draws, same arithmetic). Distinct
//!     schedules are distinct couplings of the same distribution: at a
//!     fully-accepted short round the bonus token is drawn from `p`
//!     where a longer chain would have run accept/reject there, so
//!     sample-path equality across schedules is information-
//!     theoretically impossible — see DESIGN.md §4a for the argument.
//!
//! The controller's state advances only on (k, n_accepted) observations,
//! which are identical on the host and device verify paths — so path
//! parity is preserved with the controller enabled.

use crate::spec::sampling::TreeSpec;

/// Optimistic prior for unobserved positions: assume the acceptance of
/// the last observed position rather than 0, so cold-start rounds don't
/// collapse to k = k_min before any evidence exists.
const PRIOR_ALPHA: f64 = 0.7;

/// Per-position EWMA acceptance estimator. Position `i` tracks the
/// sibling-group ADVANCE rate of draft position/tree level `i` — the
/// probability the walk moves past it GIVEN it was reached — alongside
/// an EWMA of the fanout those observations were made at. The
/// per-candidate rate [`AlphaEwma::alpha`] deconvolves the two at read
/// time (`1 - (1 - advance)^(1/fanout)`), so chain observations
/// (fanout 1) report acceptance directly and tree observations don't
/// double-count breadth when the planner re-applies a fanout exponent.
/// Rounds are censored observations: a round with `n_acc < k` observes
/// advances at positions `0..n_acc` and one failure at `n_acc`;
/// positions past the first rejection are unobserved (the walk never
/// judged them).
#[derive(Clone, Debug)]
pub struct AlphaEwma {
    /// Per-position sibling-group advance rate.
    adv: Vec<f64>,
    /// Per-position fanout the advance observations were made at.
    fan: Vec<f64>,
    /// EWMA weight of one observation (2^(-1/halflife) decay).
    decay: f64,
    /// Observations folded in per position (for warmup gating).
    counts: Vec<u64>,
}

impl AlphaEwma {
    /// `k_max` positions; `halflife` in observations (how many rounds
    /// until an old observation's weight halves).
    pub fn new(k_max: usize, halflife: f64) -> AlphaEwma {
        AlphaEwma {
            adv: vec![PRIOR_ALPHA; k_max.max(1)],
            fan: vec![1.0; k_max.max(1)],
            decay: 0.5f64.powf(1.0 / halflife.max(1.0)),
            counts: vec![0; k_max.max(1)],
        }
    }

    pub fn k_max(&self) -> usize {
        self.adv.len()
    }

    /// Estimated PER-CANDIDATE conditional acceptance at position `i`
    /// (clamped to a numerically safe open interval). At fanout 1 this
    /// is the advance rate itself.
    pub fn alpha(&self, i: usize) -> f64 {
        let i = i.min(self.adv.len() - 1);
        let adv = self.adv[i].clamp(1e-3, 1.0 - 1e-6);
        let fan = self.fan[i].max(1.0);
        let alpha = if fan <= 1.0 {
            adv
        } else {
            1.0 - (1.0 - adv).powf(1.0 / fan)
        };
        alpha.clamp(1e-3, 1.0 - 1e-6)
    }

    pub fn observations(&self, i: usize) -> u64 {
        self.counts[i.min(self.counts.len() - 1)]
    }

    fn fold(&mut self, i: usize, advanced: f64, fanout: f64) {
        if i >= self.adv.len() {
            return;
        }
        self.adv[i] = self.decay * self.adv[i] + (1.0 - self.decay) * advanced;
        self.fan[i] = self.decay * self.fan[i] + (1.0 - self.decay) * fanout.max(1.0);
        self.counts[i] += 1;
    }

    /// One chain round: `n_drafted` candidates, accepted prefix
    /// `n_accepted` (fanout-1 observations: advance == acceptance).
    pub fn observe_chain(&mut self, n_drafted: usize, n_accepted: usize) {
        debug_assert!(n_accepted <= n_drafted);
        for i in 0..n_accepted {
            self.fold(i, 1.0, 1.0);
        }
        if n_accepted < n_drafted {
            self.fold(n_accepted, 0.0, 1.0);
        }
    }

    /// One tree round: the walk advanced `path_len` levels of `tree`.
    /// Each reached level folds one advance observation (1 for levels
    /// the walk moved past, 0 for the level where every sibling
    /// rejected) together with the level's mean fanout, so
    /// [`AlphaEwma::alpha`]'s deconvolution recovers the per-candidate
    /// rate. The independence model ignores the residual-update
    /// correlation between siblings; at fanout 1 this reduces to
    /// `observe_chain`.
    pub fn observe_tree(&mut self, tree: &TreeSpec, path_len: usize) {
        let depth = tree.depth();
        debug_assert!(path_len <= depth);
        let mut level_nodes = vec![0usize; depth];
        for i in 0..tree.len() {
            level_nodes[tree.level(i)] += 1;
        }
        let fanout_at = |l: usize| -> f64 {
            let parents = if l == 0 { 1 } else { level_nodes[l - 1] };
            (level_nodes[l] as f64 / parents.max(1) as f64).max(1.0)
        };
        for l in 0..path_len {
            self.fold(l, 1.0, fanout_at(l));
        }
        if path_len < depth && level_nodes[path_len] > 0 {
            self.fold(path_len, 0.0, fanout_at(path_len));
        }
    }

    /// Expected accepted prefix length of a k-chain under the current
    /// estimates: `sum_{i<k} prod_{j<=i} alpha[j]`.
    pub fn expected_accepted(&self, k: usize) -> f64 {
        let mut run = 1.0;
        let mut total = 0.0;
        for i in 0..k {
            run *= self.alpha(i);
            total += run;
        }
        total
    }
}

/// Round cost in verify-call units. The verify pass prices 1.0 by
/// definition; drafting prices what the backend actually dispatches:
/// chained archs (recurrent EAGLE-3/MTP, MLP) pay one draft call per
/// token, parallel-head archs (MEDUSA) pay one propose pass regardless
/// of k.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Fixed per-round draft cost (bootstrap/extend/propose passes).
    pub fixed: f64,
    /// Marginal cost of one more drafted token (0 for parallel heads).
    pub per_token: f64,
}

impl CostModel {
    pub fn chained(per_token: f64) -> CostModel {
        CostModel {
            fixed: 0.0,
            per_token,
        }
    }

    pub fn parallel() -> CostModel {
        CostModel {
            fixed: 0.3,
            per_token: 0.0,
        }
    }

    /// Cost of a round drafting `k` tokens (verify included).
    pub fn round_cost(&self, k: usize) -> f64 {
        1.0 + self.fixed + self.per_token.max(0.0) * k as f64
    }
}

/// Controller configuration.
#[derive(Clone, Copy, Debug)]
pub struct ControllerCfg {
    pub k_min: usize,
    pub k_max: usize,
    /// EWMA halflife in row-round observations.
    pub halflife: f64,
    /// Relative throughput gain required to move off the current k
    /// (hysteresis against estimator noise).
    pub hysteresis: f64,
    /// Row-round observations required before leaving the prior.
    pub warmup: u64,
    pub cost: CostModel,
}

impl Default for ControllerCfg {
    fn default() -> Self {
        ControllerCfg {
            k_min: 1,
            k_max: 7,
            halflife: 48.0,
            hysteresis: 0.02,
            warmup: 8,
            cost: CostModel::chained(0.25),
        }
    }
}

/// The online speculation controller: EWMA acceptance in, per-round
/// draft budget out. One instance per engine (group-level: the lowered
/// executables take one `k_active` per call), warm across groups.
#[derive(Clone, Debug)]
pub struct SpecController {
    cfg: ControllerCfg,
    est: AlphaEwma,
    /// Current chain choice (sticky under hysteresis).
    k_cur: usize,
    observed: u64,
}

impl SpecController {
    pub fn new(cfg: ControllerCfg) -> SpecController {
        let cfg = ControllerCfg {
            k_min: cfg.k_min.clamp(1, cfg.k_max.max(1)),
            ..cfg
        };
        SpecController {
            est: AlphaEwma::new(cfg.k_max, cfg.halflife),
            k_cur: cfg.k_max,
            observed: 0,
            cfg,
        }
    }

    pub fn cfg(&self) -> &ControllerCfg {
        &self.cfg
    }

    pub fn estimator(&self) -> &AlphaEwma {
        &self.est
    }

    /// Record one live row's chain round.
    pub fn observe_chain(&mut self, n_drafted: usize, n_accepted: usize) {
        self.est.observe_chain(n_drafted, n_accepted);
        self.observed += 1;
    }

    /// Record one live row's tree round.
    pub fn observe_tree(&mut self, tree: &TreeSpec, path_len: usize) {
        self.est.observe_tree(tree, path_len);
        self.observed += 1;
    }

    /// Expected emitted tokens per unit cost for a k-chain: the accepted
    /// prefix plus the always-emitted bonus/replacement token, over the
    /// round's cost.
    pub fn throughput(&self, k: usize) -> f64 {
        (self.est.expected_accepted(k) + 1.0) / self.cfg.cost.round_cost(k)
    }

    /// The chain length for the next round. Before `warmup` observations
    /// this is `k_max` (the prior is optimistic by design: a too-long
    /// chain costs draft tokens, a too-short one costs target rounds).
    /// After warmup: argmax of [`SpecController::throughput`] over
    /// `k_min..=k_max`, moving off the current choice only for a
    /// relative gain above the hysteresis margin.
    pub fn choose_k(&mut self) -> usize {
        if self.observed < self.cfg.warmup {
            return self.k_cur;
        }
        let mut best_k = self.cfg.k_min;
        let mut best = f64::NEG_INFINITY;
        for k in self.cfg.k_min..=self.cfg.k_max {
            let t = self.throughput(k);
            // strict > keeps ties on the smaller k (cheaper round)
            if t > best {
                best = t;
                best_k = k;
            }
        }
        let cur = self.throughput(self.k_cur.clamp(self.cfg.k_min, self.cfg.k_max));
        if best > cur * (1.0 + self.cfg.hysteresis) {
            self.k_cur = best_k;
        } else {
            self.k_cur = self.k_cur.clamp(self.cfg.k_min, self.cfg.k_max);
        }
        self.k_cur
    }

    /// Expected emitted tokens per unit cost for a candidate tree with
    /// per-level fanouts `f` under the independence model: the expected
    /// accepted path length `L(f_1..f_d) = sum_m prod_{l<=m}
    /// (1 - (1 - alpha_l)^{f_l})` plus the always-emitted bonus token,
    /// over the round's cost at `depth = f.len()` (chained drafters
    /// dispatch once per LEVEL, so depth is what the cost model prices;
    /// see the module-level units convention).
    pub fn tree_throughput(&self, fanout: &[usize]) -> f64 {
        let mut run = 1.0;
        let mut total = 0.0;
        for (l, &fl) in fanout.iter().enumerate() {
            let adv = 1.0 - (1.0 - self.est.alpha(l)).powi(fl as i32);
            run *= adv;
            total += run;
        }
        (1.0 + total) / self.cfg.cost.round_cost(fanout.len())
    }

    /// Plan a per-round candidate-tree topology from the measured
    /// per-level alpha AND the backend's cost model: greedy ascent over
    /// the lowered node budget (`n_slots`, = verify_t - 1), depth capped
    /// at `depth_max` (the arch's head count) and per-level fanout at
    /// `fanout_max`.
    ///
    /// Each step takes the move — widen some level by one, or deepen by
    /// one level — with the best marginal [`tree_throughput`] gain per
    /// node spent; planning stops when nothing fits or every gain is
    /// negligible. The cost model is what makes this correct for BOTH
    /// backend families: parallel heads (`per_token = 0`) reduce to the
    /// pure accepted-length allocation, while chained drafters
    /// (recurrent EAGLE-3/MTP) pay `per_token` for every extra LEVEL,
    /// so the planner only deepens when the expected extra tokens beat
    /// the extra draft dispatch — widening a level stays near-free and
    /// wins under low alpha.
    ///
    /// [`tree_throughput`]: SpecController::tree_throughput
    pub fn plan_tree(
        &self,
        n_slots: usize,
        depth_max: usize,
        fanout_max: usize,
    ) -> TreeSpec {
        let depth_max = depth_max.max(1);
        let fanout_max = fanout_max.max(1);
        let mut fanout: Vec<usize> = vec![1];
        let nodes_of = |f: &[usize]| -> usize {
            let mut level = 1usize;
            let mut total = 0usize;
            for &fl in f {
                level *= fl;
                total += level;
            }
            total
        };
        if n_slots == 0 {
            return TreeSpec::from_fanout(&fanout).expect("chain(1) is valid");
        }
        loop {
            let base_nodes = nodes_of(&fanout);
            let base_j = self.tree_throughput(&fanout);
            let mut best: Option<(f64, Vec<usize>)> = None;
            // widen one level (cost unchanged: siblings ride the same
            // batched pass)
            for l in 0..fanout.len() {
                if fanout[l] >= fanout_max {
                    continue;
                }
                let mut cand = fanout.clone();
                cand[l] += 1;
                let dn = nodes_of(&cand).saturating_sub(base_nodes);
                if dn == 0 || nodes_of(&cand) > n_slots {
                    continue;
                }
                let gain = (self.tree_throughput(&cand) - base_j) / dn as f64;
                let better = match best.as_ref() {
                    Some((g, _)) => gain > *g,
                    None => true,
                };
                if better {
                    best = Some((gain, cand));
                }
            }
            // deepen by one level (fanout 1; chained archs pay per_token)
            if fanout.len() < depth_max {
                let mut cand = fanout.clone();
                cand.push(1);
                if nodes_of(&cand) <= n_slots {
                    let dn = nodes_of(&cand) - base_nodes;
                    let gain = (self.tree_throughput(&cand) - base_j) / dn as f64;
                    let better = match best.as_ref() {
                        Some((g, _)) => gain > *g,
                        None => true,
                    };
                    if better {
                        best = Some((gain, cand));
                    }
                }
            }
            match best {
                Some((gain, cand)) if gain > 1e-5 => fanout = cand,
                _ => break,
            }
        }
        TreeSpec::from_fanout(&fanout).expect("planned fanouts are >= 1")
    }
}

// ---------------------------------------------------------------------------
// prefill/decode budget arbiter (chunked prefill, DESIGN.md §11)
// ---------------------------------------------------------------------------

/// Configuration for the [`PrefillArbiter`]: prices one prefill chunk in
/// the SAME verify-call units the speculation controller budgets rounds
/// in, so the verify-vs-prefill FLOP split is one coherent budget.
#[derive(Clone, Debug)]
pub struct PrefillArbiterCfg {
    /// Tokens per prefill chunk (the lowered `prefill_chunk_b{B}`
    /// length).
    pub chunk: usize,
    /// Hard cap on chunks run between two decode rounds, applied even
    /// under queue pressure — the bound the stall-containment tests pin.
    pub max_chunks_per_round: usize,
    /// Round cost model (the controller's: verify + draft spend).
    pub cost: CostModel,
    /// Nominal chain length pricing the steady-state round.
    pub k_nominal: usize,
    /// One chunk's cost in verify-call units. A verify pass processes
    /// `verify_t` tokens, so a C-token chunk is roughly `C / verify_t`
    /// verify-equivalents of target compute.
    pub chunk_cost: f64,
    /// Steady-state fraction of a round's cost the prefill lane may
    /// spend when nothing is queued (decode cadence protection).
    pub steady_fraction: f64,
}

impl PrefillArbiterCfg {
    /// Standard pricing for a `chunk`-token chunk against a
    /// `verify_t`-token verify block.
    pub fn for_chunk(chunk: usize, verify_t: usize, cost: CostModel, k_nominal: usize) -> Self {
        PrefillArbiterCfg {
            chunk,
            max_chunks_per_round: 4,
            cost,
            k_nominal,
            chunk_cost: chunk as f64 / verify_t.max(1) as f64,
            steady_fraction: 0.5,
        }
    }
}

/// Per-round verify-vs-prefill budget arbiter: decides how many prefill
/// chunks the scheduler's prefill lane may run between decode rounds.
///
/// The policy is the controller's own cost framing extended to the
/// prefill lane (SpecDec++'s per-round budget decision, applied to the
/// prefill/verify split): at steady state (nothing queued) the lane
/// spends at most `steady_fraction` of one round's cost — decode cadence
/// is protected, a joining long prompt amortizes across rounds; under
/// queue pressure (requests waiting on slots held hostage by prefill
/// backlog) the lane runs up to `max_chunks_per_round`, trading this
/// round's cadence for earlier admissions. Never exceeds the backlog,
/// and always grants at least one chunk when a backlog exists — the
/// lane cannot starve.
#[derive(Clone, Debug)]
pub struct PrefillArbiter {
    cfg: PrefillArbiterCfg,
}

impl PrefillArbiter {
    pub fn new(cfg: PrefillArbiterCfg) -> PrefillArbiter {
        assert!(cfg.chunk > 0, "chunk length must be positive");
        assert!(cfg.chunk_cost > 0.0, "chunk cost must be positive");
        PrefillArbiter { cfg }
    }

    pub fn cfg(&self) -> &PrefillArbiterCfg {
        &self.cfg
    }

    /// The hard per-round chunk bound (stall containment).
    pub fn max_chunks_per_round(&self) -> usize {
        self.cfg.max_chunks_per_round.max(1)
    }

    /// Chunks the prefill lane may run before the next decode round,
    /// given `queued` requests waiting for admission and a prefill
    /// backlog of `backlog_chunks` chunks across prefilling sessions.
    pub fn chunks_for_round(&self, queued: usize, backlog_chunks: usize) -> usize {
        if backlog_chunks == 0 {
            return 0;
        }
        let cap = self.max_chunks_per_round();
        let quota = if queued > 0 {
            cap
        } else {
            let round = self.cfg.cost.round_cost(self.cfg.k_nominal);
            let budget = self.cfg.steady_fraction.max(0.0) * round;
            ((budget / self.cfg.chunk_cost).floor() as usize).clamp(1, cap)
        };
        quota.min(backlog_chunks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(k_max: usize, per_token: f64) -> ControllerCfg {
        ControllerCfg {
            k_max,
            warmup: 0,
            hysteresis: 0.0,
            cost: CostModel::chained(per_token),
            ..Default::default()
        }
    }

    #[test]
    fn ewma_converges_to_observed_rate() {
        let mut e = AlphaEwma::new(4, 8.0);
        // position 0 always accepts, position 1 always rejects
        for _ in 0..200 {
            e.observe_chain(4, 1);
        }
        assert!(e.alpha(0) > 0.99, "alpha0 {}", e.alpha(0));
        assert!(e.alpha(1) < 0.01, "alpha1 {}", e.alpha(1));
        // positions past the first rejection stay at the prior (censored)
        assert!((e.alpha(2) - PRIOR_ALPHA).abs() < 1e-9);
        assert_eq!(e.observations(2), 0);
    }

    #[test]
    fn expected_accepted_is_cumprod_sum() {
        let mut e = AlphaEwma::new(3, 1.0);
        // drive alphas to ~ [1, 0.5, ~1] via alternating observations
        for _ in 0..400 {
            e.observe_chain(3, 3);
            e.observe_chain(3, 1);
        }
        let a0 = e.alpha(0);
        let a1 = e.alpha(1);
        let a2 = e.alpha(2);
        let want = a0 + a0 * a1 + a0 * a1 * a2;
        assert!((e.expected_accepted(3) - want).abs() < 1e-12);
        assert!(e.expected_accepted(1) <= e.expected_accepted(3));
    }

    /// Hand-checkable argmax: with alpha = [0.9, 0.9, 0.1, ...] and a
    /// draft cost of 0.25/token, the closed-form throughput peaks at
    /// k = 2: going deeper buys ~0.08 expected tokens for 0.25 cost.
    #[test]
    fn choose_k_matches_closed_form_argmax() {
        let mut c = SpecController::new(cfg(5, 0.25));
        for _ in 0..600 {
            // alternate full-2 accepts and a reject at position 2 so
            // alpha ~ [1, 1, 0.5->...]; then force position 2 low:
            c.observe_chain(3, 2);
        }
        // alpha ~ [1, 1, 0]: expected tokens 1+k for k<=2, flat after.
        let t1 = c.throughput(1);
        let t2 = c.throughput(2);
        let t3 = c.throughput(3);
        assert!(t2 > t1, "t2 {t2} t1 {t1}");
        assert!(t2 > t3, "t2 {t2} t3 {t3}");
        assert_eq!(c.choose_k(), 2);
    }

    /// Parallel heads (zero marginal draft cost): more drafts are free,
    /// so the controller saturates at k_max whenever alpha > 0.
    #[test]
    fn parallel_cost_saturates_k() {
        let mut c = SpecController::new(ControllerCfg {
            cost: CostModel::parallel(),
            warmup: 0,
            hysteresis: 0.0,
            ..Default::default()
        });
        for _ in 0..100 {
            c.observe_chain(7, 4);
        }
        assert_eq!(c.choose_k(), 7);
    }

    /// Near-zero acceptance: every extra draft is wasted cost, so the
    /// controller collapses to k_min.
    #[test]
    fn hopeless_draft_collapses_to_k_min() {
        let mut c = SpecController::new(cfg(7, 0.25));
        for _ in 0..300 {
            c.observe_chain(7, 0);
        }
        assert_eq!(c.choose_k(), 1);
    }

    #[test]
    fn warmup_holds_k_max_and_hysteresis_sticks() {
        let mut c = SpecController::new(ControllerCfg {
            warmup: 50,
            ..cfg(6, 0.25)
        });
        assert_eq!(c.choose_k(), 6, "prior choice before any evidence");
        for _ in 0..49 {
            c.observe_chain(6, 0);
        }
        assert_eq!(c.choose_k(), 6, "still warming up");
        c.observe_chain(6, 0);
        assert!(c.choose_k() < 6, "post-warmup evidence applies");

        // hysteresis: a tiny gain must not move the choice
        let mut s = SpecController::new(ControllerCfg {
            hysteresis: 10.0, // absurd margin: never move
            ..cfg(6, 0.25)
        });
        for _ in 0..100 {
            s.observe_chain(6, 0);
        }
        assert_eq!(s.choose_k(), 6, "hysteresis pins the current choice");
    }

    #[test]
    fn plan_tree_respects_budget_and_caps() {
        let c = SpecController::new(ControllerCfg {
            warmup: 0,
            ..Default::default()
        });
        for (slots, depth, fan) in [(7usize, 6usize, 4usize), (3, 2, 2), (1, 1, 1), (0, 3, 3)] {
            let t = c.plan_tree(slots, depth, fan);
            assert!(t.len() <= slots.max(1), "{slots} {depth} {fan}: {}", t.len());
            assert!(t.depth() <= depth.max(1));
            assert!(!t.is_empty());
        }
    }

    /// Low alpha at level 0 with budget to spare: the planner widens
    /// level 0 (breadth recovers a rejection) instead of deepening.
    #[test]
    fn plan_tree_widens_under_low_alpha() {
        let mut c = SpecController::new(ControllerCfg {
            warmup: 0,
            ..Default::default()
        });
        for _ in 0..500 {
            c.observe_tree(&TreeSpec::from_fanout(&[2, 2]).unwrap(), 0);
        }
        let t = c.plan_tree(7, 6, 4);
        // all-reject evidence: whatever the planner keeps must be
        // shallow — depth 1 wide, or minimal
        assert!(t.depth() <= 2, "low alpha must not plan deep: {:?}", t.depth());
    }

    /// High alpha everywhere: depth dominates (a chain-ish deep tree),
    /// since each level advances with near-certainty.
    #[test]
    fn plan_tree_deepens_under_high_alpha() {
        let mut c = SpecController::new(ControllerCfg {
            warmup: 0,
            ..Default::default()
        });
        let probe = TreeSpec::from_fanout(&[1, 1, 1, 1, 1, 1]).unwrap();
        for _ in 0..500 {
            c.observe_tree(&probe, 6);
        }
        let t = c.plan_tree(7, 6, 4);
        assert!(t.depth() >= 4, "high alpha should plan deep, got {}", t.depth());
    }

    /// The chained cost model prices DEPTH (one draft dispatch per tree
    /// level): with an exorbitant per-level cost the planner must stay
    /// at depth 1 and spend the budget on width instead — the recurrent
    /// (EAGLE-3) tree regime, where siblings ride one batched pass but
    /// every extra level is another `tree_step` call.
    #[test]
    fn plan_tree_chained_cost_prefers_width_over_depth() {
        let c = SpecController::new(ControllerCfg {
            warmup: 0,
            cost: CostModel::chained(3.0),
            ..Default::default()
        });
        // prior alpha 0.7 everywhere: depth would win if levels were free
        let t = c.plan_tree(7, 6, 4);
        assert_eq!(t.depth(), 1, "3.0/level must forbid deepening: {t:?}");
        assert!(t.len() > 1, "width is free — budget should be spent");

        // same estimates, free levels: the planner goes deep instead
        let free = SpecController::new(ControllerCfg {
            warmup: 0,
            cost: CostModel::parallel(),
            ..Default::default()
        });
        assert!(free.plan_tree(7, 6, 4).depth() > 1);
    }

    /// Moderate chained cost (the recurrent default 0.25/level) still
    /// deepens under high alpha — each level buys ~1 expected token for
    /// 0.25 cost — so the recurrent tree is not stuck shallow.
    #[test]
    fn plan_tree_moderate_chained_cost_still_deepens() {
        let mut c = SpecController::new(ControllerCfg {
            warmup: 0,
            cost: CostModel::chained(0.25),
            ..Default::default()
        });
        let probe = TreeSpec::from_fanout(&[1, 1, 1, 1, 1, 1]).unwrap();
        for _ in 0..500 {
            c.observe_tree(&probe, 6);
        }
        assert!(c.plan_tree(7, 6, 4).depth() >= 4);
    }

    #[test]
    fn tree_throughput_matches_chain_throughput_on_chains() {
        let mut c = SpecController::new(cfg(4, 0.25));
        for _ in 0..100 {
            c.observe_chain(4, 2);
        }
        for k in 1..=4usize {
            let chain: Vec<usize> = vec![1; k];
            assert!(
                (c.tree_throughput(&chain) - c.throughput(k)).abs() < 1e-12,
                "depth-{k} single chain must price exactly like the k-chain"
            );
        }
    }

    #[test]
    fn tree_observation_feeds_levels() {
        let mut e = AlphaEwma::new(4, 8.0);
        let tree = TreeSpec::from_fanout(&[2, 2]).unwrap();
        for _ in 0..100 {
            e.observe_tree(&tree, 1); // always advance level 0, fail level 1
        }
        assert!(e.alpha(0) > 0.9);
        assert!(e.alpha(1) < 0.1);
        assert_eq!(e.observations(2), 0, "unreached levels stay censored");
    }

    #[test]
    fn cost_model_round_cost() {
        let c = CostModel::chained(0.25);
        assert!((c.round_cost(4) - 2.0).abs() < 1e-12);
        let p = CostModel::parallel();
        assert!((p.round_cost(1) - p.round_cost(7)).abs() < 1e-12);
    }

    fn arbiter(max_chunks: usize, steady_fraction: f64) -> PrefillArbiter {
        PrefillArbiter::new(PrefillArbiterCfg {
            max_chunks_per_round: max_chunks,
            steady_fraction,
            ..PrefillArbiterCfg::for_chunk(16, 8, CostModel::chained(0.25), 4)
        })
    }

    #[test]
    fn arbiter_zero_backlog_spends_nothing() {
        let a = arbiter(4, 0.5);
        assert_eq!(a.chunks_for_round(0, 0), 0);
        assert_eq!(a.chunks_for_round(9, 0), 0);
    }

    #[test]
    fn arbiter_steady_state_protects_decode_cadence() {
        // round_cost(4) = 2.0, chunk_cost = 2.0: half a round's budget
        // is one chunk's worth, floored to 0 then clamped up — the lane
        // never starves but also never exceeds the steady budget + 1.
        let a = arbiter(4, 0.5);
        let steady = a.chunks_for_round(0, 100);
        assert_eq!(steady, 1, "steady state must drip, not burst");
        // A roomier steady fraction grants more, still capped.
        let roomy = arbiter(4, 4.0);
        assert_eq!(roomy.chunks_for_round(0, 100), 4);
    }

    #[test]
    fn arbiter_queue_pressure_spends_the_cap() {
        let a = arbiter(4, 0.5);
        assert_eq!(a.chunks_for_round(3, 100), 4);
        // …but never more than the backlog itself.
        assert_eq!(a.chunks_for_round(3, 2), 2);
    }

    #[test]
    fn arbiter_bound_is_hard() {
        // The stall-containment bound: whatever the pressure, never
        // more than max_chunks_per_round between two decode rounds.
        let a = arbiter(2, 10.0);
        for queued in 0..8 {
            assert!(a.chunks_for_round(queued, 1000) <= 2);
        }
    }
}
