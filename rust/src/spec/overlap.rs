//! Figure 2 machinery: fitting a single Gaussian to a Gaussian mixture
//! under KL / reverse-KL / TV and measuring the density overlap
//! (= acceptance rate for continuous speculative sampling, Appendix C).
//!
//! Objectives are evaluated by trapezoidal integration on a fixed grid;
//! the fit is a coarse-to-fine grid search over (μ, σ) — robust, exactly
//! reproducible, and more than precise enough to exhibit the paper's
//! qualitative result: TV finds the overlap-maximizing compromise that
//! neither KL (mass-covering) nor reverse KL (mode-seeking) reaches.

/// 1-D Gaussian mixture.
#[derive(Clone, Debug)]
pub struct Mixture {
    pub weights: Vec<f64>,
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
}

impl Mixture {
    pub fn pdf(&self, x: f64) -> f64 {
        self.weights
            .iter()
            .zip(self.means.iter().zip(&self.stds))
            .map(|(&w, (&m, &s))| w * gauss_pdf(x, m, s))
            .sum()
    }

    /// The paper's toy target: a bimodal mixture with unequal mode widths
    /// (the paper does not publish its exact parameters; these are chosen
    /// so the three objectives land in the paper's qualitative pattern —
    /// forward KL mass-covers, reverse KL mode-seeks, TV finds the
    /// overlap-maximizing compromise and wins by several points).
    pub fn paper_toy() -> Mixture {
        Mixture {
            weights: vec![0.5, 0.5],
            means: vec![-2.2, 2.2],
            stds: vec![1.3, 0.45],
        }
    }
}

pub fn gauss_pdf(x: f64, mean: f64, std: f64) -> f64 {
    let z = (x - mean) / std;
    (-0.5 * z * z).exp() / (std * (2.0 * std::f64::consts::PI).sqrt())
}

/// Integration grid spanning the interesting region.
pub fn grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    ForwardKl,
    ReverseKl,
    Tv,
}

impl Objective {
    pub fn name(&self) -> &'static str {
        match self {
            Objective::ForwardKl => "KL(p||q)",
            Objective::ReverseKl => "KL(q||p)",
            Objective::Tv => "TV(p,q)",
        }
    }
}

/// Trapezoid ∫ f over xs (uniform grid).
fn integrate(xs: &[f64], f: impl Fn(f64) -> f64) -> f64 {
    let h = xs[1] - xs[0];
    let mut acc = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        let w = if i == 0 || i == xs.len() - 1 { 0.5 } else { 1.0 };
        acc += w * f(x);
    }
    acc * h
}

pub fn objective_value(obj: Objective, target: &Mixture, mu: f64, sigma: f64, xs: &[f64]) -> f64 {
    const EPS: f64 = 1e-300;
    match obj {
        Objective::ForwardKl => integrate(xs, |x| {
            let p = target.pdf(x);
            if p <= EPS {
                0.0
            } else {
                p * (p.ln() - gauss_pdf(x, mu, sigma).max(EPS).ln())
            }
        }),
        Objective::ReverseKl => integrate(xs, |x| {
            let q = gauss_pdf(x, mu, sigma);
            if q <= EPS {
                0.0
            } else {
                q * (q.ln() - target.pdf(x).max(EPS).ln())
            }
        }),
        Objective::Tv => integrate(xs, |x| {
            0.5 * (target.pdf(x) - gauss_pdf(x, mu, sigma)).abs()
        }),
    }
}

/// Continuous acceptance rate α = ∫ min(p, q) (Appendix C).
pub fn overlap(target: &Mixture, mu: f64, sigma: f64, xs: &[f64]) -> f64 {
    integrate(xs, |x| target.pdf(x).min(gauss_pdf(x, mu, sigma)))
}

/// Coarse-to-fine grid search; returns (mu, sigma, objective value).
pub fn fit(obj: Objective, target: &Mixture, xs: &[f64]) -> (f64, f64, f64) {
    let (mut mu_lo, mut mu_hi) = (-5.0, 5.0);
    let (mut sg_lo, mut sg_hi) = (0.2, 5.0);
    let mut best = (0.0, 1.0, f64::INFINITY);
    for _round in 0..5 {
        let mus = grid(mu_lo, mu_hi, 33);
        let sgs = grid(sg_lo, sg_hi, 33);
        for &mu in &mus {
            for &sg in &sgs {
                let v = objective_value(obj, target, mu, sg, xs);
                if v < best.2 {
                    best = (mu, sg, v);
                }
            }
        }
        let mu_step = (mu_hi - mu_lo) / 32.0;
        let sg_step = (sg_hi - sg_lo) / 32.0;
        mu_lo = best.0 - 2.0 * mu_step;
        mu_hi = best.0 + 2.0 * mu_step;
        sg_lo = (best.1 - 2.0 * sg_step).max(0.05);
        sg_hi = best.1 + 2.0 * sg_step;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pdf_normalized() {
        let m = Mixture::paper_toy();
        let xs = grid(-12.0, 12.0, 4001);
        let total = integrate(&xs, |x| m.pdf(x));
        assert!((total - 1.0).abs() < 1e-6, "{total}");
    }

    #[test]
    fn tv_maximizes_overlap() {
        // The paper's Figure 2 ordering: overlap(TV) > overlap(KL) and
        // overlap(TV) > overlap(revKL).
        let m = Mixture::paper_toy();
        let xs = grid(-12.0, 12.0, 2001);
        let (mu_f, sg_f, _) = fit(Objective::ForwardKl, &m, &xs);
        let (mu_r, sg_r, _) = fit(Objective::ReverseKl, &m, &xs);
        let (mu_t, sg_t, _) = fit(Objective::Tv, &m, &xs);
        let a_f = overlap(&m, mu_f, sg_f, &xs);
        let a_r = overlap(&m, mu_r, sg_r, &xs);
        let a_t = overlap(&m, mu_t, sg_t, &xs);
        assert!(a_t > a_f + 0.015, "tv {a_t} vs fkl {a_f}");
        assert!(a_t > a_r + 0.015, "tv {a_t} vs rkl {a_r}");
        // TV's optimum is 1 - its objective value (identity alpha = 1-TV)
        let tv_val = objective_value(Objective::Tv, &m, mu_t, sg_t, &xs);
        assert!((a_t - (1.0 - tv_val)).abs() < 1e-6);
        // mode-seeking: reverse KL shifts toward a mode, TV compromises
        assert!(mu_r.abs() > mu_t.abs(), "rkl mu {mu_r} vs tv mu {mu_t}");
        let _ = (sg_f, sg_r);
    }
}
