//! Acceptance bookkeeping: per-position acceptance rates and the paper's
//! primary metric τ = K · (#accepted / #drafted) + 1 (§5.5).

/// Accumulates accept/draft counts per draft position plus round shapes.
#[derive(Clone, Debug)]
pub struct AcceptanceStats {
    pub k: usize,
    /// `drafted[i]` / `accepted[i]`: counts at draft position i (0-based).
    pub drafted: Vec<u64>,
    pub accepted: Vec<u64>,
    /// Histogram of per-round accepted-prefix lengths (0..=K).
    pub prefix_hist: Vec<u64>,
    pub rounds: u64,
    pub generated_tokens: u64,
}

impl AcceptanceStats {
    pub fn new(k: usize) -> Self {
        AcceptanceStats {
            k,
            drafted: vec![0; k],
            accepted: vec![0; k],
            prefix_hist: vec![0; k + 1],
            rounds: 0,
            generated_tokens: 0,
        }
    }

    /// Record one verification round: `n_drafted` tokens proposed
    /// (normally K; fewer near a length cap), accepted prefix length
    /// `n_accepted` <= n_drafted.
    pub fn record_round(&mut self, n_drafted: usize, n_accepted: usize) {
        assert!(n_accepted <= n_drafted && n_drafted <= self.k);
        for i in 0..n_drafted {
            self.drafted[i] += 1;
        }
        for i in 0..n_accepted {
            self.accepted[i] += 1;
        }
        self.prefix_hist[n_accepted] += 1;
        self.rounds += 1;
        // accepted prefix + the bonus/replacement token
        self.generated_tokens += n_accepted as u64 + 1;
    }

    /// τ with the paper's convention: K × acceptance-ratio + 1 (the +1 is
    /// the bonus token always emitted per round).
    pub fn tau(&self) -> f64 {
        let drafted: u64 = self.drafted.iter().sum();
        let accepted: u64 = self.accepted.iter().sum();
        if drafted == 0 {
            return 1.0;
        }
        self.k as f64 * (accepted as f64 / drafted as f64) + 1.0
    }

    /// Mean accepted tokens per round including the bonus (equals τ when
    /// every round drafts exactly K).
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            return 1.0;
        }
        self.generated_tokens as f64 / self.rounds as f64
    }

    /// Per-position conditional acceptance rate α_i.
    pub fn alpha_per_position(&self) -> Vec<f64> {
        (0..self.k)
            .map(|i| {
                if self.drafted[i] == 0 {
                    0.0
                } else {
                    self.accepted[i] as f64 / self.drafted[i] as f64
                }
            })
            .collect()
    }

    /// Grow to `k` draft positions (counts for the new positions start
    /// at zero). Lets stats recorded at a smaller chain length — e.g. a
    /// session served while the engine was clamped to a parallel-head
    /// architecture's head count — merge into a wider accumulator.
    pub fn widen(&mut self, k: usize) {
        if k <= self.k {
            return;
        }
        self.drafted.resize(k, 0);
        self.accepted.resize(k, 0);
        self.prefix_hist.resize(k + 1, 0);
        self.k = k;
    }

    pub fn merge(&mut self, other: &AcceptanceStats) {
        assert_eq!(self.k, other.k);
        for i in 0..self.k {
            self.drafted[i] += other.drafted[i];
            self.accepted[i] += other.accepted[i];
        }
        for i in 0..=self.k {
            self.prefix_hist[i] += other.prefix_hist[i];
        }
        self.rounds += other.rounds;
        self.generated_tokens += other.generated_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_formula() {
        let mut s = AcceptanceStats::new(4);
        // two rounds: accept 4/4 then 2/4 -> ratio 6/8, tau = 4*0.75+1 = 4
        s.record_round(4, 4);
        s.record_round(4, 2);
        assert!((s.tau() - 4.0).abs() < 1e-12);
        assert_eq!(s.generated_tokens, 4 + 1 + 2 + 1);
        assert_eq!(s.prefix_hist, vec![0, 0, 1, 0, 1]);
    }

    #[test]
    fn alpha_positionwise_monotone_counts() {
        let mut s = AcceptanceStats::new(3);
        s.record_round(3, 1);
        s.record_round(3, 3);
        s.record_round(3, 0);
        let a = s.alpha_per_position();
        assert_eq!(a, vec![2.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0]);
        // accepted counts can never exceed drafted
        for i in 0..3 {
            assert!(s.accepted[i] <= s.drafted[i]);
        }
    }

    #[test]
    fn merge_adds() {
        let mut a = AcceptanceStats::new(2);
        a.record_round(2, 1);
        let mut b = AcceptanceStats::new(2);
        b.record_round(2, 2);
        a.merge(&b);
        assert_eq!(a.rounds, 2);
        assert!((a.tau() - (2.0 * (3.0 / 4.0) + 1.0)).abs() < 1e-12);
    }

    /// Short final rounds near a length cap: fewer than K drafted, and
    /// zero-draft bookkeeping never divides by zero.
    #[test]
    fn short_final_rounds() {
        let mut s = AcceptanceStats::new(4);
        s.record_round(4, 4);
        s.record_round(2, 1); // capped round: only 2 drafted
        assert_eq!(s.drafted, vec![2, 2, 1, 1]);
        assert_eq!(s.accepted, vec![2, 1, 1, 1]);
        assert_eq!(s.prefix_hist, vec![0, 1, 0, 0, 1]);
        assert_eq!(s.generated_tokens, 5 + 2);
        // positions never drafted report alpha 0, not NaN
        let fresh = AcceptanceStats::new(3);
        assert_eq!(fresh.alpha_per_position(), vec![0.0, 0.0, 0.0]);
        assert_eq!(fresh.tau(), 1.0);
    }

    /// record_round enforces the k-cap.
    #[test]
    #[should_panic]
    fn record_rejects_over_k() {
        let mut s = AcceptanceStats::new(3);
        s.record_round(4, 0);
    }

    /// widen grows the position axis so smaller-k stats can merge into a
    /// wider accumulator; tau is preserved by zero-padding.
    #[test]
    fn widen_enables_cross_k_merge() {
        let mut small = AcceptanceStats::new(2);
        small.record_round(2, 2);
        let tau_before = small.tau();
        small.widen(4);
        assert_eq!(small.k, 4);
        assert_eq!(small.drafted, vec![1, 1, 0, 0]);
        assert_eq!(small.prefix_hist.len(), 5);
        // ratio unchanged, but tau now scales with the wider K
        assert!((small.tau() - (4.0 * 1.0 + 1.0)).abs() < 1e-12);
        assert!(tau_before < small.tau());

        let mut wide = AcceptanceStats::new(4);
        wide.record_round(4, 1);
        wide.merge(&small);
        assert_eq!(wide.rounds, 2);
        assert_eq!(wide.drafted, vec![2, 2, 1, 1]);
        assert_eq!(wide.accepted, vec![2, 1, 0, 0]);

        // widen to a smaller/equal k is a no-op
        let mut s = AcceptanceStats::new(3);
        s.widen(2);
        assert_eq!(s.k, 3);
    }
}
