//! Closed-form gradients of the LK loss family on the host (paper
//! Appendix A) and the diffuse-q / concentrated-p magnitude analysis that
//! regenerates Table 3 / §A.5.
//!
//! These mirror the custom-VJP backward used inside the lowered training
//! artifacts (python/compile/losses.py); tests validate them against
//! finite differences of host-side loss evaluations, closing the loop
//! between the paper's math, the L2 implementation and this analysis code.

use crate::spec::sampling::softmax_t;

/// ∇_{z_q} KL(p‖q) = q − p  (A.2)
pub fn grad_kl(p: &[f32], q: &[f32]) -> Vec<f32> {
    q.iter().zip(p).map(|(&qi, &pi)| qi - pi).collect()
}

/// `∇_{z_q} TV(p, q) = ½ q ⊙ (s − E_q[s])`, `s = sign(q − p)`  (A.3)
pub fn grad_tv(p: &[f32], q: &[f32]) -> Vec<f32> {
    let s: Vec<f32> = q
        .iter()
        .zip(p)
        .map(|(&qi, &pi)| (qi - pi).signum() * ((qi != pi) as i32 as f32))
        .collect();
    let es: f32 = q.iter().zip(&s).map(|(&qi, &si)| qi * si).sum();
    q.iter()
        .zip(&s)
        .map(|(&qi, &si)| 0.5 * qi * (si - es))
        .collect()
}

/// ∇_{z_q} (−log α) = (1/α) ∇ TV  (A.4)
pub fn grad_log_alpha(p: &[f32], q: &[f32]) -> Vec<f32> {
    let alpha: f32 = p.iter().zip(q).map(|(&a, &b)| a.min(b)).sum();
    grad_tv(p, q).into_iter().map(|g| g / alpha).collect()
}

pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Host loss evaluations (for finite-difference tests and Figure 2 code).
pub fn kl_loss(p: &[f32], q: &[f32]) -> f64 {
    p.iter()
        .zip(q)
        .filter(|(&pi, _)| pi > 0.0)
        .map(|(&pi, &qi)| (pi as f64) * ((pi as f64).ln() - (qi.max(1e-30) as f64).ln()))
        .sum()
}

pub fn tv_loss(p: &[f32], q: &[f32]) -> f64 {
    0.5 * p
        .iter()
        .zip(q)
        .map(|(&pi, &qi)| (pi as f64 - qi as f64).abs())
        .sum::<f64>()
}

pub fn alpha_of(p: &[f32], q: &[f32]) -> f64 {
    p.iter().zip(q).map(|(&a, &b)| a.min(b) as f64).sum()
}

/// The Appendix A.5 regime: q ≈ uniform over V (random init), p ≈ uniform
/// over a support of k tokens. Returns (‖∇KL‖, ‖∇TV‖, ‖∇L_LK^α‖).
pub fn magnitudes_at_init(v: usize, k: usize) -> (f64, f64, f64) {
    // Exact construction instead of sampling: q_i = 1/V, p_i = 1/k on S.
    let q = vec![1.0f32 / v as f32; v];
    let mut p = vec![0.0f32; v];
    for pi in p.iter_mut().take(k) {
        *pi = 1.0 / k as f32;
    }
    (
        l2_norm(&grad_kl(&p, &q)),
        l2_norm(&grad_tv(&p, &q)),
        l2_norm(&grad_log_alpha(&p, &q)),
    )
}

/// Softmax logits → probabilities helper for tests and Table 3 empirics
/// with *noisy* (non-degenerate) regimes.
pub fn noisy_regime(rng: &mut crate::util::Pcg64, v: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let zq: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 0.02).collect();
    let mut zp: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 0.3 - 12.0).collect();
    for i in 0..k {
        zp[i] = rng.normal() as f32 * 0.3;
    }
    (softmax_t(&zp, 1.0), softmax_t(&zq, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Finite-difference check of all three closed forms through the
    /// softmax parameterization.
    #[test]
    fn closed_forms_match_finite_differences() {
        let mut rng = Pcg64::new(13, 0);
        let v = 24;
        let zq: Vec<f32> = (0..v).map(|_| rng.normal() as f32).collect();
        let zp: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 2.0).collect();
        let p = softmax_t(&zp, 1.0);
        let q = softmax_t(&zq, 1.0);

        let eps = 1e-3f32;
        let losses: [(&str, Box<dyn Fn(&[f32]) -> f64>, Vec<f32>); 3] = [
            (
                "kl",
                Box::new({
                    let p = p.clone();
                    move |q: &[f32]| kl_loss(&p, q)
                }),
                grad_kl(&p, &q),
            ),
            (
                "tv",
                Box::new({
                    let p = p.clone();
                    move |q: &[f32]| tv_loss(&p, q)
                }),
                grad_tv(&p, &q),
            ),
            (
                "nla",
                Box::new({
                    let p = p.clone();
                    move |q: &[f32]| -alpha_of(&p, q).ln()
                }),
                grad_log_alpha(&p, &q),
            ),
        ];
        for (name, f, analytic) in &losses {
            for j in 0..v {
                let mut zp_ = zq.clone();
                zp_[j] += eps;
                let qp = softmax_t(&zp_, 1.0);
                let mut zm_ = zq.clone();
                zm_[j] -= eps;
                let qm = softmax_t(&zm_, 1.0);
                let fd = (f(&qp) - f(&qm)) / (2.0 * eps as f64);
                assert!(
                    (fd - analytic[j] as f64).abs() < 5e-3,
                    "{name} grad[{j}]: fd {fd:.5} vs analytic {:.5}",
                    analytic[j]
                );
            }
        }
    }

    /// Table 3 scaling laws: ‖∇KL‖ ~ 1/√k, ‖∇TV‖ ~ √k/V, ‖∇LK^α‖ ~ 1/√k.
    #[test]
    fn magnitude_scaling_laws() {
        let (kl1, tv1, a1) = magnitudes_at_init(4096, 4);
        let (kl2, tv2, a2) = magnitudes_at_init(4096, 16);
        // KL and LK^α shrink like 1/sqrt(k): ratio ≈ sqrt(16/4) = 2
        assert!((kl1 / kl2 - 2.0).abs() < 0.1, "kl ratio {}", kl1 / kl2);
        assert!((a1 / a2 - 2.0).abs() < 0.2, "nla ratio {}", a1 / a2);
        // TV grows like sqrt(k): ratio ≈ 1/2
        assert!((tv1 / tv2 - 0.5).abs() < 0.1, "tv ratio {}", tv1 / tv2);
        // and at fixed k, TV shrinks like 1/V
        let (_, tv_v1, _) = magnitudes_at_init(1024, 8);
        let (_, tv_v2, _) = magnitudes_at_init(4096, 8);
        assert!(
            (tv_v1 / tv_v2 - 4.0).abs() < 0.3,
            "tv V-scaling {}",
            tv_v1 / tv_v2
        );
        // LK^α restores KL-scale magnitude: same order
        assert!(a1 / kl1 > 0.5 && a1 / kl1 < 2.0, "{a1} vs {kl1}");
    }

    #[test]
    fn grad_directions() {
        // TV and -log alpha push the same direction (A.4), KL differs.
        let mut rng = Pcg64::new(3, 0);
        let (p, q) = noisy_regime(&mut rng, 64, 8);
        let gtv = grad_tv(&p, &q);
        let gla = grad_log_alpha(&p, &q);
        let dot: f64 = gtv.iter().zip(&gla).map(|(&a, &b)| (a * b) as f64).sum();
        let cos = dot / (l2_norm(&gtv) * l2_norm(&gla));
        assert!(cos > 0.999, "cos {cos}");
    }
}
