//! Speculative-decoding core: the sampling and verification arithmetic
//! (Leviathan et al. 2023) plus the paper-specific analysis utilities.
//!
//! Submodules:
//!  * `sampling`  — temperature softmax, categorical / greedy / residual
//!    sampling, the EXACT rejection rule and the broken greedy-draft rule
//!    (Appendix D ablation)
//!  * `accept`    — acceptance bookkeeping: per-position rates, τ
//!  * `adaptive`  — the online speculation controller: per-position EWMA
//!    acceptance estimators + a cost model picking each round's draft
//!    budget (chain `k_active`, profiled tree topologies)
//!  * `gradients` — closed-form ∇KL / ∇TV / ∇L_LK^α on host, used by the
//!    Table 3 bench and cross-checked against finite differences in tests
//!  * `overlap`   — 1-D Gaussian/mixture overlap machinery for Figure 2

pub mod accept;
pub mod adaptive;
pub mod gradients;
pub mod overlap;
pub mod sampling;

pub use accept::AcceptanceStats;
pub use adaptive::{AlphaEwma, ControllerCfg, CostModel, SpecController};
pub use sampling::{softmax_t, SamplingMode};
