//! lk-spec CLI — the single entrypoint for the whole pipeline:
//!
//!   gen-data      generate the synthetic domain corpora
//!   train-target  pretrain target LMs (drives tgt_*_train_step artifacts)
//!   train-draft   train speculators with any LK-family objective
//!   eval          evaluate τ / speedup cells (cached as JSON)
//!   eval-all      run every cell the paper tables need
//!   serve         router + engine: demo burst, or --http for the SSE edge
//!   report        print cached results summary
//!
//! Typical full reproduction: `make experiments` (see Makefile), which is
//! gen-data → train-target --all → train-draft --all → cargo bench.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use lk_spec::config::{plan, LossSpec, TrainPreset, MTP_ORIGINAL_TAG};
use lk_spec::data::corpus::{Corpus, CorpusSpec};
use lk_spec::data::grammar::{Domain, DOMAINS};
use lk_spec::eval::{eval_cell, EvalMode, EvalSettings};
use lk_spec::runtime::Runtime;
use lk_spec::server::{Router, RouterConfig};
use lk_spec::train::{DraftTrainer, RunDirs, TargetTrainer};
use lk_spec::util::{Args, Json};
use lk_spec::{info, warn_log};

fn main() {
    let args = Args::parse_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    if args.flag("verbose") {
        lk_spec::util::log::set_level(3);
    } else if args.flag("quiet") {
        lk_spec::util::log::set_level(1);
    }
    let sub = args.subcommand.clone().unwrap_or_default();
    match sub.as_str() {
        "gen-data" => gen_data(args),
        "train-target" => train_target(args),
        "train-draft" => train_draft(args),
        "eval" => eval_cmd(args),
        "eval-all" => eval_all(args),
        "serve" => serve_demo(args),
        "report" => report(args),
        "" | "help" => {
            print_help();
            args.finish()
        }
        other => bail!("unknown subcommand '{other}' — try `lk-spec help`"),
    }
}

fn print_help() {
    println!(
        "lk-spec — LK-loss speculative decoding framework\n\
         \n\
         subcommands:\n\
           gen-data      --out data [--seed N] [--train-tokens N]\n\
           train-target  --target NAME | --all  [--data D] [--runs R] [--steps N]\n\
           train-draft   --draft A@T --loss L | --all  [--steps N]\n\
           eval          --draft A@T --loss L [--domain D] [--mode t0|t1|t1gd] [--k K]\n\
           eval-all      run every paper-table cell (idempotent, cached)\n\
           serve         --draft A@T --loss L [--requests N] — router demo.\n\
                         Adaptive speculation is ON by default (per-round K /\n\
                         profiled trees); fixed overrides: --spec-k K, --tree FxF\n\
                         (--tree auto = profiled topologies, --no-adaptive,\n\
                         --draft-cost C tune the controller).\n\
                         Paged KV: --kv-blocks N (pool budget, default 256),\n\
                         --kv-block-size N (tokens/block, default 16),\n\
                         --no-prefix-cache (disable cross-session sharing).\n\
                         Chunked prefill: on by default when the artifacts\n\
                         carry the chunk entry; --prefill-chunk 0 = off,\n\
                         N pins the expected chunk length; --prefill-budget M\n\
                         caps chunks per decode round (DESIGN.md §11).\n\
                         Robustness: --deadline-ms N (per-request latency\n\
                         budget; expired requests are shed with a typed\n\
                         verdict, 0 = off); shutdown drains gracefully.\n\
                         HTTP edge: --http ADDR (e.g. 127.0.0.1:8080) serves\n\
                         POST /v1/generate (SSE token streaming), /healthz,\n\
                         /metrics until stdin closes; --max-conns N,\n\
                         --stream-buffer N tune the edge (DESIGN.md §10).\n\
                         Online adaptation: --adapt (harvest live acceptance\n\
                         verdicts, background LK fine-tune, hot-swap draft\n\
                         weights at round boundaries), --adapt-interval N\n\
                         (rounds between fine-tunes), --trainer-cmd CMD\n\
                         (subprocess trainer, e.g. \"python3\n\
                         python/train/lk_finetune.py\"; DESIGN.md §12)\n\
           report        print cached result cells\n\
         \n\
         common options: --artifacts DIR (default artifacts), --runs DIR\n\
         (default runs), --data DIR (default data), --verbose, --quiet"
    );
}

fn dirs_of(args: &Args) -> (PathBuf, PathBuf, PathBuf) {
    (
        PathBuf::from(args.opt_or("artifacts", "artifacts")),
        PathBuf::from(args.opt_or("data", "data")),
        PathBuf::from(args.opt_or("runs", "runs")),
    )
}

fn gen_data(args: &Args) -> Result<()> {
    let (_, data, _) = dirs_of(args);
    let out = PathBuf::from(args.opt_or("out", data.to_str().unwrap()));
    let spec = CorpusSpec {
        seed: args.opt_u64("seed", CorpusSpec::default().seed)?,
        train_tokens: args.opt_usize("train-tokens", CorpusSpec::default().train_tokens)?,
        eval_docs: args.opt_usize("eval-docs", CorpusSpec::default().eval_docs)?,
        doc_len: CorpusSpec::default().doc_len,
    };
    args.finish()?;
    Corpus::generate(&out, &spec)?;
    Ok(())
}

fn train_target(args: &Args) -> Result<()> {
    let (artifacts, data, runs) = dirs_of(args);
    let all = args.flag("all");
    let only = args.opt("target").map(str::to_string);
    let steps_override = args.opt_usize("steps", 0)?;
    let force = args.flag("force");
    args.finish()?;

    let rt = Runtime::new(&artifacts)?;
    let corpus = Corpus::open(&data)?;
    let trainer = TargetTrainer {
        rt: &rt,
        dirs: RunDirs::new(&runs),
    };
    let targets: Vec<String> = match (&only, all) {
        (Some(t), _) => vec![t.clone()],
        (None, true) => rt.manifest.targets.keys().cloned().collect(),
        _ => bail!("pass --target NAME or --all"),
    };
    for t in &targets {
        if trainer.dirs.target_ckpt(t).exists() && !force {
            info!("[{t}] checkpoint exists, skipping (--force to retrain)");
            continue;
        }
        let mut preset = TrainPreset::target(t);
        if steps_override > 0 {
            preset.steps = steps_override;
        }
        trainer.train(t, &corpus, &preset, 50)?;
    }
    Ok(())
}

fn train_draft(args: &Args) -> Result<()> {
    let (artifacts, data, runs) = dirs_of(args);
    let all = args.flag("all");
    let draft = args.opt("draft").map(str::to_string);
    let loss = args.opt("loss").map(str::to_string);
    let steps_override = args.opt_usize("steps", 0)?;
    let force = args.flag("force");
    args.finish()?;

    let rt = Runtime::new(&artifacts)?;
    let corpus = Corpus::open(&data)?;
    let dirs = RunDirs::new(&runs);
    let trainer = DraftTrainer { rt: &rt, dirs };

    let runs_list = match (all, &draft, &loss) {
        (true, _, _) => plan::all_runs(),
        (false, Some(d), Some(l)) => {
            vec![lk_spec::config::RunSpec::new(d, LossSpec::parse(l)?)]
        }
        _ => bail!("pass --draft A@T --loss L, or --all"),
    };

    // MTP "original" baseline checkpoints (no training — Table 2 row).
    for r in &runs_list {
        if r.draft.starts_with("mtp@") {
            let stem = format!("{}__{MTP_ORIGINAL_TAG}", r.draft.replace('@', "_"));
            if !trainer.dirs.draft_ckpt(&stem).exists() {
                trainer.save_mtp_original(&r.draft)?;
                info!("saved MTP original checkpoint for {}", r.draft);
            }
        }
    }

    let total = runs_list.len();
    for (i, r) in runs_list.iter().enumerate() {
        let stem = r.stem();
        if trainer.dirs.draft_ckpt(&stem).exists() && !force {
            info!("[{stem}] checkpoint exists, skipping");
            continue;
        }
        let dspec = rt.manifest.draft(&r.draft)?;
        let mut preset = TrainPreset::draft(&dspec.target, &dspec.arch);
        if steps_override > 0 {
            preset.steps = steps_override;
        }
        info!(
            "=== draft run {}/{total}: {stem} ({} steps)",
            i + 1,
            preset.steps
        );
        trainer.train(&r.draft, &r.loss, &corpus, &preset, 50)?;
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let (artifacts, data, runs) = dirs_of(args);
    let draft = args.opt("draft").context("--draft required")?.to_string();
    let loss = args.opt_or("loss", "kl").to_string();
    let domain = Domain::parse(args.opt_or("domain", "chat"))?;
    let mode = EvalMode::parse(args.opt_or("mode", "t1"))?;
    let k = args.opt_usize("k", 7)?;
    let n_prompts = args.opt_usize("prompts", 16)?;
    let max_new = args.opt_usize("max-new", 40)?;
    let force = args.flag("force");
    args.finish()?;

    let rt = Runtime::new(&artifacts)?;
    let corpus = Corpus::open(&data)?;
    let dirs = RunDirs::new(&runs);
    let settings = EvalSettings {
        n_prompts,
        max_new,
        ..Default::default()
    };
    let cell = eval_cell(
        &rt, &dirs, &corpus, &draft, &loss, domain, mode, k, &settings, force,
    )?;
    println!(
        "tau={:.3} alpha_pos={:?} spec_tps={:.1} vanilla_tps={:.1} speedup={:.2}",
        cell.tau,
        cell.alpha_pos
            .iter()
            .map(|a| (a * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>(),
        cell.spec_tps,
        cell.vanilla_tps,
        cell.speedup
    );
    Ok(())
}

/// Every cell the paper tables/figures consume (idempotent; cached cells
/// are skipped unless --force).
fn eval_all(args: &Args) -> Result<()> {
    let (artifacts, data, runs) = dirs_of(args);
    let force = args.flag("force");
    args.finish()?;

    let rt = Runtime::new(&artifacts)?;
    let corpus = Corpus::open(&data)?;
    let dirs = RunDirs::new(&runs);
    let settings = EvalSettings::default();

    let mut cells = 0usize;
    let t0 = std::time::Instant::now();

    // Tables 1/2 (+ Table 4 columns measured alongside): all runs × 3
    // domains × {t0, t1} at the default chain length.
    for r in plan::all_runs() {
        let dspec = rt.manifest.draft(&r.draft)?;
        let k = if dspec.is_recurrent { 7 } else { dspec.k_heads };
        for domain in DOMAINS {
            for mode in [EvalMode::T0, EvalMode::T1] {
                eval_cell(
                    &rt, &dirs, &corpus, &r.draft, &r.loss.tag, domain, mode, k,
                    &settings, force,
                )?;
                cells += 1;
            }
        }
    }

    // MTP original row (Table 2).
    for domain in DOMAINS {
        for mode in [EvalMode::T0, EvalMode::T1] {
            eval_cell(
                &rt, &dirs, &corpus, "mtp@mtp-l", MTP_ORIGINAL_TAG, domain, mode, 7,
                &settings, force,
            )?;
            cells += 1;
        }
    }

    // Figure 1: τ vs K on the Qwen3 analog, chat domain, T=1.
    for r in plan::fig1() {
        for k in 1..=7usize {
            eval_cell(
                &rt, &dirs, &corpus, &r.draft, &r.loss.tag, Domain::Chat,
                EvalMode::T1, k, &settings, force,
            )?;
            cells += 1;
        }
    }

    // Appendix D: greedy-draft bug vs exact rejection sampling.
    for loss in [LossSpec::kl(), LossSpec::lk_lambda(3.0)] {
        for domain in DOMAINS {
            eval_cell(
                &rt, &dirs, &corpus, "eagle3@dense-s", &loss.tag, domain,
                EvalMode::T1GreedyDraft, 7, &settings, force,
            )?;
            cells += 1;
        }
    }

    info!(
        "eval-all: {cells} cells ready in {:.0}s",
        t0.elapsed().as_secs_f64()
    );
    // Perf accounting for the §Perf log.
    for (name, calls, ms) in rt.exec_report().iter().take(12) {
        info!("  exec {name}: {calls} calls, {ms:.0} ms total");
    }
    Ok(())
}

/// Serving demo: spin the router, submit a burst of prompts, print
/// metrics (the quickstart example does the same through the public API).
fn serve_demo(args: &Args) -> Result<()> {
    let (artifacts, data, runs) = dirs_of(args);
    let draft = args.opt_or("draft", "eagle3@dense-s").to_string();
    let loss = args.opt_or("loss", "lkl-eta3").to_string();
    let n_requests = args.opt_usize("requests", 12)?;
    let max_new = args.opt_usize("max-new", 32)?;
    // Per-request latency budget, measured from submission: past it the
    // request is shed (queued or mid-flight) with a typed
    // `deadline exceeded` verdict instead of being served late. 0 (the
    // default) disables deadlines.
    let deadline_ms = args.opt_u64("deadline-ms", 0)?;
    // The speculation controller is on by default; --spec-k and
    // --tree FxF are FIXED overrides (see DESIGN.md §4a). --tree auto
    // keeps tree decoding but lets the controller plan the topology
    // per round from measured per-level acceptance.
    let mut adaptive = lk_spec::server::AdaptiveOpts::default();
    let spec_k = args.opt("spec-k").map(|s| s.parse::<usize>()).transpose()
        .map_err(|_| anyhow::anyhow!("--spec-k expects an integer"))?;
    if spec_k.is_some() || args.flag("no-adaptive") {
        adaptive.enabled = false;
    }
    if let Some(c) = args.opt("draft-cost") {
        let c: f64 = c
            .parse()
            .map_err(|_| anyhow::anyhow!("--draft-cost expects a number"))?;
        adaptive.draft_cost = Some(c);
    }
    // Multi-candidate drafting: per-level fanouts, e.g. --tree 2x2
    // (parallel-head drafts only; see DESIGN.md §3).
    let tree = match args.opt("tree") {
        Some("auto") => {
            anyhow::ensure!(
                adaptive.enabled,
                "--tree auto plans topologies with the controller; it \
                 contradicts --no-adaptive / --spec-k (use --tree FxF for \
                 a fixed topology)"
            );
            adaptive.tree = true;
            None
        }
        Some(s) => Some(lk_spec::spec::sampling::TreeSpec::parse(s)?),
        None => None,
    };
    anyhow::ensure!(
        spec_k.is_none() || tree.is_none(),
        "--spec-k is a chain-length override; trees size by their \
         topology — drop one of --spec-k / --tree"
    );
    // Paged-KV admission (DESIGN.md §8): --kv-blocks caps resident KV,
    // --kv-block-size sets the sharing granularity, --no-prefix-cache
    // keeps the block pool but disables cross-session prefix sharing
    // (the dense-accounting baseline).
    let kv_defaults = lk_spec::server::PagedKvConfig::default();
    let paged_kv = lk_spec::server::PagedKvConfig {
        block_size: args.opt_usize("kv-block-size", kv_defaults.block_size)?,
        total_blocks: args.opt_usize("kv-blocks", kv_defaults.total_blocks)?,
        prefix_cache: !args.flag("no-prefix-cache"),
    };
    anyhow::ensure!(
        paged_kv.block_size > 0 && paged_kv.total_blocks > 0,
        "--kv-block-size and --kv-blocks must be positive"
    );
    // Chunked prefill (DESIGN.md §11): long joining prompts amortize
    // across decode rounds instead of stalling the group. On by default
    // whenever the artifacts carry the prefill_chunk_b1 entry.
    // --prefill-chunk 0 turns the lane off; a nonzero value pins the
    // expected chunk length (a mismatch with the lowered entry leaves
    // the lane off). --prefill-budget caps chunks per decode round.
    let prefill_chunk = args
        .opt("prefill-chunk")
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|_| anyhow::anyhow!("--prefill-chunk expects an integer"))?;
    let prefill_budget = args.opt_usize("prefill-budget", 4)?;
    // HTTP edge (DESIGN.md §10): --http ADDR serves SSE token streams
    // over the same router instead of running the demo burst.
    let http_defaults = lk_spec::server::HttpOpts::default();
    let http_addr = args.opt("http").map(str::to_string);
    let http_opts = lk_spec::server::HttpOpts {
        max_conns: args.opt_usize("max-conns", http_defaults.max_conns)?,
        stream_buffer: args.opt_usize("stream-buffer", http_defaults.stream_buffer)?,
        default_max_new: max_new,
        ..http_defaults
    };
    anyhow::ensure!(
        http_opts.max_conns > 0 && http_opts.stream_buffer > 0,
        "--max-conns and --stream-buffer must be positive"
    );
    // Online drafter adaptation (DESIGN.md §12): --adapt turns on the
    // harvest → background fine-tune → hot-swap loop with the built-in
    // sim trainer; --trainer-cmd "python3 python/train/lk_finetune.py"
    // runs a real subprocess trainer over the JSONL protocol instead
    // (and implies --adapt); --adapt-interval N sets the decode-round
    // cadence between fine-tune launches.
    let adapt_interval = args.opt_u64("adapt-interval", 0)?;
    let trainer_cmd = args.opt("trainer-cmd").map(str::to_string);
    let adapt_cfg = if args.flag("adapt") || adapt_interval > 0 || trainer_cmd.is_some() {
        let mut cfg = lk_spec::server::AdaptConfig {
            out_dir: runs.join("adapt"),
            ..Default::default()
        };
        if adapt_interval > 0 {
            cfg.interval_rounds = adapt_interval;
        }
        if let Some(cmd) = &trainer_cmd {
            let argv: Vec<String> = cmd.split_whitespace().map(str::to_string).collect();
            anyhow::ensure!(!argv.is_empty(), "--trainer-cmd expects a command line");
            cfg.trainer = lk_spec::server::TrainerSpec::Command(argv);
        }
        Some(cfg)
    } else {
        None
    };
    args.finish()?;

    let corpus = Corpus::open(&data)?;
    let prompts = corpus.load(Domain::Chat, "eval")?.prompts(n_requests, 16);

    let router_cfg = RouterConfig {
        paged_kv: Some(paged_kv),
        prefill_chunk,
        prefill_budget,
        adapt: adapt_cfg,
        ..Default::default()
    };
    let router = Router::spawn(router_cfg, move || {
        // Built inside the worker thread: PJRT state never crosses threads.
        let rt = Box::leak(Box::new(Runtime::new(&artifacts)?));
        let dirs = RunDirs::new(&runs);
        let dspec = rt.manifest.draft(&draft)?.clone();
        let tckpt = lk_spec::tensor::read_checkpoint(&dirs.target_ckpt(&dspec.target))?;
        let stem = format!("{}__{loss}", draft.replace('@', "_"));
        let dckpt = lk_spec::tensor::read_checkpoint(&dirs.draft_ckpt(&stem))?;
        let vocab_map = if dspec.arch == "eagle3" {
            let j = Json::parse_file(&dirs.vocab_map())?;
            Some(
                j.get("map")
                    .as_arr()
                    .context("map")?
                    .iter()
                    .map(|x| x.as_i64().unwrap_or(0) as i32)
                    .collect::<Vec<i32>>(),
            )
        } else {
            None
        };
        // The engine implements SchedulerCore: the router's worker wraps
        // it in a continuous-batching Scheduler (join/leave mid-flight,
        // long-tail downshift; the speculation controller lives in the
        // engine itself).
        let opts = lk_spec::server::EngineOpts {
            k_draft: spec_k.unwrap_or(lk_spec::server::EngineOpts::default().k_draft),
            tree: tree.clone(),
            adaptive: adaptive.clone(),
            ..Default::default()
        };
        lk_spec::server::SpecEngine::new(rt, &draft, &tckpt, &dckpt, vocab_map, opts)
    })?;

    if let Some(addr) = http_addr {
        return serve_http(&addr, router, http_opts);
    }

    info!("submitting {} requests…", prompts.len());
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = prompts
        .iter()
        .map(|p| {
            let deadline = (deadline_ms > 0)
                .then(|| std::time::Instant::now() + std::time::Duration::from_millis(deadline_ms));
            router.submit_with(p.clone(), max_new, deadline).map(|s| s.rx)
        })
        .collect::<Result<_>>()?;
    let mut total_tokens = 0usize;
    let mut taus = Vec::new();
    for (i, rx) in receivers.into_iter().enumerate() {
        match rx.recv()? {
            Ok(res) => {
                total_tokens += res.tokens.len();
                taus.push(res.stats.tau());
                info!(
                    "request {i}: {} tokens, tau={:.2}, queue {:.0} ms, ttft {:.0} ms, total {:.0} ms",
                    res.tokens.len(),
                    res.stats.tau(),
                    res.queue_ms,
                    res.ttft_ms,
                    res.latency_ms
                );
            }
            Err(e) => warn_log!("request {i} failed: {e}"),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let mean_tau = taus.iter().sum::<f64>() / taus.len().max(1) as f64;
    println!(
        "served {} requests, {total_tokens} tokens in {secs:.2}s ({:.1} tok/s), mean tau {mean_tau:.2}",
        prompts.len(),
        total_tokens as f64 / secs,
    );
    router.shutdown();
    Ok(())
}

/// Serve the router over the HTTP edge until stdin closes, then drain
/// gracefully: `/healthz` flips to 503 first (load balancers stop
/// routing), in-flight streams finish, new requests get 503.
fn serve_http(addr: &str, router: Router, opts: lk_spec::server::HttpOpts) -> Result<()> {
    let router = std::sync::Arc::new(router);
    let server = lk_spec::server::HttpServer::spawn(addr, std::sync::Arc::clone(&router), opts)?;
    let bound = server.addr();
    println!("serving on http://{bound}  (close stdin / Ctrl-D to drain and exit)");
    println!(
        "  curl -N -X POST http://{bound}/v1/generate \\\n    -d '{{\"prompt\": [1, 2, 3], \"max_new\": 32}}'"
    );
    let mut sink = String::new();
    let _ = std::io::Read::read_to_string(&mut std::io::stdin().lock(), &mut sink);
    info!("stdin closed — draining the http edge");
    server.shutdown();
    if let Ok(r) = std::sync::Arc::try_unwrap(router) {
        r.shutdown();
    }
    Ok(())
}

fn report(args: &Args) -> Result<()> {
    let (_, _, runs) = dirs_of(args);
    args.finish()?;
    let dir = runs.join("results");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("no results in {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    println!("{:<64} {:>6} {:>8} {:>8}", "cell", "tau", "tps", "speedup");
    for p in entries {
        if let Ok(c) = lk_spec::eval::read_cell(&p) {
            let name = p.file_stem().unwrap().to_string_lossy();
            println!(
                "{:<64} {:>6.3} {:>8.1} {:>8.2}",
                name, c.tau, c.spec_tps, c.speedup
            );
        }
    }
    Ok(())
}
