//! Training orchestrator (L3): drives the AOT-lowered train-step
//! executables for target pretraining and draft distillation.
//!
//! The Rust side owns everything stateful: parameter/optimizer buffers,
//! the cosine LR schedule, batching, seeding, metric logs and
//! checkpointing. The XLA artifacts are pure functions; one draft
//! train-step artifact serves every objective because the loss selection
//! (weights, η, γ) is runtime data — the paper's "drop-in replacement"
//! property made literal.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{LossSpec, TrainPreset};
use crate::data::corpus::{Corpus, MixtureBatcher};
use crate::data::vocab::build_vocab_map;
use crate::runtime::{Runtime, TensorSpec};
use crate::tensor::{read_checkpoint, write_checkpoint, Checkpoint, HostTensor};
#[cfg(test)]
use crate::tensor::DType;
use crate::util::{Json, Pcg64};

/// Where runs live on disk.
pub struct RunDirs {
    pub root: PathBuf,
}

impl RunDirs {
    pub fn new(root: &Path) -> RunDirs {
        RunDirs {
            root: root.to_path_buf(),
        }
    }

    pub fn target_ckpt(&self, target: &str) -> PathBuf {
        self.root.join("targets").join(format!("{target}.lkt"))
    }

    pub fn draft_ckpt(&self, stem: &str) -> PathBuf {
        self.root.join("drafts").join(format!("{stem}.lkt"))
    }

    pub fn metrics(&self, stem: &str) -> PathBuf {
        self.root.join("metrics").join(format!("{stem}.json"))
    }

    pub fn vocab_map(&self) -> PathBuf {
        self.root.join("vocab_map.json")
    }

    pub fn results(&self, name: &str) -> PathBuf {
        self.root.join("results").join(format!("{name}.json"))
    }
}

// ---------------------------------------------------------------------------
// param pytree <-> checkpoint plumbing
// ---------------------------------------------------------------------------

/// Save ordered param tensors under their manifest names.
pub fn params_to_checkpoint(
    specs: &[TensorSpec],
    params: &[HostTensor],
    meta: Json,
) -> Checkpoint {
    assert_eq!(specs.len(), params.len());
    let mut ckpt = Checkpoint::new(meta);
    for (s, p) in specs.iter().zip(params) {
        ckpt.tensors.insert(s.name.clone(), p.clone());
    }
    ckpt
}

/// Load params in manifest order, validating shapes.
pub fn checkpoint_to_params(specs: &[TensorSpec], ckpt: &Checkpoint) -> Result<Vec<HostTensor>> {
    specs
        .iter()
        .map(|s| {
            let t = ckpt.get(&s.name)?;
            if t.shape != s.shape {
                bail!(
                    "checkpoint tensor '{}' shape {:?} != manifest {:?}",
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            Ok(t.clone())
        })
        .collect()
}

fn zeros_like(specs: &[TensorSpec]) -> Vec<HostTensor> {
    specs
        .iter()
        .map(|s| HostTensor::zeros(s.dtype, &s.shape))
        .collect()
}

fn seed_tensor(seed: u64) -> HostTensor {
    HostTensor::from_u32(&[2], &[(seed >> 32) as u32, seed as u32])
}

// ---------------------------------------------------------------------------
// target pretraining
// ---------------------------------------------------------------------------

pub struct TargetTrainer<'rt> {
    pub rt: &'rt Runtime,
    pub dirs: RunDirs,
}

impl<'rt> TargetTrainer<'rt> {
    /// Pretrain one target LM on the domain mixture; writes checkpoint +
    /// loss-curve metrics. Returns the final LM loss.
    pub fn train(
        &self,
        target: &str,
        corpus: &Corpus,
        preset: &TrainPreset,
        log_every: usize,
    ) -> Result<f64> {
        let spec = self.rt.manifest.target(target)?.clone();
        let init = self.rt.target_entry(target, "init")?;
        let step_exe = self.rt.target_entry(target, "train_step")?;

        let mut params = init.run(&[seed_tensor(preset.seed ^ hash_name(target))])?;
        let mut m = zeros_like(&spec.params);
        let mut v = zeros_like(&spec.params);

        let datasets = corpus.load_mixture("train")?;
        let mut batcher = MixtureBatcher::new(&datasets);
        let mut rng = Pcg64::new(preset.seed, hash_name(target));

        let b = self.rt.manifest.train_batch;
        let w = self.rt.manifest.span + self.rt.manifest.k_heads + 2;
        let mut curve = Vec::new();
        let mut last = f64::NAN;
        let t0 = std::time::Instant::now();
        for step in 0..preset.steps {
            let tokens = HostTensor::from_i32(&[b, w], &batcher.sample_batch(&mut rng, b, w));
            let mut args = Vec::with_capacity(3 * params.len() + 3);
            args.extend(params.iter().cloned());
            args.extend(m.iter().cloned());
            args.extend(v.iter().cloned());
            args.push(HostTensor::scalar_i32(step as i32 + 1));
            args.push(tokens);
            args.push(HostTensor::scalar_f32(preset.lr_at(step) as f32));
            let mut out = step_exe.run(&args)?;
            let metrics = out.pop().context("missing metrics")?.as_f32();
            let n = spec.params.len();
            v = out.split_off(2 * n);
            m = out.split_off(n);
            params = out;
            last = metrics[0] as f64;
            if step % log_every == 0 || step + 1 == preset.steps {
                curve.push(Json::arr_f64(&[step as f64, metrics[0] as f64, metrics[1] as f64]));
                crate::info!(
                    "[{target}] step {step}/{}: lm_loss={:.4} mtp={:.4}",
                    preset.steps,
                    metrics[0],
                    metrics[1]
                );
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let meta = Json::obj(vec![
            ("kind", Json::Str("target".into())),
            ("target", Json::Str(target.into())),
            ("steps", Json::Num(preset.steps as f64)),
            ("seed", Json::Num(preset.seed as f64)),
            ("final_loss", Json::Num(last)),
        ]);
        write_checkpoint(
            &self.dirs.target_ckpt(target),
            &params_to_checkpoint(&spec.params, &params, meta),
        )?;
        Json::obj(vec![
            ("curve", Json::Arr(curve)),
            ("seconds", Json::Num(secs)),
        ])
        .write_file(&self.dirs.metrics(&format!("target_{target}")))?;
        crate::info!("[{target}] pretrained in {secs:.0}s, final loss {last:.4}");
        Ok(last)
    }
}

// ---------------------------------------------------------------------------
// draft training
// ---------------------------------------------------------------------------

pub struct DraftTrainer<'rt> {
    pub rt: &'rt Runtime,
    pub dirs: RunDirs,
}

/// Per-step metric record for the training log.
#[derive(Debug, Clone)]
pub struct DraftStepMetrics {
    pub loss: f64,
    pub mean_alpha: f64,
    pub alpha_heads: Vec<f64>,
    pub lambda_heads: Vec<f64>,
}

impl<'rt> DraftTrainer<'rt> {
    /// Ensure the truncated draft vocabulary exists (computed from the
    /// training mixture, FR-Spec style) and return it.
    pub fn vocab_map(&self, corpus: &Corpus) -> Result<Vec<i32>> {
        let path = self.dirs.vocab_map();
        if path.exists() {
            let j = Json::parse_file(&path)?;
            return Ok(j
                .get("map")
                .as_arr()
                .context("vocab map")?
                .iter()
                .map(|x| x.as_i64().unwrap_or(0) as i32)
                .collect());
        }
        let datasets = corpus.load_mixture("train")?;
        let (map, coverage) =
            build_vocab_map(&datasets, self.rt.manifest.vocab, self.rt.manifest.draft_vocab);
        Json::obj(vec![
            ("map", Json::Arr(map.iter().map(|&i| Json::Num(i as f64)).collect())),
            ("coverage", Json::Num(coverage)),
        ])
        .write_file(&path)?;
        crate::info!(
            "built draft vocab map ({} of {}, {:.1}% corpus mass)",
            map.len(),
            self.rt.manifest.vocab,
            coverage * 100.0
        );
        Ok(map)
    }

    /// Initialize draft params: from seed, or for the MTP arch from the
    /// pretrained target module (paper §5.2: fine-tune the released MTP).
    pub fn init_params(
        &self,
        draft: &str,
        target_ckpt: &Checkpoint,
        seed: u64,
    ) -> Result<Vec<HostTensor>> {
        let dspec = self.rt.manifest.draft(draft)?.clone();
        if dspec.arch == "mtp" {
            return mtp_params_from_target(&dspec.params, target_ckpt);
        }
        let init = self.rt.draft_entry(draft, "init")?;
        init.run(&[seed_tensor(seed ^ hash_name(draft))])
    }

    /// Train one draft with the given objective. Returns final metrics.
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        draft: &str,
        loss: &LossSpec,
        corpus: &Corpus,
        preset: &TrainPreset,
        log_every: usize,
    ) -> Result<DraftStepMetrics> {
        let dspec = self.rt.manifest.draft(draft)?.clone();
        let tname = dspec.target.clone();
        let tspec = self.rt.manifest.target(&tname)?.clone();
        let step_exe = self.rt.draft_entry(draft, "train_step")?;

        let tckpt_path = self.dirs.target_ckpt(&tname);
        if !tckpt_path.exists() {
            bail!(
                "target checkpoint {} missing — run `lk-spec train-target --target {tname}` first",
                tckpt_path.display()
            );
        }
        let tckpt = read_checkpoint(&tckpt_path)?;
        let tparams = checkpoint_to_params(&tspec.params, &tckpt)?;

        let mut dparams = self.init_params(draft, &tckpt, preset.seed)?;
        let mut m = zeros_like(&dspec.params);
        let mut v = zeros_like(&dspec.params);

        let needs_vmap = dspec.arch == "eagle3";
        let vmap = if needs_vmap {
            Some(HostTensor::from_i32(
                &[self.rt.manifest.draft_vocab],
                &self.vocab_map(corpus)?,
            ))
        } else {
            None
        };

        let datasets = corpus.load_mixture("train")?;
        let mut batcher = MixtureBatcher::new(&datasets);
        let mut rng = Pcg64::new(preset.seed, hash_name(draft) ^ hash_name(&loss.tag));

        let b = self.rt.manifest.train_batch;
        let w = self.rt.manifest.span + self.rt.manifest.k_heads + 1;
        let k = self.rt.manifest.k_heads;
        let stem = format!("{}__{}", draft.replace('@', "_"), loss.tag);
        let mut curve = Vec::new();
        let mut final_metrics = DraftStepMetrics {
            loss: f64::NAN,
            mean_alpha: 0.0,
            alpha_heads: vec![0.0; k],
            lambda_heads: vec![0.0; k],
        };
        let t0 = std::time::Instant::now();
        for step in 0..preset.steps {
            let tokens = HostTensor::from_i32(&[b, w], &batcher.sample_batch(&mut rng, b, w));
            let mut args = Vec::with_capacity(tparams.len() + 3 * dparams.len() + 8);
            args.extend(tparams.iter().cloned());
            args.extend(dparams.iter().cloned());
            args.extend(m.iter().cloned());
            args.extend(v.iter().cloned());
            args.push(HostTensor::scalar_i32(step as i32 + 1));
            args.push(tokens);
            args.push(HostTensor::from_f32(&[4], &loss.weights));
            args.push(HostTensor::scalar_f32(loss.eta));
            args.push(HostTensor::scalar_f32(preset.gamma as f32));
            args.push(HostTensor::scalar_f32(preset.lr_at(step) as f32));
            if let Some(vm) = &vmap {
                args.push(vm.clone());
            }
            let mut out = step_exe.run(&args)?;
            let metrics = out.pop().context("missing metrics")?.as_f32();
            let n = dspec.params.len();
            v = out.split_off(2 * n);
            m = out.split_off(n);
            dparams = out;
            final_metrics = DraftStepMetrics {
                loss: metrics[0] as f64,
                mean_alpha: metrics[1] as f64,
                alpha_heads: metrics[2..2 + k].iter().map(|&x| x as f64).collect(),
                lambda_heads: metrics[2 + k..2 + 2 * k].iter().map(|&x| x as f64).collect(),
            };
            if step % log_every == 0 || step + 1 == preset.steps {
                curve.push(Json::arr_f64(&[
                    step as f64,
                    final_metrics.loss,
                    final_metrics.mean_alpha,
                ]));
                crate::info!(
                    "[{stem}] step {step}/{}: loss={:.4} alpha={:.4} lam1={:.3}",
                    preset.steps,
                    final_metrics.loss,
                    final_metrics.mean_alpha,
                    final_metrics.lambda_heads[0]
                );
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let meta = Json::obj(vec![
            ("kind", Json::Str("draft".into())),
            ("draft", Json::Str(draft.into())),
            ("loss", Json::Str(loss.tag.clone())),
            ("steps", Json::Num(preset.steps as f64)),
            ("seed", Json::Num(preset.seed as f64)),
            ("final_alpha", Json::Num(final_metrics.mean_alpha)),
        ]);
        write_checkpoint(
            &self.dirs.draft_ckpt(&stem),
            &params_to_checkpoint(&dspec.params, &dparams, meta),
        )?;
        Json::obj(vec![
            ("curve", Json::Arr(curve)),
            ("seconds", Json::Num(secs)),
            (
                "alpha_heads",
                Json::arr_f64(&final_metrics.alpha_heads),
            ),
            (
                "lambda_heads",
                Json::arr_f64(&final_metrics.lambda_heads),
            ),
        ])
        .write_file(&self.dirs.metrics(&stem))?;
        crate::info!(
            "[{stem}] trained in {secs:.0}s, mean alpha {:.4}",
            final_metrics.mean_alpha
        );
        Ok(final_metrics)
    }

    /// Write the "MTP original" pseudo-checkpoint: the module exactly as
    /// target pretraining left it (Table 2 baseline row).
    pub fn save_mtp_original(&self, draft: &str) -> Result<()> {
        let dspec = self.rt.manifest.draft(draft)?.clone();
        let tckpt = read_checkpoint(&self.dirs.target_ckpt(&dspec.target))?;
        let params = mtp_params_from_target(&dspec.params, &tckpt)?;
        let stem = format!(
            "{}__{}",
            draft.replace('@', "_"),
            crate::config::MTP_ORIGINAL_TAG
        );
        let meta = Json::obj(vec![
            ("kind", Json::Str("draft".into())),
            ("draft", Json::Str(draft.into())),
            ("loss", Json::Str(crate::config::MTP_ORIGINAL_TAG.into())),
        ]);
        write_checkpoint(
            &self.dirs.draft_ckpt(&stem),
            &params_to_checkpoint(&dspec.params, &params, meta),
        )?;
        Ok(())
    }
}

/// Restructure the target's pretrained MTP module into the draft layout
/// (mirror of python drafts.init_mtp_from_target; the name mapping is the
/// contract documented there): fc_fuse <- identity, fc_in <- mtp/proj,
/// everything else <- mtp/<name>.
pub fn mtp_params_from_target(
    dspecs: &[TensorSpec],
    tckpt: &Checkpoint,
) -> Result<Vec<HostTensor>> {
    dspecs
        .iter()
        .map(|s| {
            if s.name == "fc_fuse" {
                let d = s.shape[0];
                anyhow::ensure!(s.shape == vec![d, d], "fc_fuse must be square");
                let mut eye = vec![0f32; d * d];
                for i in 0..d {
                    eye[i * d + i] = 1.0;
                }
                return Ok(HostTensor::from_f32(&s.shape, &eye));
            }
            let tname = if s.name == "fc_in" {
                "mtp/proj".to_string()
            } else {
                format!("mtp/{}", s.name)
            };
            let t = tckpt.get(&tname)?;
            anyhow::ensure!(
                t.shape == s.shape,
                "mtp param '{}' shape {:?} != draft '{}' {:?}",
                tname,
                t.shape,
                s.name,
                s.shape
            );
            Ok(t.clone())
        })
        .collect()
}

pub fn hash_name(s: &str) -> u64 {
    // FNV-1a — stable across runs/platforms (std hasher is not).
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_stable() {
        assert_eq!(hash_name("dense-s"), hash_name("dense-s"));
        assert_ne!(hash_name("dense-s"), hash_name("dense-m"));
    }

    #[test]
    fn checkpoint_param_roundtrip() {
        let specs = vec![
            TensorSpec {
                name: "a/w".into(),
                shape: vec![2, 2],
                dtype: DType::F32,
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![3],
                dtype: DType::F32,
            },
        ];
        let params = vec![
            HostTensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            HostTensor::from_f32(&[3], &[5.0, 6.0, 7.0]),
        ];
        let ckpt = params_to_checkpoint(&specs, &params, Json::Null);
        let back = checkpoint_to_params(&specs, &ckpt).unwrap();
        assert_eq!(back, params);
        // shape mismatch rejected
        let bad_specs = vec![TensorSpec {
            name: "a/w".into(),
            shape: vec![4],
            dtype: DType::F32,
        }];
        assert!(checkpoint_to_params(&bad_specs, &ckpt).is_err());
    }
}
