//! `.lkt` checkpoint format: named tensors + a JSON metadata blob.
//!
//! Layout (all integers little-endian):
//!
//!   magic   "LKT1" (4 bytes)
//!   meta_len: u32, meta: JSON bytes (run config, step, seeds, ...)
//!   count:  u32
//!   repeated count times:
//!     name_len: u32, name bytes (utf-8)
//!     dtype:  u8 (0=f32, 1=i32, 2=u32)
//!     rank:   u8
//!     dims:   rank × u32
//!     data:   product(dims) × 4 bytes
//!
//! Deliberately minimal — no compression, no alignment tricks — but with
//! full validation on read. Tested by round-trip and corruption tests.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{DType, HostTensor};
use crate::util::Json;

const MAGIC: &[u8; 4] = b"LKT1";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub meta: Json,
    pub tensors: BTreeMap<String, HostTensor>,
}

impl Checkpoint {
    pub fn new(meta: Json) -> Checkpoint {
        Checkpoint {
            meta,
            tensors: BTreeMap::new(),
        }
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U32 => 2,
    }
}

fn code_dtype(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::I32,
        2 => DType::U32,
        other => bail!("bad dtype code {other}"),
    })
}

pub fn write_checkpoint(path: &Path, ckpt: &Checkpoint) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("lkt.tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        let meta = ckpt.meta.to_string().into_bytes();
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(&meta)?;
        f.write_all(&(ckpt.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &ckpt.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[dtype_code(t.dtype), t.shape.len() as u8])?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            f.write_all(&t.data)?;
        }
    }
    // Atomic replace so a crash mid-write never corrupts a checkpoint.
    std::fs::rename(&tmp, path)?;
    Ok(())
}

pub fn read_checkpoint(path: &Path) -> Result<Checkpoint> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an LKT1 checkpoint", path.display());
    }
    let meta_len = read_u32(&mut f)? as usize;
    if meta_len > 64 << 20 {
        bail!("unreasonable metadata size {meta_len}");
    }
    let mut meta_bytes = vec![0u8; meta_len];
    f.read_exact(&mut meta_bytes)?;
    let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint metadata: {e}"))?;
    let count = read_u32(&mut f)? as usize;
    if count > 1 << 20 {
        bail!("unreasonable tensor count {count}");
    }
    let mut tensors = BTreeMap::new();
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            bail!("unreasonable tensor name length {name_len}");
        }
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let dtype = code_dtype(hdr[0])?;
        let rank = hdr[1] as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(&mut f)? as usize);
        }
        let n: usize = shape.iter().product();
        if n > 1 << 28 {
            bail!("unreasonable tensor size {n} for '{name}'");
        }
        let mut data = vec![0u8; n * dtype.size()];
        f.read_exact(&mut data)
            .with_context(|| format!("truncated tensor data for '{name}'"))?;
        tensors.insert(name, HostTensor { dtype, shape, data });
    }
    Ok(Checkpoint { meta, tensors })
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lkt_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new(Json::obj(vec![("step", Json::Num(7.0))]));
        c.tensors.insert(
            "layer/w".into(),
            HostTensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]),
        );
        c.tensors
            .insert("tokens".into(), HostTensor::from_i32(&[3], &[5, -6, 7]));
        let path = tmpdir().join("rt.lkt");
        write_checkpoint(&path, &c).unwrap();
        let c2 = read_checkpoint(&path).unwrap();
        assert_eq!(c2.meta.get("step").as_f64(), Some(7.0));
        assert_eq!(c2.tensors.len(), 2);
        assert_eq!(c2.get("layer/w").unwrap().as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c2.get("tokens").unwrap().as_i32(), vec![5, -6, 7]);
        assert_eq!(c2.get("tokens").unwrap().shape, vec![3]);
    }

    #[test]
    fn rejects_corruption() {
        let path = tmpdir().join("bad.lkt");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(read_checkpoint(&path).is_err());
        let mut c = Checkpoint::new(Json::Null);
        c.tensors
            .insert("t".into(), HostTensor::from_f32(&[4], &[0.0; 4]));
        write_checkpoint(&path, &c).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3); // chop tensor data
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_checkpoint(&path).is_err());
    }
}
