//! Host tensor representation and the `.lkt` checkpoint format.
//!
//! `HostTensor` is the bridge type between the Rust world (corpus
//! batches, checkpoints, sampled tokens) and the XLA runtime (Literals).
//! Conversions to/from `xla::Literal` live in `runtime::pack` so this
//! module stays pure and unit-testable without PJRT.

pub mod checkpoint;

pub use checkpoint::{read_checkpoint, write_checkpoint, Checkpoint};

/// Element type of a host tensor (matches the manifest dtype strings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "uint32" => Ok(DType::U32),
            other => anyhow::bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

/// Dense row-major host tensor. Data is stored as raw little-endian bytes
/// so checkpoint IO and literal packing are straight memcpys.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor {
            dtype,
            shape: shape.to_vec(),
            data: vec![0u8; n * dtype.size()],
        }
    }

    pub fn from_f32(shape: &[usize], values: &[f32]) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_u32(shape: &[usize], values: &[u32]) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor {
            dtype: DType::U32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::from_f32(&[], &[v])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::from_i32(&[], &[v])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// f32 view at a flat offset range (no copy of the whole tensor).
    pub fn f32_at(&self, idx: usize) -> f32 {
        assert_eq!(self.dtype, DType::F32);
        let o = idx * 4;
        f32::from_le_bytes([
            self.data[o],
            self.data[o + 1],
            self.data[o + 2],
            self.data[o + 3],
        ])
    }

    pub fn i32_at(&self, idx: usize) -> i32 {
        assert_eq!(self.dtype, DType::I32);
        let o = idx * 4;
        i32::from_le_bytes([
            self.data[o],
            self.data[o + 1],
            self.data[o + 2],
            self.data[o + 3],
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 3], &[1.0, -2.5, 3.0, 0.0, 5.5, -6.25]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32(), vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25]);
        assert_eq!(t.f32_at(4), 5.5);
    }

    #[test]
    fn zeros_and_scalars() {
        let z = HostTensor::zeros(DType::I32, &[4]);
        assert_eq!(z.as_i32(), vec![0; 4]);
        assert_eq!(HostTensor::scalar_f32(7.0).as_f32(), vec![7.0]);
        assert_eq!(HostTensor::scalar_i32(-3).as_i32(), vec![-3]);
    }
}
