//! Deterministic HTTP streaming-edge suite (DESIGN.md §10): the whole
//! serving stack — parser, SSE framing, router event streams, scheduler
//! — driven over REAL loopback TCP against [`SimCore`], PJRT-free.
//!
//! The client side here is deliberately independent of the server's
//! encoders: a minimal chunked-transfer decoder and SSE splitter live
//! in this file, so a framing bug cannot hide behind a shared helper.
//! Edge chaos (mid-stream disconnects) is declared through the same
//! [`FaultPlan`] vocabulary the ChaosCore engine faults use — the test
//! client reads `drop_conn_at` and acts it out by severing its socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lk_spec::server::batcher::BatcherConfig;
use lk_spec::server::scheduler::{FaultPlan, SimCore};
use lk_spec::server::{HttpOpts, HttpServer, Router, RouterConfig};
use lk_spec::util::Json;

// ---------------------------------------------------------------------------
// harness: SimCore server + raw-TCP client helpers
// ---------------------------------------------------------------------------

/// Spin up the full stack on a loopback port the OS picks.
fn edge(
    buckets: Vec<usize>,
    queue_cap: usize,
    max_wait: Duration,
    plan: FaultPlan,
) -> HttpServer {
    let cfg = RouterConfig {
        batcher: BatcherConfig {
            buckets: buckets.clone(),
            max_wait,
            queue_cap,
        },
        idle_poll: Duration::from_micros(200),
        ..Default::default()
    };
    let router =
        Router::spawn(cfg, move || Ok(SimCore::new(4, 7, buckets).with_fault_plan(plan)))
            .expect("router spawn");
    HttpServer::spawn("127.0.0.1:0", Arc::new(router), HttpOpts::default())
        .expect("http edge spawn")
}

fn connect(server: &HttpServer) -> TcpStream {
    let s = TcpStream::connect(server.addr()).expect("loopback connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

/// One request, whole response. The edge keeps connections alive, so
/// the helper injects `Connection: close` (after the request line) to
/// get the old answer-and-close shape; sequential reuse is pinned
/// separately by `two_requests_one_connection`.
fn request(server: &HttpServer, raw: &str) -> Vec<u8> {
    let raw = raw.replacen("\r\n", "\r\nConnection: close\r\n", 1);
    let mut s = connect(server);
    s.write_all(raw.as_bytes()).unwrap();
    let mut out = Vec::new();
    s.read_to_end(&mut out).expect("read response");
    out
}

/// Read exactly one `Content-Length`-framed response off a keep-alive
/// socket (`read_to_end` would block until the server's idle timeout).
fn read_one_response(s: &mut TcpStream) -> Vec<u8> {
    let mut out = Vec::new();
    let mut buf = [0u8; 1024];
    let (head_end, content_length) = loop {
        if let Some(he) = find(&out, b"\r\n\r\n") {
            let head = std::str::from_utf8(&out[..he]).unwrap();
            let cl = head
                .split("\r\n")
                .skip(1)
                .find_map(|l| {
                    l.split_once(':')
                        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
                        .map(|(_, v)| v.trim().parse::<usize>().unwrap())
                })
                .expect("content-length header");
            break (he, cl);
        }
        let n = s.read(&mut buf).expect("response head");
        assert!(n > 0, "server closed mid-head");
        out.extend_from_slice(&buf[..n]);
    };
    while out.len() < head_end + 4 + content_length {
        let n = s.read(&mut buf).expect("response body");
        assert!(n > 0, "server closed mid-body");
        out.extend_from_slice(&buf[..n]);
    }
    out.truncate(head_end + 4 + content_length);
    out
}

fn post_generate(server: &HttpServer, body: &str) -> Vec<u8> {
    request(
        server,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Open a generate stream and return the socket once `min_token_events`
/// `token` frames (and the head) have arrived, plus the bytes so far.
fn open_stream(server: &HttpServer, body: &str, min_token_events: usize) -> (TcpStream, Vec<u8>) {
    let mut s = connect(server);
    s.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut seen = Vec::new();
    let mut buf = [0u8; 1024];
    while count(&seen, b"event: token") < min_token_events
        || count(&seen, b"event: queued") < 1
    {
        let n = s.read(&mut buf).expect("stream bytes");
        assert!(n > 0, "server closed before the expected events arrived");
        seen.extend_from_slice(&buf[..n]);
    }
    (s, seen)
}

fn metrics_text(server: &HttpServer) -> String {
    let resp = parse_response(&request(server, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n"));
    assert_eq!(resp.status, 200);
    String::from_utf8(resp.body).unwrap()
}

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn body_json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).unwrap()).expect("JSON body")
    }
}

fn parse_response(raw: &[u8]) -> Response {
    let head_end = find(raw, b"\r\n\r\n").expect("response head terminator");
    let head = std::str::from_utf8(&raw[..head_end]).unwrap();
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line
        .strip_prefix("HTTP/1.1 ")
        .unwrap_or_else(|| panic!("bad status line: {status_line:?}"))
        .split(' ')
        .next()
        .unwrap()
        .parse()
        .unwrap();
    let mut headers = Vec::new();
    for line in lines {
        let (k, v) = line.split_once(':').expect("header colon");
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let rest = &raw[head_end + 4..];
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v == "chunked");
    let body = if chunked { decode_chunked(rest) } else { rest.to_vec() };
    Response {
        status,
        headers,
        body,
    }
}

/// Minimal chunked-transfer decoder, independent of the server's
/// encoder: lowercase-hex size line, CRLF, payload, CRLF, until the
/// zero chunk — anything else panics the test.
fn decode_chunked(mut rest: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let line_end = find(rest, b"\r\n").expect("chunk size line");
        let size =
            usize::from_str_radix(std::str::from_utf8(&rest[..line_end]).unwrap(), 16)
                .expect("hex chunk size");
        rest = &rest[line_end + 2..];
        if size == 0 {
            assert_eq!(&rest[..2], b"\r\n", "terminal chunk must end with CRLF");
            return out;
        }
        out.extend_from_slice(&rest[..size]);
        assert_eq!(&rest[size..size + 2], b"\r\n", "chunk payload must end with CRLF");
        rest = &rest[size + 2..];
    }
}

struct SseEvent {
    id: u64,
    event: String,
    data: String,
}

/// Strict SSE splitter: every frame must be exactly id/event/data.
fn parse_sse(payload: &[u8]) -> Vec<SseEvent> {
    let text = std::str::from_utf8(payload).expect("SSE payload is UTF-8");
    let mut events = Vec::new();
    for frame in text.split("\r\n\r\n").filter(|f| !f.is_empty()) {
        let (mut id, mut event, mut data) = (None, None, None);
        for line in frame.split("\r\n") {
            if let Some(v) = line.strip_prefix("id: ") {
                id = Some(v.parse().unwrap());
            } else if let Some(v) = line.strip_prefix("event: ") {
                event = Some(v.to_string());
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = Some(v.to_string());
            } else {
                panic!("unexpected SSE line: {line:?}");
            }
        }
        events.push(SseEvent {
            id: id.expect("id field"),
            event: event.expect("event field"),
            data: data.expect("data field"),
        });
    }
    events
}

/// Concatenate the token deltas of every `token` event, in order.
fn stream_tokens(events: &[SseEvent]) -> Vec<i64> {
    events
        .iter()
        .filter(|e| e.event == "token")
        .flat_map(|e| {
            Json::parse(&e.data)
                .unwrap()
                .get("tokens")
                .as_arr()
                .expect("tokens array")
                .iter()
                .map(|t| t.as_i64().unwrap())
                .collect::<Vec<_>>()
        })
        .collect()
}

fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

fn count(hay: &[u8], needle: &[u8]) -> usize {
    if hay.len() < needle.len() {
        return 0;
    }
    hay.windows(needle.len()).filter(|w| *w == needle).count()
}

// ---------------------------------------------------------------------------
// the edge contract
// ---------------------------------------------------------------------------

/// THE streaming guarantee: concatenating the streamed token deltas
/// yields exactly the one-shot reply's token sequence (SimCore tokens
/// are position-deterministic, so two sessions over the same prompt
/// must agree bit-for-bit).
#[test]
fn stream_is_bit_identical_to_one_shot() {
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), FaultPlan::default());
    // 96 tokens: 3x the stream_buffer coalescing cap, so the stream is
    // provably incremental (at least three token events) even when the
    // simulated decode outruns the handler.
    let one_shot = parse_response(&post_generate(
        &server,
        "{\"prompt\": [1, 2], \"max_new\": 96, \"stream\": false}",
    ));
    assert_eq!(one_shot.status, 200);
    assert_eq!(one_shot.header("content-type"), Some("application/json"));
    let body = one_shot.body_json();
    let want: Vec<i64> = body
        .get("tokens")
        .as_arr()
        .expect("tokens array")
        .iter()
        .map(|t| t.as_i64().unwrap())
        .collect();
    assert_eq!(want.len(), 96);
    assert_eq!(body.get("n_tokens").as_usize(), Some(96));

    let streamed = parse_response(&post_generate(
        &server,
        "{\"prompt\": [1, 2], \"max_new\": 96}",
    ));
    assert_eq!(streamed.status, 200);
    let events = parse_sse(&streamed.body);
    assert_eq!(events[0].event, "queued");
    assert!(
        events.iter().filter(|e| e.event == "token").count() >= 3,
        "tokens must stream incrementally, not as one terminal burst"
    );
    assert_eq!(
        stream_tokens(&events),
        want,
        "streamed deltas must concatenate to the one-shot tokens exactly"
    );
    let done = events.last().unwrap();
    assert_eq!(done.event, "done");
    let d = Json::parse(&done.data).unwrap();
    assert_eq!(d.get("n_tokens").as_usize(), Some(96));
    let stats = d.get("stats");
    assert!(stats.get("tau").as_f64().unwrap() >= 1.0);
    assert!(stats.get("drafted").as_arr().is_some());
    assert!(stats.get("generated_tokens").as_usize().unwrap() >= 96);
    server.shutdown();
}

/// Golden wire framing: the response head, the byte-exact first frame,
/// CRLF discipline, monotonic event ids, one terminal event, and the
/// chunked round-trip through this file's own decoder.
#[test]
fn golden_sse_framing_is_pinned() {
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), FaultPlan::default());
    let raw = post_generate(&server, "{\"prompt\": [5], \"max_new\": 6}");
    assert!(raw.starts_with(b"HTTP/1.1 200 OK\r\n"));
    let head_end = find(&raw, b"\r\n\r\n").unwrap();
    let head = std::str::from_utf8(&raw[..head_end]).unwrap();
    for needle in [
        "Content-Type: text/event-stream",
        "Cache-Control: no-cache",
        "Connection: close",
        "Transfer-Encoding: chunked",
    ] {
        assert!(head.contains(needle), "missing {needle:?} in:\n{head}");
    }
    // CRLF discipline across the WHOLE response: no bare LF anywhere.
    for (i, b) in raw.iter().enumerate() {
        if *b == b'\n' {
            assert_eq!(raw[i - 1], b'\r', "bare LF at byte {i}");
        }
    }
    assert!(raw.ends_with(b"0\r\n\r\n"), "terminal chunk must close the body");
    let payload = decode_chunked(&raw[head_end + 4..]);
    assert!(
        payload.starts_with(b"id: 0\r\nevent: queued\r\ndata: {}\r\n\r\n"),
        "first frame not pinned, got: {}",
        String::from_utf8_lossy(&payload[..payload.len().min(64)])
    );
    let events = parse_sse(&payload);
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.id, i as u64, "event ids must increase monotonically from 0");
    }
    assert_eq!(events.last().unwrap().event, "done");
    assert_eq!(
        events
            .iter()
            .filter(|e| e.event == "done" || e.event == "fault")
            .count(),
        1,
        "exactly one terminal event"
    );
    server.shutdown();
}

/// Edge chaos: the client severs its connection mid-stream (driven by
/// the FaultPlan's `drop_conn_at`); the edge must notice, cancel the
/// session through the router, and free the slot for new work.
#[test]
fn mid_stream_disconnect_cancels_the_session() {
    let plan = FaultPlan::default().drop_conn_at(2);
    let drop_after = plan.drop_conn_at.unwrap() as usize;
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), plan);
    // Admissible, but far too long to finish on its own: the session
    // can only end because the vanished client cancels it.
    let (s, _) = open_stream(&server, "{\"prompt\": [1, 2], \"max_new\": 2000}", drop_after);
    drop(s); // act out DropConnAt: FIN mid-stream

    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let m = metrics_text(&server);
        if m.contains("lkspec_sched_cancelled_total{engine=\"router\"} 1")
            && m.contains("lkspec_http_disconnects_total 1")
            && m.contains("lkspec_http_queue_depth 0")
        {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect-cancel not observed; metrics:\n{m}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // The freed slot serves fresh work to completion.
    let resp = parse_response(&post_generate(
        &server,
        "{\"prompt\": [9], \"max_new\": 4, \"stream\": false}",
    ));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body_json().get("tokens").as_arr().unwrap().len(), 4);
    server.shutdown();
}

/// Graceful drain through the edge: `/healthz` flips to 503, new
/// generate requests are refused with 503, and the in-flight stream
/// keeps decoding to its full `done` event.
#[test]
fn drain_refuses_new_work_and_finishes_inflight() {
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), FaultPlan::default());
    let (mut s, mut seen) = open_stream(&server, "{\"prompt\": [3, 4], \"max_new\": 64}", 1);
    server.drain();
    let hz = parse_response(&request(&server, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n"));
    assert_eq!(hz.status, 503);
    assert!(String::from_utf8(hz.body).unwrap().contains("draining"));
    let refused = parse_response(&post_generate(&server, "{\"prompt\": [7], \"max_new\": 4}"));
    assert_eq!(refused.status, 503);
    assert!(
        String::from_utf8(refused.body).unwrap().contains("draining"),
        "refusal must say why"
    );
    // The stream opened before the drain runs to completion.
    s.read_to_end(&mut seen).expect("stream tail");
    let resp = parse_response(&seen);
    let events = parse_sse(&resp.body);
    let done = events.last().unwrap();
    assert_eq!(done.event, "done", "in-flight work must finish under drain");
    assert_eq!(
        Json::parse(&done.data).unwrap().get("n_tokens").as_usize(),
        Some(64)
    );
    let m = metrics_text(&server);
    assert!(
        m.contains("lkspec_http_sheds_total 1"),
        "the drain refusal must count as a shed; metrics:\n{m}"
    );
    server.shutdown();
}

/// Backpressure at the edge: with the scheduler queue held full
/// (buckets never fill, `max_wait` outlasts the test), the next request
/// bounces with 429 + `Retry-After`, and the edge's queue-depth gauge
/// agrees with the scheduler's own.
#[test]
fn queue_full_returns_429_and_gauges_agree() {
    let server = edge(vec![4], 2, Duration::from_secs(1000), FaultPlan::default());
    let body = "{\"prompt\": [1], \"max_new\": 4}";
    let mut held = Vec::new();
    for _ in 0..2 {
        // Wait for `queued` so the two admissions are ordered.
        let (s, _) = open_stream(&server, body, 0);
        held.push(s);
    }
    let resp = parse_response(&post_generate(&server, body));
    assert_eq!(resp.status, 429);
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert!(
        String::from_utf8(resp.body.clone()).unwrap().contains("queue full"),
        "429 body must carry the verdict"
    );
    let m = metrics_text(&server);
    assert!(m.contains("lkspec_http_queue_depth 2"), "metrics:\n{m}");
    assert!(
        m.contains("lkspec_sched_queue_depth{engine=\"router\"} 2"),
        "edge and scheduler must agree on queued work; metrics:\n{m}"
    );
    assert!(m.contains("lkspec_http_sheds_total 1"), "metrics:\n{m}");
    drop(held);
    server.shutdown();
}

/// A session-fatal engine fault mid-stream arrives as an SSE `fault`
/// event (the 200 head is already on the wire) and still terminates the
/// chunked body cleanly.
#[test]
fn mid_stream_fault_arrives_as_sse_fault_event() {
    let server = edge(
        vec![1, 4],
        16,
        Duration::from_millis(1),
        FaultPlan::default().session_fatal_at(2, 0),
    );
    let raw = post_generate(&server, "{\"prompt\": [1, 2], \"max_new\": 500}");
    let resp = parse_response(&raw);
    assert_eq!(resp.status, 200, "the fault struck after the 200 head");
    assert!(raw.ends_with(b"0\r\n\r\n"), "fault must still close the body");
    let events = parse_sse(&resp.body);
    let last = events.last().unwrap();
    assert_eq!(last.event, "fault");
    let d = Json::parse(&last.data).unwrap();
    assert_eq!(d.get("status").as_i64(), Some(500));
    assert!(
        d.get("error").as_str().unwrap().contains("session fault"),
        "got: {}",
        last.data
    );
    server.shutdown();
}

/// HTTP/1.1 keep-alive: two sequential requests over ONE connection —
/// glued into a single write, so the parser's residual hand-off is
/// exercised too. The first response (no `Connection` header sent) must
/// answer `keep-alive` and be `Content-Length`-framed; the second sends
/// `Connection: close` and the server closes after answering. The whole
/// exchange consumes exactly one connection slot.
#[test]
fn two_requests_one_connection() {
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), FaultPlan::default());
    let mut s = connect(&server);
    let body = "{\"prompt\": [1, 2], \"max_new\": 8, \"stream\": false}";
    let first_req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let second_req = "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    s.write_all(format!("{first_req}{second_req}").as_bytes())
        .unwrap();
    let first = parse_response(&read_one_response(&mut s));
    assert_eq!(first.status, 200);
    assert_eq!(
        first.header("connection"),
        Some("keep-alive"),
        "HTTP/1.1 without Connection: close must keep the connection"
    );
    assert_eq!(first.body_json().get("tokens").as_arr().unwrap().len(), 8);
    let mut rest = Vec::new();
    s.read_to_end(&mut rest)
        .expect("second response then orderly close");
    let second = parse_response(&rest);
    assert_eq!(second.status, 200);
    assert_eq!(
        second.header("connection"),
        Some("close"),
        "the server must honor the client's Connection: close"
    );
    assert!(String::from_utf8(second.body).unwrap().contains("ok"));
    // One keep-alive connection + the metrics probe's own = 2 total.
    let m = metrics_text(&server);
    assert!(
        m.contains("lkspec_http_conns_total 2"),
        "both requests must share one connection slot; metrics:\n{m}"
    );
    server.shutdown();
}

/// Admission and parse errors surface as their mapped status codes —
/// never a hang, never a panic, never a 200.
#[test]
fn edge_maps_errors_to_status_codes() {
    let server = edge(vec![1, 4], 16, Duration::from_millis(1), FaultPlan::default());
    let hz = parse_response(&request(&server, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n"));
    assert_eq!(hz.status, 200);
    // Malformed request head -> 400 (parser verdict).
    let resp = parse_response(&request(&server, "NOT HTTP\r\n\r\n"));
    assert_eq!(resp.status, 400);
    // Unknown route -> 404.
    let resp = parse_response(&request(&server, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n"));
    assert_eq!(resp.status, 404);
    // Non-JSON body -> 400.
    let resp = parse_response(&post_generate(&server, "not json"));
    assert_eq!(resp.status, 400);
    // Missing prompt -> 400 naming the field.
    let resp = parse_response(&post_generate(&server, "{\"max_new\": 4}"));
    assert_eq!(resp.status, 400);
    assert!(String::from_utf8(resp.body).unwrap().contains("prompt"));
    // Inadmissible size -> 413 (the paged pool can never hold it).
    let resp = parse_response(&post_generate(
        &server,
        "{\"prompt\": [1], \"max_new\": 100000}",
    ));
    assert_eq!(resp.status, 413);
    assert!(String::from_utf8(resp.body).unwrap().contains("KV blocks"));
    server.shutdown();
}
